"""Pure-JAX sharded checkpointing.

Layout on disk:
    <dir>/step_<N>/manifest.json     tree structure, shapes, dtypes
    <dir>/step_<N>/arrays.npz        flat leaf arrays (key = leaf path)
    <dir>/step_<N>/DONE              commit marker (atomic completion)

Features:
* async save (background thread; ``wait()`` joins) — training never blocks
  on the filesystem,
* elastic restore: arrays are saved unsharded and re-``device_put`` under
  whatever sharding the *restoring* mesh wants, so a 512-chip checkpoint
  restores onto 256 chips (or a reshaped mesh) without conversion,
* integrity: restore only reads checkpoints with a DONE marker; interrupted
  saves are invisible.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

Pytree = Any


def _flatten(tree: Pytree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


# dtypes numpy's savez cannot serialize -> stored as a same-width uint view,
# with the true dtype recorded in the manifest (lossless)
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def save(tree: Pytree, directory: str, step: int) -> str:
    """Synchronous save. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    true_dtypes = {k: str(a.dtype) for k, a in arrays.items()}
    stored = {k: (a.view(_VIEW_AS[str(a.dtype)])
                  if str(a.dtype) in _VIEW_AS else a)
              for k, a in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "leaves": {k: {"shape": list(a.shape), "dtype": true_dtypes[k]}
                           for k, a in arrays.items()}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "DONE"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


class AsyncSaver:
    """Fire-and-forget checkpointing with at most one save in flight."""

    def __init__(self) -> None:
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, tree: Pytree, directory: str, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(host_tree, directory, step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "DONE")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, like: Pytree, step: Optional[int] = None,
            sharding_fn: Optional[Callable[[str, Any], Any]] = None) -> Pytree:
    """Restore into the structure of ``like``.

    ``sharding_fn(leaf_path, abstract_leaf) -> Sharding | None`` lets the
    caller reshard onto a *different* mesh than the one that saved (elastic
    restart).  Leaves are matched by tree path.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "DONE")):
        raise IOError(f"checkpoint {path} is not committed")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    out_flat = {}
    for key, leaf in flat_like.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        true_dt = manifest["leaves"].get(key, {}).get("dtype", str(arr.dtype))
        if true_dt in _VIEW_AS:                 # un-view bf16/f8 payloads
            arr = arr.view(jnp.dtype(true_dt))
        want = np.dtype(jnp.asarray(leaf).dtype if not hasattr(leaf, "dtype")
                        else leaf.dtype)
        arr = arr.astype(want, copy=False)
        if sharding_fn is not None:
            sh = sharding_fn(key, leaf)
            out_flat[key] = (jax.device_put(arr, sh) if sh is not None
                             else jnp.asarray(arr))
        else:
            out_flat[key] = jnp.asarray(arr)
    # rebuild in the order/structure of `like`
    paths_leaves = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in paths_leaves[0]]
    leaves = [out_flat[k] for k in keys]
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)
