"""Fault tolerance & elasticity, driven by the H-EYE HW-GRAPH.

The same dynamic-adaptability machinery the paper demonstrates on edge
fleets (§5.4: bandwidth drops, nodes joining) handles TPU-fleet failures:

* a failed host is marked dead via a ``Churn`` delta batch — the compiled
  scheduling snapshot absorbs this via ``CompiledHWGraph.apply_delta`` (no
  full recompile), and ``remap`` pushes the orphaned work back through the
  batch-first scheduling surface (``Orchestrator.map_batch`` /
  ``SchedulerSession``) in one frontier instead of task-by-task;
* the manager recomputes the largest healthy mesh (elastic rescale) and
  replays from the last committed checkpoint, resharded onto the surviving
  mesh (checkpoint/store.restore takes a per-leaf sharding_fn);
* stragglers are detected as step-time outliers vs the fleet median — the
  H-EYE slowdown model's inverse: an unexplained slowdown on one host means
  contention we did not schedule, so the Orchestrator re-maps work off it;
* periodic async checkpointing bounds lost work to one interval.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.hwgraph import Churn, HWGraph
from repro.checkpoint import AsyncSaver


@dataclass
class FTConfig:
    checkpoint_every: int = 100
    straggler_factor: float = 1.8        # step time > f * median => straggler
    straggler_patience: int = 3          # consecutive flags before action
    min_hosts: int = 1


@dataclass
class RecoveryPlan:
    restore_step: int
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    lost_hosts: tuple[str, ...]


class FTManager:
    def __init__(self, graph: HWGraph, cfg: Optional[FTConfig] = None,
                 ckpt_dir: str = "/tmp/repro_ckpt") -> None:
        self.graph = graph
        self.cfg = cfg or FTConfig()
        self.ckpt_dir = ckpt_dir
        self.saver = AsyncSaver()
        self.last_committed = -1
        self._strikes: dict[str, int] = {}

    # -- checkpointing --------------------------------------------------------
    def maybe_checkpoint(self, state, step: int) -> bool:
        if step % self.cfg.checkpoint_every != 0:
            return False
        self.saver.save(state, self.ckpt_dir, step)
        self.last_committed = step
        return True

    # -- health ------------------------------------------------------------------
    def alive_hosts(self) -> list[str]:
        return sorted({n.name for n in self.graph.nodes.values()
                       if n.attrs.get("orc_level") == "device" and n.alive})

    def alive_chips(self) -> int:
        return len(self.graph.pus())

    def report_step_times(self, times: dict[str, float]) -> list[str]:
        """Feed per-host step times; returns hosts confirmed as stragglers."""
        if len(times) < 2:
            return []
        med = float(np.median(list(times.values())))
        confirmed = []
        for host, t in times.items():
            if t > self.cfg.straggler_factor * med:
                self._strikes[host] = self._strikes.get(host, 0) + 1
                if self._strikes[host] >= self.cfg.straggler_patience:
                    confirmed.append(host)
            else:
                self._strikes[host] = 0
        return confirmed

    # -- failure / elastic rescale ---------------------------------------------
    def on_failure(self, hosts: list[str]) -> RecoveryPlan:
        self.graph.apply_churn(Churn(dead=tuple(hosts)))
        return self.plan_mesh()

    def on_join(self, host: str) -> RecoveryPlan:
        self.graph.apply_churn(Churn(alive=(host,)))
        return self.plan_mesh()

    def remap(self, scheduler, tasks, now: float = 0.0):
        """Re-place orphaned tasks after ``on_failure`` in one batch.

        ``scheduler`` is an Orchestrator root (or anything exposing
        ``map_batch(tasks, now)``); the dead hosts are already invisible
        to its eligibility masks via the delta-patched snapshot."""
        from repro.core.orchestrator import Orchestrator
        if isinstance(scheduler, Orchestrator):
            return scheduler.map_batch(tasks, now, route=True)
        return scheduler.map_batch(tasks, now)

    def plan_mesh(self, model_parallel: int = 16) -> RecoveryPlan:
        """Largest (data, model) grid over surviving chips, keeping the model
        axis if divisible (re-sharding params across a different TP degree
        needs no conversion — the checkpoint is stored unsharded)."""
        chips = self.alive_chips()
        if chips == 0:
            raise RuntimeError("no healthy chips remain")
        tp = model_parallel
        while tp > 1 and chips % tp:
            tp //= 2
        dp = chips // tp
        # largest power-of-two dp for clean batch sharding
        dp = 2 ** int(math.floor(math.log2(dp))) if dp > 0 else 1
        dead = tuple(n.name for n in self.graph.nodes.values()
                     if n.attrs.get("orc_level") == "device" and not n.alive)
        return RecoveryPlan(restore_step=max(self.last_committed, 0),
                            mesh_shape=(dp, tp), mesh_axes=("data", "model"),
                            lost_hosts=dead)
