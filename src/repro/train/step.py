"""Training step: loss, gradient accumulation (microbatching), AdamW.

``make_train_step`` builds a jit-able function over a TrainState dict
{"params", "opt"} — pytree-native so pjit sharding rules apply uniformly.
Microbatching splits the global batch along axis 0 and accumulates grads
with a ``lax.scan`` (keeps activation memory at one microbatch).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.model import Model
from repro.optim import OptConfig, adamw_update, init_opt_state

Pytree = Any
AUX_WEIGHT = 0.01      # MoE load-balance loss weight
IGNORE = -1            # masked label id


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL with IGNORE masking.  logits (B,S,V) fp32."""
    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = cross_entropy(logits, batch["labels"])
        return loss + AUX_WEIGHT * aux, {"loss": loss, "aux": aux}
    return loss_fn


def init_train_state(model: Model, rng: jax.Array,
                     opt_cfg: Optional[OptConfig] = None,
                     param_dtype: Any = None) -> Pytree:
    """``param_dtype=bf16`` selects pure-bf16 training (master weights in
    bf16) — the escape hatch for 400B-class models on a 4 TB-HBM pod."""
    opt_cfg = opt_cfg or OptConfig()
    params = model.init(rng)
    if param_dtype is not None:
        params = jax.tree.map(lambda p: p.astype(param_dtype), params)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def make_train_step(model: Model, opt_cfg: Optional[OptConfig] = None,
                    microbatches: int = 1, accum_dtype: Any = jnp.float32):
    """Returns train_step(state, batch) -> (state, metrics)."""
    opt_cfg = opt_cfg or OptConfig()
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: Pytree, batch: Pytree):
        params = state["params"]
        if microbatches <= 1:
            (tot, metrics), grads = grad_fn(params, batch)
        else:
            def resplit(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            micro = jax.tree.map(resplit, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (g_acc, l_acc + m["loss"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (grads, loss_sum), _ = lax.scan(acc_fn, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {"loss": loss_sum / microbatches,
                       "aux": jnp.zeros(())}
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], opt_cfg)
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
