import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including repro.*):
# jax locks the device count on first initialization, and the production
# meshes below need 512 placeholder host devices.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
        --shape train_4k --mesh single --set microbatches=16 --set remat=none

Per cell this script:
  1. asks the placement search (core/placement.py — H-EYE's predict ->
     check-constraint -> assign loop over layouts) for a Plan,
  2. builds the jitted step (train_step / prefill / serve_step) with explicit
     in/out shardings, ``.lower()``s it against ShapeDtypeStruct inputs
     (no allocation) and ``.compile()``s it,
  3. prints ``compiled.memory_analysis()`` (proves the cell fits HBM) and
     ``compiled.cost_analysis()``,
  4. parses the SPMD HLO with launch/hlo_analysis.py (loop-aware: XLA's
     cost_analysis counts while bodies once) into the three roofline terms,
  5. appends the record to a JSON results file consumed by
     benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import SHAPES, Shape, input_specs, shape_applicable
from repro.core.placement import Plan, choose_plan, model_flops, predict_plan
from repro.launch import hlo_analysis
from repro.launch.mesh import batch_axes as mesh_batch_axes
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import batch_sharding, make_shardings
from repro.models import ParallelCtx, build_model
from repro.optim import OptConfig
from repro.train.step import init_train_state, make_train_step

HBM_PER_CHIP = 16e9   # TPU v5e


def _mesh_info(mesh):
    return tuple(mesh.devices.shape), tuple(mesh.axis_names)


def build_and_lower(arch: str, shape_name: str, mesh, plan: Plan):
    """Returns (lowered, n_chips, tokens, mode)."""
    cfg = get_config(arch)
    if cfg.n_experts > 0 and plan.moe_group != cfg.moe_group:
        cfg = cfg.scaled(moe_group=plan.moe_group)
    shape = SHAPES[shape_name]
    baxes = mesh_batch_axes(mesh)
    # (§Perf refuted hypothesis: dropping the model-axis activation
    # constraints under fsdp_only lets XLA insert a full-width fp32
    # all-reduce instead — keep the constraints for every policy.)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    ctx = ParallelCtx(batch_axes=baxes, model_axis="model", model_size=msize,
                      remat=plan.remat, compute_dtype=jnp.bfloat16)
    model = build_model(cfg, ctx)
    specs = input_specs(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    n_chips = 1
    for d in mesh.devices.shape:
        n_chips *= d

    if shape.mode == "train":
        opt_cfg = OptConfig(state_dtype=jnp.dtype(plan.state_dtype))
        pdt = jnp.dtype(plan.param_dtype)
        state_shape = jax.eval_shape(
            lambda: init_train_state(model, jax.random.key(0), opt_cfg,
                                     param_dtype=pdt))
        state_sh = make_shardings(state_shape, mesh, policy=plan.policy,
                                  batch_axes=baxes)
        batch_sh = batch_sharding(specs, mesh, baxes)
        step = make_train_step(model, opt_cfg, microbatches=plan.microbatches,
                               accum_dtype=jnp.dtype(plan.accum_dtype))
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              donate_argnums=(0,)).lower(state_shape, specs)
        tokens = B * S
    elif shape.mode == "prefill":
        cdt = jnp.dtype(plan.cache_dtype)
        params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        cache_shape = jax.eval_shape(lambda: model.init_cache(B, S, dtype=cdt))
        params_sh = make_shardings(params_shape, mesh, policy=plan.policy,
                                   batch_axes=baxes)
        cache_sh = make_shardings(cache_shape, mesh, policy=plan.policy,
                                  batch_axes=baxes, cache_mode=plan.cache_mode)
        batch_sh = batch_sharding(specs, mesh, baxes)

        def prefill_step(params, cache, batch):
            return model.prefill(params, batch, cache)

        with mesh:
            lowered = jax.jit(
                prefill_step,
                in_shardings=(params_sh, cache_sh, batch_sh),
                donate_argnums=(1,)).lower(params_shape, cache_shape, specs)
        tokens = B * S
    else:  # decode
        cdt = jnp.dtype(plan.cache_dtype)
        params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        cache_shape = jax.eval_shape(lambda: model.init_cache(B, S, dtype=cdt))
        params_sh = make_shardings(params_shape, mesh, policy=plan.policy,
                                   batch_axes=baxes)
        cache_sh = make_shardings(cache_shape, mesh, policy=plan.policy,
                                  batch_axes=baxes, cache_mode=plan.cache_mode)
        batch_sh = batch_sharding(specs, mesh, baxes)

        def serve_step(params, cache, tokens, positions):
            return model.decode_step(params, cache, tokens, positions)

        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(params_sh, cache_sh, batch_sh["tokens"],
                              batch_sh["positions"]),
                donate_argnums=(1,)).lower(
                    params_shape, cache_shape, specs["tokens"],
                    specs["positions"])
        tokens = B
    return lowered, n_chips, tokens, shape.mode


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             plan: Plan | None = None, verbose: bool = True,
             autofit: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        record.update(status="skipped", reason=why)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    mesh_shape, mesh_axes = _mesh_info(mesh)
    if plan is None:
        plan, pred = choose_plan(cfg, shape, mesh_shape, mesh_axes)
    else:
        pred = predict_plan(cfg, shape, mesh_shape, mesh_axes, plan)

    if autofit:
        # measured-feedback loop: the analytic memory model chooses the
        # starting microbatch count; if the COMPILED peak exceeds HBM,
        # double mb and recompile (hypothesis -> measure -> iterate).
        attempts = []
        while True:
            rec = _compile_cell(arch, shape_name, mesh_kind, mesh, cfg,
                                shape, plan, pred, verbose)
            attempts.append({"microbatches": plan.microbatches,
                             "peak_gb": rec.get("memory", {}).get("peak_gb"),
                             "status": rec["status"]})
            over = (rec["status"] == "ok"
                    and not rec["memory"]["fits_hbm"]
                    and shape.mode == "train"
                    and plan.microbatches * 2 <= shape.global_batch)
            # stop when doubling mb no longer helps: the over-HBM component
            # is static state (params/optimizer), which microbatching cannot
            # shave (llama4 lesson, EXPERIMENTS.md §Perf-1)
            if (over and len(attempts) >= 2
                    and attempts[-2]["peak_gb"] is not None
                    and rec["memory"]["peak_gb"]
                    > 0.98 * attempts[-2]["peak_gb"]):
                rec["autofit_attempts"] = attempts
                rec["autofit_stopped"] = "static memory; mb-doubling flat"
                return rec
            if not over:
                rec["autofit_attempts"] = attempts
                return rec
            jax.clear_caches()
            plan = dataclasses.replace(plan,
                                       microbatches=plan.microbatches * 2)
            pred = predict_plan(cfg, shape, mesh_shape, mesh_axes, plan)
            if verbose:
                print(f"  autofit: over HBM -> retry with "
                      f"mb={plan.microbatches}", flush=True)
    return _compile_cell(arch, shape_name, mesh_kind, mesh, cfg, shape,
                         plan, pred, verbose)


def _compile_cell(arch, shape_name, mesh_kind, mesh, cfg, shape, plan,
                  pred, verbose) -> dict:
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    record["plan"] = dataclasses.asdict(plan)
    record["predicted"] = {
        "mem_gb": pred.mem_bytes / 1e9,
        "t_compute_s": pred.t_compute, "t_memory_s": pred.t_memory,
        "t_collective_s": pred.t_collective, "t_step_s": pred.t_step,
    }

    t0 = time.time()
    try:
        lowered, n_chips, tokens, mode = build_and_lower(
            arch, shape_name, mesh, plan)
        record["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 1)
    except Exception as e:   # a failure here is a bug in the system
        record.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        return record

    ma = compiled.memory_analysis()
    arg_b = ma.argument_size_in_bytes
    tmp_b = ma.temp_size_in_bytes
    out_b = ma.output_size_in_bytes
    alias_b = ma.alias_size_in_bytes
    peak = arg_b + tmp_b + max(0, out_b - alias_b)
    record["memory"] = {
        "argument_gb": arg_b / 1e9, "temp_gb": tmp_b / 1e9,
        "output_gb": out_b / 1e9, "aliased_gb": alias_b / 1e9,
        "peak_gb": peak / 1e9, "fits_hbm": bool(peak <= HBM_PER_CHIP),
    }
    ca = compiled.cost_analysis() or {}
    record["xla_cost"] = {"flops": ca.get("flops", 0.0),
                          "bytes_accessed": ca.get("bytes accessed", 0.0)}

    mf = model_flops(cfg, tokens, "train" if mode == "train" else "serve")
    rep = hlo_analysis.analyze_hlo(compiled.as_text())
    terms = hlo_analysis.roofline_terms(rep, n_chips=n_chips,
                                        model_flops_total=mf)
    record["roofline"] = terms
    record["status"] = "ok"
    if verbose:
        print(f"  memory_analysis: arg={arg_b/1e9:.2f}GB temp={tmp_b/1e9:.2f}GB "
              f"peak={peak/1e9:.2f}GB fits={peak <= HBM_PER_CHIP}")
        print(f"  cost_analysis:   flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e} (loop bodies x1)")
        print(f"  roofline:        Tc={terms['t_compute_s']*1e3:.2f}ms "
              f"Tm={terms['t_memory_s']*1e3:.2f}ms "
              f"Tl={terms['t_collective_s']*1e3:.2f}ms "
              f"bound={terms['bottleneck']} "
              f"useful={terms['useful_flops_ratio']:.2f} "
              f"frac={terms['roofline_fraction']:.2f}")
    return record


def _plan_overrides(pairs: list[str]) -> dict:
    out = {}
    for kv in pairs:
        k, v = kv.split("=", 1)
        if k == "microbatches":
            out[k] = int(v)
        elif k == "moe_group":
            out[k] = int(v)
        else:
            out[k] = v
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="override a Plan field (hillclimb variants)")
    ap.add_argument("--autofit", action="store_true",
                    help="if the compiled peak exceeds HBM, double the "
                         "microbatch count and recompile until it fits")
    ap.add_argument("--variant", default="baseline",
                    help="label stored with overridden-plan records")
    ap.add_argument("--cells", default=None,
                    help="slice of the cell list, e.g. 0:16 (parallel shards)")
    args = ap.parse_args(argv)

    from repro.configs import all_configs
    if args.all:
        cell_list = [(a, s) for a in all_configs() for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cell_list = [(args.arch, args.shape)]
    if args.cells:
        lo, hi = args.cells.split(":")
        cell_list = cell_list[int(lo):int(hi)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    overrides = _plan_overrides(args.set)
    results: dict[str, dict] = {}
    out_path = args.out
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)

    failures = 0
    for arch, shape_name in cell_list:
        for mesh_kind in meshes:
            key = f"{arch}|{shape_name}|{mesh_kind}|{args.variant}"
            print(f"[dryrun] {key}", flush=True)
            plan = None
            if overrides:
                cfg = get_config(arch)
                mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
                base, _ = choose_plan(cfg, SHAPES[shape_name],
                                      *_mesh_info(mesh))
                plan = dataclasses.replace(base, **overrides)
            rec = run_cell(arch, shape_name, mesh_kind, plan=plan,
                           autofit=args.autofit)
            rec["variant"] = args.variant
            results[key] = rec
            jax.clear_caches()      # keep host memory flat across 80 compiles
            if rec["status"] == "FAILED":
                failures += 1
                print(f"  FAILED: {rec['error']}", flush=True)
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)
    print(f"[dryrun] done: {len(cell_list) * len(meshes)} cells, "
          f"{failures} failures -> {out_path}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
