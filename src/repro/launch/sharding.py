"""Sharding policies: param-tree path -> PartitionSpec.

Logical roles per weight (Megatron/GSPMD conventions):
    col  (d_in, d_out*)  : in->fsdp, out->tp      (wq wk wv wg wu w_x ...)
    row  (d_in*, d_out)  : in->tp,  out->fsdp     (wo wd w_out w_o ...)
    embed (V, d)         : V->tp,  d->fsdp
    expert (E, ., .)     : E->tp (expert parallelism), then col/row inside
    vectors / norms / small tensors: replicated

Policies map logical axes onto mesh axes:
    tp_fsdp (default) : tp->model, fsdp->data   (2D: Megatron TP + ZeRO-3)
    tp_only           : tp->model, fsdp->None   (params replicated over data)
    fsdp_only         : tp->None,  fsdp->data
Params are replicated across the 'pod' axis (DCN carries only gradient
all-reduce) — the multi-pod baseline.  Dims that do not divide the mesh axis
fall back to replication (e.g. 8 q-heads on a 16-way model axis).

Stacked layers (leading n_super dim from scan) get a leading None.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

COL = ("fsdp", "tp")
ROW = ("tp", "fsdp")
_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)(embed|lm_head)$", ("tp", "fsdp")),
    (r"/moe/(wg|wu)$", ("tp", "fsdp", None)),       # (E, d, ff)
    (r"/moe/wd$", ("tp", None, "fsdp")),            # (E, ff, d)
    (r"/moe/router$", ("fsdp", None)),
    (r"/(wq|wk|wv|wg|wu|w_x|w_gate|w_r|w_k|w_v|w_g|w_lora_a)$", COL),
    (r"/(wo|wd|w_out|w_o|w_lora_b)$", ROW),
    # caches: (B, C, Hkv, hd) -> batch over data axes; recurrent states
    (r"/attn/(k|v)$", ("batch", None, None, None)),
    (r"/cross_kv/(k|v)$", ("batch", None, None, None)),
    (r"/rec/(h|state)$", ("batch", None)),           # padded per-ndim below
]
# cache_mode overrides for KV caches (flash-decode style seq sharding, or
# kv-head TP when the head count divides the model axis).  "ctp" resolves to
# the model axis under EVERY policy — the cache must shard even when params
# are fsdp-only, else a 32k x batch cache replicates 16x.
_CACHE_MODES = {
    "batch": ("batch", None, None, None),
    "seq": ("batch", "ctp", None, None),
    "heads": ("batch", None, "ctp", None),
}


# weight-stationary MoE overrides (policy tp_fsdp_moeff): the ff dim shards
# over data, so the (huge) expert weights stay put; forward/backward instead
# all-reduce the (small) activation partial sums over data.
_MOEFF_RULES = {
    "wg": ("tp", None, "fsdp"), "wu": ("tp", None, "fsdp"),
    "wd": ("tp", "fsdp", None),
}


def _logical_for(path: str, ndim: int, cache_mode: str = "batch",
                 policy: str = "tp_fsdp") -> tuple:
    if policy == "tp_fsdp_moeff":
        m = re.search(r"/moe/(wg|wu|wd)$", path)
        if m:
            ax = list(_MOEFF_RULES[m.group(1)])
            if ndim > 3:
                ax = [None] * (ndim - 3) + ax
            return tuple(ax)
    for pat, axes in _RULES:
        if re.search(pat, path):
            ax = list(axes)
            if re.search(r"/attn/(k|v)$", path):
                ax = list(_CACHE_MODES[cache_mode])
            if len(ax) < ndim:                    # stacked: leading scan dims
                ax = [None] * (ndim - len(ax)) + ax
            elif len(ax) > ndim:
                ax = ax[-ndim:] if ndim > 0 else []
            return tuple(ax)
    return (None,) * ndim


def _resolve(logical: tuple, shape: tuple, mesh: Mesh, policy: str,
             batch_axes: tuple[str, ...]) -> P:
    mapping = {"tp_fsdp": {"tp": "model", "fsdp": "data"},
               "tp_only": {"tp": "model", "fsdp": None},
               "fsdp_only": {"tp": None, "fsdp": "data"},
               "fsdp_pod": {"tp": "model", "fsdp": ("data", "pod")
                            if "pod" in mesh.axis_names else "data"},
               # weight-stationary MoE: like tp_fsdp, but expert FFNs keep
               # the ff dim sharded over data (see _MOEFF_RULES) so expert
               # weights are never all-gathered per microbatch
               "tp_fsdp_moeff": {"tp": "model", "fsdp": "data"},
               }[policy]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, role in enumerate(logical):
        if role == "batch":
            ax: Any = tuple(a for a in batch_axes if a in sizes)
            n = int(np.prod([sizes[a] for a in ax])) if ax else 1
            if not ax or shape[dim] % n:
                ax = None
            elif len(ax) == 1:
                ax = ax[0]
        elif role == "ctp":
            ax = "model" if "model" in sizes else None
            if ax is not None and shape[dim] % sizes[ax]:
                ax = None
        elif role in ("tp", "fsdp"):
            ax = mapping[role]
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([sizes[a] for a in axes]))
                if shape[dim] % n:
                    ax = None
        else:
            ax = None
        out.append(ax)
    return P(*out)


def tree_paths_and_leaves(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def make_shardings(tree: Pytree, mesh: Mesh, policy: str = "tp_fsdp",
                   batch_axes: tuple[str, ...] = ("data",),
                   cache_mode: str = "batch") -> Pytree:
    """NamedSharding tree matching ``tree`` (of arrays or ShapeDtypeStructs)."""
    flat, treedef = tree_paths_and_leaves(tree)
    shardings = []
    for path, leaf in flat:
        logical = _logical_for(path, len(leaf.shape), cache_mode, policy)
        spec = _resolve(logical, leaf.shape, mesh, policy, batch_axes)
        shardings.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def batch_sharding(specs: Pytree, mesh: Mesh,
                   batch_axes: tuple[str, ...]) -> Pytree:
    """Shard dim-0 (global batch) over the batch axes; replicate the rest."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = int(np.prod([sizes[a] for a in batch_axes]))

    def one(leaf):
        if leaf.shape and leaf.shape[0] % n == 0:
            ax = batch_axes[0] if len(batch_axes) == 1 else batch_axes
            return NamedSharding(mesh, P(ax, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, specs)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
