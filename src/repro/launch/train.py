"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --steps 200 --batch 8 --seq 128 --smoke

Wires every substrate layer together on whatever devices exist (the
production meshes are exercised by dryrun.py): config registry -> model ->
data pipeline (prefetched) -> sharded train step -> AdamW -> periodic async
checkpointing -> restart-from-latest, with the FT manager watching per-step
times for stragglers.  ``--smoke`` shrinks the arch to its reduced config so
the driver runs on one CPU; without it the full config is used (TPU fleet).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, synthetic_batches
from repro.ft.manager import FTConfig, FTManager
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.launch.sharding import batch_sharding, make_shardings
from repro.models import ParallelCtx, build_model
from repro.optim import OptConfig
from repro.train.step import init_train_state, make_train_step
from repro.checkpoint import latest_step, restore


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh(data=len(jax.devices()))
    baxes = batch_axes(mesh)
    ctx = ParallelCtx(batch_axes=baxes, model_axis="model",
                      compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    model = build_model(cfg, ctx)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        decay_steps=args.steps)

    state = init_train_state(model, jax.random.key(0), opt_cfg)
    start_step = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        start_step = latest_step(args.ckpt_dir)
        state = restore(args.ckpt_dir, state)
        print(f"[train] resumed from step {start_step}")

    state_sh = make_shardings(state, mesh)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, state_sh)

    dcfg = DataConfig(batch=args.batch, seq=args.seq, vocab=cfg.vocab,
                      seed=start_step)
    specs = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    b_sh = batch_sharding(specs, mesh, baxes)["tokens"]
    data = Prefetcher(synthetic_batches(dcfg, cfg), depth=2)

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0,))

    from repro.core.topology import build_tpu_fleet
    ft = FTManager(build_tpu_fleet(n_pods=1, hosts_per_pod=1,
                                   chips_per_host=len(jax.devices())).graph,
                   FTConfig(checkpoint_every=args.ckpt_every),
                   ckpt_dir=args.ckpt_dir)

    t_last = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            batch = next(data)
            batch = {k: jax.device_put(jnp.asarray(v), b_sh)
                     if v.ndim == 2 and v.shape == (args.batch, args.seq)
                     else jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            if (step + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                dt = (time.time() - t_last) / args.log_every
                t_last = time.time()
                tok_s = args.batch * args.seq / dt
                print(f"[train] step {step + 1:5d} loss {loss:7.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):6.2f} "
                      f"{dt * 1e3:7.1f} ms/step {tok_s:9.0f} tok/s",
                      flush=True)
                ft.report_step_times({"host0": dt})
            ft.maybe_checkpoint(state, step + 1)
    ft.saver.wait()
    data.close()
    print(f"[train] done at step {args.steps}; "
          f"last checkpoint: {latest_step(args.ckpt_dir)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
