"""Loop-aware HLO analysis for the roofline report.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any program
built from ``lax.scan`` (microbatch accumulation, scan-over-layers) is
undercounted by the product of trip counts.  This module parses the
post-SPMD HLO text instead:

* builds the computation call graph (while body/cond, fusion calls,
  to_apply reducers) with per-computation *execution multipliers* derived
  from ``backend_config={"known_trip_count":{"n":...}}``,
* FLOPs: every ``dot`` op contributes 2 * prod(output dims) * prod(lhs
  contracting dims), scaled by its computation's multiplier,
* collective bytes: every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute contributes its operand bytes, scaled
  and bucketed by type,
* HBM traffic estimate: operand + output bytes of every op at fusion
  granularity (ops inside fusion bodies are on-chip and skipped).  This
  over-counts reads (once per consumer) and ignores caching — treat it as
  an upper bound.

All sizes are per-device: the text is the SPMD-partitioned module.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*(?:\(([^)]*)\))?.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str          # text after the opening paren of the op call


@dataclass
class _Computation:
    name: str
    params: dict[str, str] = field(default_factory=dict)   # name -> type str
    ops: list[_Op] = field(default_factory=list)
    # edges: (callee, trip multiplier, via_fusion)
    calls: list[tuple[str, int, bool]] = field(default_factory=list)


@dataclass
class HloReport:
    """Per-device totals (the module is SPMD-partitioned)."""

    dot_flops: float = 0.0
    hbm_bytes: float = 0.0                    # fusion-granularity upper bound
    collective_bytes: dict[str, float] = field(default_factory=dict)
    collective_count: dict[str, int] = field(default_factory=dict)
    n_while: int = 0
    unknown_trip_whiles: int = 0
    top_traffic: list = field(default_factory=list)   # (bytes, op, shape)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _parse_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    entry: str | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = _Computation(name=m.group(1))
                if stripped.startswith("ENTRY"):
                    entry = m.group(1)
                for p in (m.group(2) or "").split(","):
                    p = p.strip()
                    if ":" in p:
                        pname, ptype = p.split(":", 1)
                        cur.params[pname.strip()] = ptype.strip()
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        op = _Op(name=name, type_str=type_str, kind=kind, rest=rest)
        cur.ops.append(op)
        # call edges
        trip = 1
        if kind == "while":
            tm = _TRIP_RE.search(rest)
            trip = int(tm.group(1)) if tm else -1
        for attr in _CALL_ATTR_RE.finditer(rest):
            callee = attr.group(1)
            via_fusion = kind == "fusion"
            cur.calls.append((callee, trip, via_fusion))
    if cur is not None:
        comps[cur.name] = cur
    if entry is not None and entry in comps:
        comps["__entry__"] = comps[entry]
    return comps


def analyze_hlo(text: str) -> HloReport:
    comps = _parse_computations(text)
    entry = comps.get("__entry__")
    rep = HloReport()
    if entry is None:
        return rep

    # multiplier per computation (and whether reached only through fusions)
    mult: dict[str, float] = defaultdict(float)
    fusion_internal: dict[str, bool] = {}

    def visit(comp: _Computation, m: float, via_fusion: bool) -> None:
        mult[comp.name] += m
        fusion_internal[comp.name] = (fusion_internal.get(comp.name, True)
                                      and via_fusion)
        for callee, trip, fus in comp.calls:
            if callee not in comps:
                continue
            t = trip
            if t == -1:
                rep.unknown_trip_whiles += 1
                t = 1
            visit(comps[callee], m * t, via_fusion or fus)

    visit(entry, 1.0, False)

    # op walks
    name_to_type: dict[str, dict[str, str]] = {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        table = dict(comp.params)
        for op in comp.ops:
            table[op.name] = op.type_str
        name_to_type[cname] = table

    seen = set()
    for cname, comp in comps.items():
        if cname == "__entry__" or comp.name in seen:
            continue
        seen.add(comp.name)
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        table = name_to_type[comp.name]
        internal = fusion_internal.get(comp.name, False)
        for op in comp.ops:
            if op.kind == "while":
                rep.n_while += 1
            if op.kind == "dot":
                out_dims = _shape_dims(op.type_str)
                lhs_m = _OPERAND_RE.search(op.rest)
                k = 1
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                if lhs_m and cd and lhs_m.group(1) in table:
                    lhs_dims = _shape_dims(table[lhs_m.group(1)])
                    for d in (cd.group(1).split(",") if cd.group(1) else []):
                        di = int(d)
                        if di < len(lhs_dims):
                            k *= lhs_dims[di]
                out = 1
                for d in out_dims:
                    out *= d
                rep.dot_flops += m * 2.0 * out * k
            if op.kind in COLLECTIVES:
                nbytes = 0
                # operands appear before the first ')', attrs after
                arg_text = op.rest.split(")")[0]
                for operand in _OPERAND_RE.findall(arg_text):
                    if operand in table:
                        nbytes += _shape_bytes(table[operand])
                if nbytes == 0:     # fall back to output size
                    nbytes = _shape_bytes(op.type_str)
                rep.collective_bytes[op.kind] = (
                    rep.collective_bytes.get(op.kind, 0.0) + m * nbytes)
                rep.collective_count[op.kind] = (
                    rep.collective_count.get(op.kind, 0) + 1)
            # HBM traffic at fusion granularity
            if not internal and op.kind not in ("tuple", "get-tuple-element",
                                                "parameter", "constant",
                                                "bitcast"):
                out_b = _shape_bytes(op.type_str)
                obytes = []
                arg_text = op.rest.split(")")[0]
                for operand in _OPERAND_RE.findall(arg_text):
                    if operand in table:
                        b = _shape_bytes(table[operand])
                        # inside a loop (m>1), an operand vastly larger than
                        # the op's output is a loop-carried buffer accessed
                        # through an internal (dynamic-)slice — charge the
                        # slice-sized access, not the whole buffer.  Weights
                        # fully re-read per iteration stay fully charged
                        # (they are never >64x the activation they produce).
                        if m > 1 and b > 64 * max(out_b, 1):
                            b = max(out_b, 1)
                        obytes.append(b)
                in_b = sum(obytes)
                total = m * (out_b + in_b)
                # dynamic-update-slice updates in place: the target buffer is
                # neither fully read nor fully written — charge the update
                # slice (2x the sub-buffer-sized operands; the target may
                # appear as several full-size aliased operands).
                lname = op.name.lower()
                if ("dynamic-update-slice" in lname
                        or op.kind == "dynamic-update-slice"):
                    small = sum(b for b in obytes if b < max(out_b, 1) / 2)
                    total = m * 2.0 * max(small, 1)
                elif op.kind == "dynamic-slice":
                    total = m * 2.0 * out_b
                rep.hbm_bytes += total
                rep.top_traffic.append((total, op.kind,
                                        op.type_str[:60], op.name[:40]))
    rep.top_traffic = sorted(rep.top_traffic, reverse=True)[:20]
    return rep


# ---------------------------------------------------------------------------
# roofline terms (assignment-prescribed hardware constants: TPU v5e)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link


def roofline_terms(rep: HloReport, *, n_chips: int,
                   model_flops_total: float = 0.0) -> dict:
    """Terms in seconds (per-step).  ``rep`` totals are per-device already,
    so the per-chip roofline divides by nothing further; total-FLOP ratios
    multiply back by n_chips."""
    t_compute = rep.dot_flops / PEAK_FLOPS
    t_memory = rep.hbm_bytes / HBM_BW
    t_coll = rep.total_collective_bytes / LINK_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    hlo_total_flops = rep.dot_flops * n_chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dominant,
        "hlo_flops_total": hlo_total_flops,
        "model_flops_total": model_flops_total,
        "useful_flops_ratio": (model_flops_total / hlo_total_flops
                               if hlo_total_flops else 0.0),
        "collective_bytes_per_chip": rep.total_collective_bytes,
        "collective_breakdown": dict(rep.collective_bytes),
        "roofline_bound_s": max(t_compute, t_memory, t_coll),
        "roofline_fraction": (t_compute /
                              max(t_compute, t_memory, t_coll)
                              if max(t_compute, t_memory, t_coll) > 0 else 0.0),
    }
