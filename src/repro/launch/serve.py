"""Serving driver: multi-tenant engine placement via the H-EYE Orchestrator.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        --requests 12 --smoke

Two layers cooperate, exactly as the paper's §3.2 prescribes:

* the H-EYE Orchestrator places request streams ("tenants") onto pod
  slices of a TPU-fleet HW-GRAPH, using the Traverser's slowdown model to
  keep every tenant's latency SLO intact under multi-tenancy, and
* a ServeEngine (continuous batching over a slot pool) executes the stream
  placed on THIS process's devices.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (SchedulerSession, Task, build_orchestrators,
                        heye_traverser, percentiles)
from repro.core.topology import build_tpu_fleet
from repro.models import ParallelCtx, build_model
from repro.serve.engine import Request, ServeEngine


def place_tenants(n_tenants: int, slo_s: float, est_s: float):
    """Map tenant streams onto fleet chips in one batch-first session;
    returns {tenant -> chip} and the scheduling overhead ledger.

    The whole tenant wave goes through ``SchedulerSession`` /
    ``Orchestrator.map_batch`` (origin-routed), replacing the removed
    per-tenant single-task loop — the assignments are identical (batch
    parity is pinned by tests/test_session.py) but the wave is scored in
    one kernel call."""
    tb = build_tpu_fleet(n_pods=1, hosts_per_pod=2, chips_per_host=4)
    # a profiled model for 'serve_stream' tasks: est_s per stream
    from repro.core.predict import CallableModel
    model = CallableModel(fn=lambda t, pu, unit: est_s * t.size)
    for chip in tb.graph.pus():
        chip.model = model
        chip.max_tenancy = 4
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    orc = next(o for o in root.iter_tree() if o.is_device_orc())
    tenants = []
    for _ in range(n_tenants):
        t = Task(kind="serve_stream", deadline=slo_s,
                 usage={"pu": 1.0, "mem": 0.6})
        t.origin = orc.group
        tenants.append(t)
    session = SchedulerSession(tb.graph, root, charge_overhead=False)
    session.submit(tenants)
    session.map_pending()
    placements = {i: session.mapping.get(t.uid)
                  for i, t in enumerate(tenants)}
    overheads = [session.results[t.uid].overhead
                 if session.results.get(t.uid) else 0.0 for t in tenants]
    return placements, overheads


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg, ParallelCtx(
        compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16))
    params = model.init(jax.random.key(0))

    # fleet-level placement: one tenant per batch of requests
    n_tenants = max(1, args.requests // args.slots)
    placements, overheads = place_tenants(
        n_tenants, slo_s=args.slo_ms * 1e-3, est_s=args.slo_ms * 0.4e-3)
    spread = len(set(filter(None, placements.values())))
    print(f"[serve] orchestrator placed {n_tenants} tenants on {spread} chips "
          f"(mean placement overhead {np.mean(overheads) * 1e6:.0f} us)")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=rng.integers(2, 6)
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    eng = ServeEngine(model, params, max_slots=args.slots,
                      max_len=args.max_len)
    # continuous batching with per-request wall latency (all requests
    # arrive at t0: open-loop burst, so latency includes slot queueing)
    t0 = time.time()
    pending, done, lat = list(reqs), [], []
    while pending or eng.active:
        if pending and eng.free:
            admitted = eng.admit_many(pending[:len(eng.free)])
            del pending[:len(admitted)]
        for r in eng.step():
            lat.append(time.time() - t0)
            done.append(r)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {eng._tokens_decoded} decode steps)")
    # tail metrics share the percentile definitions with ServeStats /
    # RunStats (docs/serving.md)
    pct = percentiles(lat)
    print(f"[serve] wall latency p50 {pct[50.0] * 1e3:.0f}ms  "
          f"p99 {pct[99.0] * 1e3:.0f}ms  p999 {pct[99.9] * 1e3:.0f}ms  "
          f"({eng.admitted_total} slot admissions, "
          f"{eng.slot_rejections} slot-exhaustion refusals)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
