"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run entry
point (dryrun.py) sets XLA_FLAGS to fabricate 512 host devices *before* any
jax import; everything else (tests, benches) sees the real single device.
"""
from __future__ import annotations

from typing import Optional

import jax


def _auto(n: int) -> dict:
    """``axis_types`` kwargs for ``jax.make_mesh``.

    ``jax.sharding.AxisType`` only exists on newer JAX; older installs
    (e.g. 0.4.37) take no ``axis_types`` argument and default to the same
    Auto behaviour, so simply omit it there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes, **_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests/examples on 1 CPU)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"), **_auto(2))


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes the global batch shards over (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
