"""Multi-tenant admission control for the online serving continuum.

Decides per request — **accept**, **reject**, or **defer** — against
per-tenant SLA deadlines, using the Orchestrator's own Alg. 1 signals:

* *feasibility* — ``Orchestrator.map_batch`` returning ``None`` for a
  task means no PU passed the constraint walk at current occupancy
  (eligibility, tenancy, memory, the l.15 deadline re-check of resident
  tasks), so the request cannot be placed without degrading someone;
* *projected slowdown* — for a placed task, ``MapResult.prediction.total``
  is the orchestrator's own end-to-end estimate (standalone x slowdown
  + comm); a projection beyond ``deadline * slack`` is an SLA miss the
  controller can refuse up front instead of discovering at p99.

Deferral re-enqueues the request ``defer_delay`` seconds later, up to
``max_defers`` times — the knob that turns a hard burst into a short
queue instead of a reject storm.  ``ServeEngine`` slot admission
(`serve/engine.py`) reports through the same shared claim/telemetry
path so a controller can treat simulator and token-serving admission
uniformly.

This module is dependency-light on purpose (no jax, no numpy): it is
imported by ``core.serving`` and usable from the jax-side serving stack
alike.
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import Enum
from typing import Optional, Sequence


class Verdict(Enum):
    ACCEPT = "accept"
    REJECT = "reject"
    DEFER = "defer"


@dataclass
class Decision:
    """One admission outcome.  ``retry_at`` is set iff deferred."""

    verdict: Verdict
    reason: str = ""
    retry_at: Optional[float] = None

    @classmethod
    def accept(cls) -> "Decision":
        return cls(Verdict.ACCEPT)

    @classmethod
    def reject(cls, reason: str) -> "Decision":
        return cls(Verdict.REJECT, reason)

    @classmethod
    def defer(cls, reason: str, retry_at: float) -> "Decision":
        return cls(Verdict.DEFER, reason, retry_at)


class AdmissionController:
    """Accept / reject / defer per-tenant bursts against SLA deadlines.

    Knobs:

    ``slack``
        Projected-completion multiplier: a task whose mapped
        ``prediction.total`` exceeds ``deadline * slack`` is refused.
        ``slack=1.0`` admits only what the orchestrator projects to meet
        its deadline outright; ``>1`` tolerates optimistic projections
        (the prediction ignores future arrivals); ``float("inf")``
        disables the projection check (feasibility-only, see
        :func:`admit_all`).
    ``defer_delay`` / ``max_defers``
        A refused request is re-enqueued ``defer_delay`` seconds later
        instead of rejected, up to ``max_defers`` times per request.
        ``max_defers=0`` (default) rejects immediately.
    ``max_inflight``
        Global per-tenant concurrent-request cap, checked before mapping
        (a tenant's own ``TenantSpec.max_inflight`` overrides it).
    """

    def __init__(self, slack: float = 1.0, defer_delay: float = 0.0,
                 max_defers: int = 0,
                 max_inflight: Optional[int] = None) -> None:
        self.slack = float(slack)
        self.defer_delay = float(defer_delay)
        self.max_defers = int(max_defers)
        self.max_inflight = max_inflight

    def _back_off(self, req, now: float, reason: str) -> Decision:
        if self.defer_delay > 0.0 and req.defers < self.max_defers:
            return Decision.defer(reason, retry_at=now + self.defer_delay)
        return Decision.reject(reason)

    def pre_admit(self, req, now: float,
                  inflight: int) -> Optional[Decision]:
        """Quota gate before any mapping work is spent.  ``None`` means
        proceed to mapping; a Decision is a refusal."""
        cap = req.max_inflight if req.max_inflight is not None \
            else self.max_inflight
        if cap is not None and inflight >= cap:
            return self._back_off(req, now, "inflight_cap")
        return None

    def post_admit(self, req, results: Sequence, now: float) -> Decision:
        """Judge the mapped placement: ``results`` holds one
        ``MapResult`` (or ``None``) per task of the request, from
        ``map_pending(fallback=False)``."""
        if any(r is None for r in results):
            return self._back_off(req, now, "infeasible")
        if self.slack != float("inf"):
            for t, r in zip(req.tasks, results):
                if (t.deadline is not None
                        and r.prediction.total > t.deadline * self.slack):
                    return self._back_off(req, now, "projected_sla")
        return Decision.accept()


@dataclass
class AdaptiveWindow:
    """Overload-adaptive admission coalescing for ``ServeLoop``.

    Replaces a fixed ``batch_window`` with one that tracks *pressure*:
    when the loop is idle every arrival is admitted on its own instant
    (``min_window``, zero by default — no added queueing delay), and as
    either the in-flight queue depth or the last wave's worst projected
    slowdown rises toward its high-water mark the window widens linearly
    toward ``max_window`` — waves grow exactly when batch amortization
    pays and requests are waiting anyway.

    ``window(depth, proj)`` is a pure function of its inputs, so wave
    boundaries stay deterministic for a seeded arrival process.

    Knobs:

    ``max_window``
        Widest coalescing window (seconds), reached at/beyond a
        high-water mark.
    ``depth_hi``
        In-flight request count at which depth pressure alone saturates
        the window.
    ``proj_hi``
        Projected completion/deadline ratio at which slowdown pressure
        alone saturates the window (pressure starts at ratio 1.0 — a
        projection at its deadline).
    ``min_window``
        Window when idle (default 0.0 — per-arrival admission).
    """

    max_window: float
    depth_hi: int = 16
    proj_hi: float = 2.0
    min_window: float = 0.0

    def window(self, depth: int, proj: float) -> float:
        p_d = depth / self.depth_hi if self.depth_hi > 0 else 0.0
        p_s = ((proj - 1.0) / (self.proj_hi - 1.0)
               if self.proj_hi > 1.0 else 0.0)
        press = max(p_d, p_s, 0.0)
        if press <= 0.0:
            return self.min_window
        return self.min_window + (self.max_window - self.min_window) \
            * min(1.0, press)


def admit_all() -> AdmissionController:
    """Feasibility-only controller: admit everything the orchestrator can
    place at all, regardless of projected SLA."""
    return AdmissionController(slack=float("inf"))
