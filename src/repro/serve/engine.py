"""Serving engine: continuous batching over a fixed slot pool.

``ServeEngine`` keeps a (max_slots, max_len) KV cache; requests claim free
slots via the batch-first ``admit_many`` (all newly admitted prompts
prefill together, one decode step per prompt position across the wave),
then advance together in batched decode steps; finished slots are recycled
mid-flight (continuous batching).  The multi-tenant *placement* of engines
onto pod slices — with SLO-aware contention checks — is handled by the
H-EYE scheduling session (see examples/serve_fleet.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.model import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False


@dataclass
class SlotAdmission:
    """Outcome of one slot-claim pass: who got a slot, who hit slot
    exhaustion.  The shared report for ``admit`` and ``admit_many`` so
    an admission controller can treat both uniformly."""

    admitted: list[Request] = field(default_factory=list)
    rejected: list[Request] = field(default_factory=list)


class ServeEngine:
    def __init__(self, model: Model, params, max_slots: int = 4,
                 max_len: int = 128, cache_dtype=jnp.float32) -> None:
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = model.init_cache(max_slots, max_len, dtype=cache_dtype)
        self.free = list(range(max_slots))
        self.active: dict[int, Request] = {}
        self.pos = np.zeros(max_slots, np.int32)
        self._decode = jax.jit(model.decode_step)
        self._tokens_decoded = 0
        # slot-admission telemetry (shared by admit / admit_many)
        self.admitted_total = 0
        self.slot_rejections = 0
        self.last_admission: Optional[SlotAdmission] = None

    # -- slot management ------------------------------------------------------
    def _claim_slots(self, reqs: list[Request]) -> SlotAdmission:
        """The one slot-claim path: every admission route reports slot
        exhaustion through the same counters and ``last_admission``."""
        report = SlotAdmission()
        for req in reqs:
            if not self.free:
                report.rejected.append(req)
                continue
            req.slot = self.free.pop()
            self.active[req.slot] = req
            report.admitted.append(req)
        self.admitted_total += len(report.admitted)
        self.slot_rejections += len(report.rejected)
        self.last_admission = report
        return report

    def admit(self, req: Request) -> bool:
        """One-request shim over :meth:`admit_many`: same claim, prefill,
        and slot-exhaustion telemetry path (``last_admission`` /
        ``slot_rejections``), so a False return is observably identical
        to the request landing in ``admit_many``'s leftover set."""
        return bool(self.admit_many([req]))

    def admit_many(self, reqs: list[Request]) -> list[Request]:
        """Batch-first admission: claim free slots for as many requests as
        fit, then prefill *all* claimed slots together — one decode step
        per prompt position across the batch instead of one per token per
        request (mirrors the scheduler's frontier batching).  Returns the
        admitted requests; the rest stay with the caller (and are listed
        in ``last_admission.rejected``)."""
        admitted = self._claim_slots(reqs).admitted
        if not admitted:
            return admitted
        last: dict[int, np.ndarray] = {}
        for t in range(max(len(r.prompt) for r in admitted)):
            toks = np.zeros((self.max_slots, 1), np.int32)
            poss = self.pos.copy()
            stepped = [r for r in admitted if t < len(r.prompt)]
            for r in stepped:
                toks[r.slot, 0] = int(r.prompt[t])
                poss[r.slot] = t
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks),
                                              jnp.asarray(poss))
            self._tokens_decoded += len(stepped)
            logits = np.asarray(logits)
            for r in stepped:
                if t == len(r.prompt) - 1:
                    last[r.slot] = logits[r.slot]
        for r in admitted:
            self.pos[r.slot] = len(r.prompt)
            r.out.append(int(np.argmax(last[r.slot])))
        return admitted

    # -- batched decode ------------------------------------------------------
    def step(self) -> list[Request]:
        """One decode step for every active slot; returns finished requests."""
        if not self.active:
            return []
        toks = np.zeros((self.max_slots, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        logits = np.asarray(logits)
        finished = []
        for slot, req in list(self.active.items()):
            self.pos[slot] += 1
            req.out.append(int(np.argmax(logits[slot])))
            self._tokens_decoded += 1
            if (len(req.out) >= req.max_new
                    or self.pos[slot] >= self.max_len - 1):
                req.done = True
                finished.append(req)
                del self.active[slot]
                self.free.append(slot)
                self.pos[slot] = 0
        return finished

    def run(self, requests: list[Request]) -> list[Request]:
        """Continuous batching: admit whenever slots free up, in one
        batched prefill per admission wave."""
        pending = list(requests)
        done: list[Request] = []
        while pending or self.active:
            if pending and self.free:
                admitted = self.admit_many(pending[:len(self.free)])
                del pending[:len(admitted)]
            done.extend(self.step())
        return done
