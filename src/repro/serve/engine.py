"""Serving engine: continuous batching over a fixed slot pool.

``ServeEngine`` keeps a (max_slots, max_len) KV cache; requests claim free
slots, are prefillled (per-request), then advance together in batched decode
steps; finished slots are recycled mid-flight (continuous batching).  The
multi-tenant *placement* of engines onto pod slices — with SLO-aware
contention checks — is handled by the H-EYE Orchestrator (see
examples/serve_fleet.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.model import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, max_slots: int = 4,
                 max_len: int = 128, cache_dtype=jnp.float32) -> None:
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = model.init_cache(max_slots, max_len, dtype=cache_dtype)
        self.free = list(range(max_slots))
        self.active: dict[int, Request] = {}
        self.pos = np.zeros(max_slots, np.int32)
        self._decode = jax.jit(model.decode_step)
        self._tokens_decoded = 0

    # -- slot management ------------------------------------------------------
    def admit(self, req: Request) -> bool:
        if not self.free:
            return False
        req.slot = self.free.pop()
        self.active[req.slot] = req
        # per-request prefill: feed prompt tokens through decode steps for the
        # claimed slot (batched single-token steps keep the cache layout
        # uniform across slots; bulk prefill is an optimization knob)
        for t, tok in enumerate(req.prompt):
            logits = self._step_slot(req.slot, int(tok), t)
        self.pos[req.slot] = len(req.prompt)
        req.out.append(int(np.argmax(logits)))
        return True

    def _step_slot(self, slot: int, token: int, position: int):
        toks = np.zeros((self.max_slots, 1), np.int32)
        poss = self.pos.copy()
        toks[slot, 0] = token
        poss[slot] = position
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks), jnp.asarray(poss))
        self._tokens_decoded += 1
        return np.asarray(logits[slot])

    # -- batched decode ------------------------------------------------------
    def step(self) -> list[Request]:
        """One decode step for every active slot; returns finished requests."""
        if not self.active:
            return []
        toks = np.zeros((self.max_slots, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        logits = np.asarray(logits)
        finished = []
        for slot, req in list(self.active.items()):
            self.pos[slot] += 1
            req.out.append(int(np.argmax(logits[slot])))
            self._tokens_decoded += 1
            if (len(req.out) >= req.max_new
                    or self.pos[slot] >= self.max_len - 1):
                req.done = True
                finished.append(req)
                del self.active[slot]
                self.free.append(slot)
                self.pos[slot] = 0
        return finished

    def run(self, requests: list[Request]) -> list[Request]:
        """Continuous batching: admit whenever a slot frees up."""
        pending = list(requests)
        done: list[Request] = []
        while pending or self.active:
            while pending and self.free:
                self.admit(pending.pop(0))
            done.extend(self.step())
        return done
