from . import ops, ref
