"""Pallas kernels + pure-jnp references.

Compat shim: JAX renamed ``pltpu.TPUCompilerParams`` to
``pltpu.CompilerParams`` across 0.4.x releases.  The kernels in this
package use the new spelling; on installs that only ship the old one
(e.g. 0.4.37) we alias it here so both spellings work.  This runs before
any kernel module is imported (importing a submodule triggers this
package ``__init__`` first), so every ``pltpu.CompilerParams(...)`` call
site resolves regardless of the installed JAX.
"""
from jax.experimental.pallas import tpu as _pltpu

if not hasattr(_pltpu, "CompilerParams"):        # old JAX, new spelling used
    _pltpu.CompilerParams = _pltpu.TPUCompilerParams
if not hasattr(_pltpu, "TPUCompilerParams"):     # new JAX, old spelling used
    _pltpu.TPUCompilerParams = _pltpu.CompilerParams

from . import ops, ref, slowdown_kernel, timeline_kernel
