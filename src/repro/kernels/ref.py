"""Pure-jnp oracles for every Pallas kernel (the ground truth that
interpret-mode kernel sweeps assert against)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0 ** 30


def slowdown_factors_ref(x, beta, mem, mt_term, kappa: float) -> np.ndarray:
    """NumPy oracle for kernels/slowdown_kernel.py (H-EYE §3.4).

    factors[i] = max(1, (1 + mt_term[i])
                        * prod_r(1 + beta[r]*x[i,r]*(1+kappa*x[i,r]) * mem[i]))

    ``x``: (N, R) per-rclass co-runner pressure; ``beta``: (R,) resource
    sensitivities; ``mem``: (N,) the task's own effective memory usage;
    ``mt_term``: (N,) the precomputed multi-tenancy pressure term."""
    x = np.asarray(x, dtype=np.float64)
    beta = np.asarray(beta, dtype=np.float64)
    mem = np.asarray(mem, dtype=np.float64)
    mt_term = np.asarray(mt_term, dtype=np.float64)
    term = np.where((x > 0.0) & (beta[None, :] > 0.0),
                    beta[None, :] * x * (1.0 + kappa * x), 0.0)
    return np.maximum(1.0, (1.0 + mt_term)
                      * np.prod(1.0 + term * mem[:, None], axis=-1))


def rate_advance_ref(W, rate, t_last, now: float
                     ) -> tuple[np.ndarray, np.ndarray]:
    """NumPy oracle for the DES rate-advance kernel
    (kernels/timeline_kernel.py): settle virtual work to ``now`` and
    project completion times.

    ``W2 = max(0, W - rate*(now - t_last))`` with nan residues clamped
    to zero (the scalar seed's ``max(0.0, nan)`` behaviour), and
    ``eta = now + W2/rate`` where ``rate > 0``, +inf otherwise."""
    W = np.asarray(W, dtype=np.float64)
    rate = np.asarray(rate, dtype=np.float64)
    t_last = np.asarray(t_last, dtype=np.float64)
    with np.errstate(invalid="ignore"):      # inf-rate x zero-dt corner
        raw = W - rate * (now - t_last)
    W2 = np.maximum(0.0, raw)
    nan = np.isnan(raw)
    if nan.any():
        W2 = W2.copy()
        W2[nan] = 0.0
    eta = np.divide(W2, rate, out=np.full(W2.shape, np.inf),
                    where=rate > 0.0)
    eta += now
    return W2, eta


def segment_min_ref(values, counts) -> np.ndarray:
    """NumPy oracle for the DES segment-min kernel: per-segment min of
    ``values`` split into consecutive runs of ``counts[i]`` elements
    (a transfer's bottleneck bandwidth over its route edges).  Empty
    segments yield +inf — an edgeless transfer is latency-only."""
    values = np.asarray(values, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.int64)
    out = np.full(len(counts), np.inf)
    nz = counts > 0
    if nz.any():
        starts = np.cumsum(counts) - counts
        out[nz] = np.minimum.reduceat(values, starts[nz])
    return out


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jax.Array:
    """Naive masked attention.  q: (B,S,Hq,hd); k,v: (B,S,Hkv,hd); GQA by
    head repetition.  All math fp32."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) / math.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)
    kpos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return o.astype(q.dtype)


def lru_scan_ref(a: jax.Array, b: jax.Array,
                 h0: Optional[jax.Array] = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a, b: (B, S, W) fp32."""
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                    jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def wkv_ref(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
            u: jax.Array, state0: Optional[jax.Array] = None):
    """Naive per-token RWKV6 WKV recurrence (fp32).

    r,k,v,log_w: (B,S,H,hd); u: (H,hd).
    S_t = diag(w_t) S_{t-1} + k_t v_t^T;  o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    """
    B, S, H, hd = r.shape
    f32 = jnp.float32
    if state0 is None:
        state0 = jnp.zeros((B, H, hd, hd), f32)

    def step(Sm, inp):
        rt, kt, vt, lw = (x.astype(f32) for x in inp)
        w = jnp.exp(lw)
        o = jnp.einsum("bhk,bhkv->bhv", rt, Sm)
        bonus = jnp.einsum("bhk,hk,bhk->bh", rt, u.astype(f32), kt)
        o = o + bonus[..., None] * vt
        S1 = Sm * w[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S1, o

    seq = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, log_w))
    state, outs = jax.lax.scan(step, state0, seq)
    return jnp.moveaxis(outs, 0, 1), state
