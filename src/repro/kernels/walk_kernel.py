"""Kernel for the fused Alg. 1 subtree-scan accounting reduce.

The wave-batched orchestrator walk (core/orchestrator.py) lowers each
hierarchical frontier expansion to arrays over a *scan plan* — the
CSR-style preorder of one ORC subtree: per node its subtree PU range
``[pu_lo, pu_hi)``, own leaf count, child count, summed hop cost to its
children and depth below the scan root.  Given the fused constraint
check's ``ok``/``key`` vectors over the plan's PU order, the whole
recursive TraverseChildren replay collapses to one reduce:

    feas[n]  = any(ok[pu_lo[n]:pu_hi[n]])          (alive-subtree mask)
    winner   = argmin(key where ok)                 (first-wins, preorder)
    queries  = sum(leafcnt[feas])
    hops     = sum(nchild[feas])
    overhead = sum(hopsum[feas] + lqc*leafcnt[feas]*(depth[feas]+1))

The closed forms follow from Alg. 1's accounting recursion because a
feasible node's ancestors are feasible by construction (its witness PU
sits in every enclosing subtree range).  ``queries``/``hops`` are exact
integer sums; ``overhead`` may differ from the Python oracle's nested
accumulation order by float-associativity ulps (tests pin it at 1e-9,
and the pu/score decisions never read it).

Dispatch mirrors the other kernels: the numpy reference is the oracle
and the CPU path; ``REPRO_WALK_KERNEL`` selects ``ref`` | ``jax`` |
``auto`` (auto takes the jitted path only on an accelerator backend —
for a reduce this size, XLA on CPU would lose to numpy on dispatch
overhead alone).  The jax path is jitted over the static plan shapes,
so repeated scans of one plan reuse the compiled reduce.
"""
from __future__ import annotations

import os
from typing import Tuple

import numpy as np

__all__ = ["scan_reduce", "scan_reduce_batch", "scan_reduce_ref"]


def scan_reduce_ref(ok: np.ndarray, key: np.ndarray, pu_lo: np.ndarray,
                    pu_hi: np.ndarray, leafcnt: np.ndarray,
                    nchild: np.ndarray, hopsum: np.ndarray,
                    depth: np.ndarray, lqc: float,
                    ) -> Tuple[int, int, int, float]:
    """Numpy reference: (winner_pos, queries, hops, overhead).

    ``winner_pos`` is -1 when no PU in the scan is feasible (the scan
    root returns None); ties on ``key`` resolve to the first feasible
    position in plan (preorder) order, matching ``min()`` first-wins."""
    if len(ok) < 128:
        # scalar path: device-level scans are a handful of PUs, where
        # per-call numpy dispatch dwarfs the math.  Bit-identical to the
        # array path — numpy's pairwise summation is sequential below its
        # 128-element block size, so the Python running sums accumulate
        # in the same order
        okl = ok.tolist()
        keyl = key.tolist()
        if not any(okl[int(pu_lo[0]):int(pu_hi[0])]):
            return -1, 0, 0, 0.0
        w = -1
        best = 0.0
        for i, o in enumerate(okl):
            if o and (w < 0 or keyl[i] < best):
                w = i
                best = keyl[i]
        queries = 0
        hops = 0
        overhead = 0.0
        lol = pu_lo.tolist()
        hil = pu_hi.tolist()
        lcl = leafcnt.tolist()
        ncl = nchild.tolist()
        hsl = hopsum.tolist()
        dpl = depth.tolist()
        for nidx in range(len(lol)):
            lo, hi = lol[nidx], hil[nidx]
            if not any(okl[lo:hi]):
                continue
            queries += lcl[nidx]
            hops += ncl[nidx]
            overhead += hsl[nidx] + lqc * lcl[nidx] * (dpl[nidx] + 1.0)
        return w, queries, hops, overhead
    cs = np.zeros(len(ok) + 1, dtype=np.int64)
    np.cumsum(ok, out=cs[1:])
    feas = cs[pu_hi] > cs[pu_lo]
    if not feas[0]:
        return -1, 0, 0, 0.0
    # argmin over feasible rows only: with no deadline every feasible key
    # may be inf (unroutable comm), and the winner must still be feasible
    ok_idx = np.flatnonzero(ok)
    w = int(ok_idx[np.argmin(key[ok_idx])])
    queries = int(leafcnt[feas].sum())
    hops = int(nchild[feas].sum())
    overhead = float((hopsum[feas]
                      + lqc * leafcnt[feas] * (depth[feas] + 1.0)).sum())
    return w, queries, hops, overhead


def _jax_reduce_raw():
    import jax.numpy as jnp

    def reduce(ok, key, pu_lo, pu_hi, leafcnt, nchild, hopsum, depth, lqc):
        cs = jnp.concatenate([jnp.zeros(1, jnp.int64),
                              jnp.cumsum(ok.astype(jnp.int64))])
        feas = cs[pu_hi] > cs[pu_lo]
        # first feasible index attaining the feasible-row minimum (inf-safe)
        masked = jnp.where(ok, key, jnp.inf)
        kmin = jnp.min(masked)
        w = jnp.where(feas[0],
                      jnp.argmax(ok & ((masked == kmin) | ~jnp.isfinite(kmin))),
                      -1)
        queries = jnp.sum(jnp.where(feas, leafcnt, 0))
        hops = jnp.sum(jnp.where(feas, nchild, 0))
        overhead = jnp.sum(jnp.where(
            feas, hopsum + lqc * leafcnt * (depth + 1.0), 0.0))
        return w, queries, hops, overhead

    return reduce


def _jax_reduce():
    import jax

    return jax.jit(_jax_reduce_raw())


_JAX_REDUCE = None
_AUTO_JAX = None                          # memoized auto-mode probe


def _use_jax() -> bool:
    mode = os.environ.get("REPRO_WALK_KERNEL", "auto")
    if mode == "ref":
        return False
    if mode == "jax":
        return True
    # the backend cannot change mid-process: probe jax once, then the
    # auto path costs one env read per call
    global _AUTO_JAX
    if _AUTO_JAX is None:
        try:
            import jax
            _AUTO_JAX = jax.default_backend() not in ("cpu",)
        except Exception:                 # pragma: no cover - no jax
            _AUTO_JAX = False
    return _AUTO_JAX


def scan_reduce(ok, key, pu_lo, pu_hi, leafcnt, nchild, hopsum, depth,
                lqc: float) -> Tuple[int, int, int, float]:
    """Dispatching entry: numpy ref on CPU, jitted reduce on accelerators
    (or when forced via ``REPRO_WALK_KERNEL=jax``)."""
    if _use_jax():
        global _JAX_REDUCE
        if _JAX_REDUCE is None:
            _JAX_REDUCE = _jax_reduce()
        w, q, h, ov = _JAX_REDUCE(ok, key, pu_lo, pu_hi, leafcnt, nchild,
                                  hopsum, depth, lqc)
        return int(w), int(q), int(h), float(ov)
    return scan_reduce_ref(ok, key, pu_lo, pu_hi, leafcnt, nchild,
                           hopsum, depth, lqc)


_JAX_REDUCE_BATCH = None


def scan_reduce_batch(ok, key, pu_lo, pu_hi, leafcnt, nchild, hopsum,
                      depth, lqc: float,
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Reduce a stack of same-shape scans in one call.

    All array inputs are 2-D with one scan per row (``ok``/``key`` over
    each row's plan PU order, the remaining five over its plan nodes);
    ``lqc`` is shared.  Returns per-row ``(winners, queries, hops,
    overheads)`` arrays; ``winners[i] == -1`` marks an infeasible row.

    The numpy path loops :func:`scan_reduce_ref` per row — bit-identical
    to the unbatched calls by construction.  The jax path vmaps the
    jitted reduce over the stack (one fused dispatch for the whole
    group of scans), used where the sharded walk driver stacks
    same-shape group slices."""
    if _use_jax():
        global _JAX_REDUCE_BATCH
        if _JAX_REDUCE_BATCH is None:
            import jax
            _JAX_REDUCE_BATCH = jax.jit(jax.vmap(
                _jax_reduce_raw(),
                in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None)))
        w, q, h, ov = _JAX_REDUCE_BATCH(ok, key, pu_lo, pu_hi, leafcnt,
                                        nchild, hopsum, depth, lqc)
        return (np.asarray(w, dtype=np.int64),
                np.asarray(q, dtype=np.int64),
                np.asarray(h, dtype=np.int64),
                np.asarray(ov, dtype=np.float64))
    n = len(ok)
    winners = np.empty(n, dtype=np.int64)
    queries = np.empty(n, dtype=np.int64)
    hops = np.empty(n, dtype=np.int64)
    overheads = np.empty(n, dtype=np.float64)
    for i in range(n):
        winners[i], queries[i], hops[i], overheads[i] = scan_reduce_ref(
            ok[i], key[i], pu_lo[i], pu_hi[i], leafcnt[i], nchild[i],
            hopsum[i], depth[i], lqc)
    return winners, queries, hops, overheads
