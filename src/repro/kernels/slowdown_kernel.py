"""Pallas kernel for the batched slowdown factor-aggregation inner loop.

The vectorized slowdown model (core/slowdown.py) reduces every co-run
pool to dense per-rclass pressure arrays; the remaining inner loop is a
pure map over pool members:

    factor[i] = max(1, (1 + mt_term[i])
                       * prod_r(1 + beta[r]*x[i,r]*(1+kappa*x[i,r]) * mem[i]))

On a TPU backend this lowers natively (rows tile the sublanes, the tiny
rclass axis pads the lanes).  Everywhere else ``slowdown_factors``
selects the numpy reference (``ref.slowdown_factors_ref``) — the same
``on_tpu`` switch the other kernels use, except that here the CPU
fallback is the oracle itself rather than interpret mode: this runs per
contention interval inside the DES hot loop, where interpret-mode
execution would defeat the point of the batching.  Interpret mode stays
available through ``slowdown_factors_pallas(interpret=True)`` for the
parity tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

_LANES = 128


def _factors_kernel(x_ref, beta_ref, mem_ref, mt_ref, o_ref, *, kappa):
    x = x_ref[...].astype(jnp.float32)           # (bn, R)
    beta = beta_ref[...].astype(jnp.float32)     # (1, R)
    mem = mem_ref[...].astype(jnp.float32)       # (bn, 1)
    mt = mt_ref[...].astype(jnp.float32)         # (bn, 1)
    term = jnp.where((x > 0.0) & (beta > 0.0),
                     beta * x * (1.0 + kappa * x), 0.0)
    f = (1.0 + mt) * jnp.prod(1.0 + term * mem, axis=-1, keepdims=True)
    o_ref[...] = jnp.maximum(f, 1.0)


def slowdown_factors_pallas(x: jax.Array, beta: jax.Array, mem: jax.Array,
                            mt_term: jax.Array, kappa: float, *,
                            block_n: int = 256,
                            interpret: Optional[bool] = None) -> jax.Array:
    """(N, R) pressures -> (N,) factors via pl.pallas_call."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x = jnp.asarray(x, jnp.float32)
    N, R = x.shape
    pad_r = (-R) % _LANES
    bn = min(block_n, max(N, 1))
    pad_n = (-N) % bn
    # zero rclass padding contributes a factor-term of exactly 1.0; padded
    # rows are dropped after the call
    xp = jnp.pad(x, ((0, pad_n), (0, pad_r)))
    betap = jnp.pad(jnp.asarray(beta, jnp.float32), (0, pad_r))[None, :]
    memp = jnp.pad(jnp.asarray(mem, jnp.float32), (0, pad_n))[:, None]
    mtp = jnp.pad(jnp.asarray(mt_term, jnp.float32), (0, pad_n))[:, None]
    Np, Rp = N + pad_n, R + pad_r
    out = pl.pallas_call(
        functools.partial(_factors_kernel, kappa=kappa),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, Rp), lambda i: (i, 0)),
            pl.BlockSpec((1, Rp), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 1), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp, betap, memp, mtp)
    return out[:N, 0]


def slowdown_factors(x, beta, mem, mt_term, kappa: float) -> np.ndarray:
    """Backend-selected batched factor aggregation.

    TPU: Pallas kernel (native lowering).  CPU/GPU: the numpy reference —
    bit-identical formula, no interpret-mode overhead in the DES hot loop.
    """
    if jax.default_backend() == "tpu":
        return np.asarray(slowdown_factors_pallas(x, beta, mem, mt_term,
                                                  kappa, interpret=False),
                          dtype=np.float64)
    return ref.slowdown_factors_ref(x, beta, mem, mt_term, kappa)
