"""jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels lower natively; elsewhere (this container is
CPU-only) they run in ``interpret=True`` mode, which executes the kernel
body in Python — bit-accurate for validation against ref.py, not for speed.
"""
from __future__ import annotations

from typing import Optional

import jax

from .flash_attention import flash_attention as _flash
from .lru_scan import lru_scan_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None):
    """(B,S,Hq,hd) attention; GQA via Hkv | Hq; see flash_attention.py."""
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=interpret)


def lru_scan(a, b, *, block_s: int = 256, block_w: int = 512,
             interpret: Optional[bool] = None):
    """h_t = a_t * h_{t-1} + b_t  over (B, S, W)."""
    if interpret is None:
        interpret = not on_tpu()
    # pad W to a block multiple if needed (lanes want 128-multiples on TPU)
    B, S, W = a.shape
    bw = min(block_w, W)
    pad = (-W) % bw
    if pad:
        import jax.numpy as jnp
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    out = lru_scan_pallas(a, b, block_s=bs, block_w=bw, interpret=interpret)
    return out[..., :W] if pad else out
