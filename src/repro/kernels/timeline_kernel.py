"""Pallas kernels for the DES timeline engine's two batched inner loops.

The array-native ``core.timeline.TimelineEngine`` reduces every
contention-interval flush to two data-parallel primitives:

* **rate-advance** — settle each job's remaining virtual work to the
  shared timestamp and project its completion:
  ``W' = max(0, W - rate*(now - t_last))``, ``eta = now + W'/rate``
  (+inf where the rate is non-positive; nan residues — the
  ``inf * 0`` corner of infinite-bandwidth transfers — clamp to 0,
  matching the scalar seed's ``max(0.0, nan)``).
* **segment-min** — a transfer's bottleneck bandwidth is the min of its
  route edges' fair shares; the flush evaluates the whole dirty set as
  one segmented reduction.  The kernel takes the dense padded form
  ``(S, Emax)`` (+inf padding), which the wrapper builds from the CSR
  (values, counts) layout the engine keeps.

On a TPU backend both lower natively (rows tile the sublanes, the tiny
edge axis pads the lanes).  The engine itself defaults to its float64
numpy settles on *every* backend — its parity contract against the
seed event loop is a hard 1e-9 bound the fp32 kernels cannot
guarantee, and the per-flush batches are memory-bound — so these
kernels are the opt-in path for TPU-resident pipelines
(``REPRO_TIMELINE_KERNEL=pallas`` routes the engine through the
``*_forced`` variants, interpret-mode off-TPU; the ``rate_advance`` /
``segment_min`` entry points below backend-select for direct callers).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

_LANES = 128


# ---------------------------------------------------------------------------
# rate-advance: elementwise settle + completion projection
# ---------------------------------------------------------------------------
def _rate_advance_kernel(w_ref, r_ref, t_ref, o_w_ref, o_e_ref, *, now):
    W = w_ref[...].astype(jnp.float32)
    rate = r_ref[...].astype(jnp.float32)
    t_last = t_ref[...].astype(jnp.float32)
    raw = W - rate * (now - t_last)
    W2 = jnp.maximum(0.0, raw)
    W2 = jnp.where(jnp.isnan(raw), 0.0, W2)
    eta = jnp.where(rate > 0.0, now + W2 / rate, jnp.inf)
    o_w_ref[...] = W2
    o_e_ref[...] = eta


def rate_advance_pallas(W, rate, t_last, now: float, *,
                        block_n: int = 1024,
                        interpret: Optional[bool] = None):
    """(N,) settle via pl.pallas_call; returns (W', eta) as numpy."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    W = jnp.asarray(W, jnp.float32)
    N = W.shape[0]
    if N == 0:
        return np.zeros(0), np.zeros(0)
    cols = min(_LANES, max(N, 1))
    pad = (-N) % cols
    rows = (N + pad) // cols
    bn = min(block_n // _LANES if cols == _LANES else 1, rows) or 1

    def shape2d(x):
        return jnp.pad(jnp.asarray(x, jnp.float32), (0, pad),
                       constant_values=1.0).reshape(rows, cols)

    Wp = jnp.pad(W, (0, pad)).reshape(rows, cols)
    rp = shape2d(rate)               # pad rate=1: no div-by-zero lanes
    tp = shape2d(t_last)
    grid = ((rows + bn - 1) // bn,)
    pad_rows = (-rows) % bn
    if pad_rows:
        Wp = jnp.pad(Wp, ((0, pad_rows), (0, 0)))
        rp = jnp.pad(rp, ((0, pad_rows), (0, 0)), constant_values=1.0)
        tp = jnp.pad(tp, ((0, pad_rows), (0, 0)))
        grid = ((rows + pad_rows) // bn,)
    out_w, out_e = pl.pallas_call(
        functools.partial(_rate_advance_kernel, now=now),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, cols), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((bn, cols), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(Wp.shape, jnp.float32)] * 2,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(Wp, rp, tp)
    return (np.asarray(out_w, np.float64).reshape(-1)[:N],
            np.asarray(out_e, np.float64).reshape(-1)[:N])


# ---------------------------------------------------------------------------
# segment-min: per-transfer bottleneck over padded route-edge shares
# ---------------------------------------------------------------------------
def _segment_min_kernel(v_ref, o_ref):
    o_ref[...] = jnp.min(v_ref[...], axis=-1, keepdims=True)


def segment_min_pallas(values, counts, *, block_s: int = 256,
                       interpret: Optional[bool] = None) -> np.ndarray:
    """CSR (values, counts) -> per-segment min via a dense padded row
    reduction (route lists are short: Emax is single-digit)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    counts = np.asarray(counts, dtype=np.int64)
    S = len(counts)
    if S == 0:
        return np.zeros(0)
    emax = int(counts.max()) if S else 0
    if emax == 0:
        return np.full(S, np.inf)
    dense = np.full((S, emax), np.inf, dtype=np.float32)
    starts = np.cumsum(counts) - counts
    vals = np.asarray(values, dtype=np.float32)
    within = np.arange(int(counts.sum())) - np.repeat(starts, counts)
    rows = np.repeat(np.arange(S), counts)
    dense[rows, within] = vals
    pad_e = (-emax) % _LANES
    bs = min(block_s, S)
    pad_s = (-S) % bs
    dp = jnp.pad(jnp.asarray(dense), ((0, pad_s), (0, pad_e)),
                 constant_values=np.inf)
    out = pl.pallas_call(
        _segment_min_kernel,
        grid=((S + pad_s) // bs,),
        in_specs=[pl.BlockSpec((bs, emax + pad_e), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S + pad_s, 1), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(dp)
    return np.asarray(out, np.float64)[:S, 0]


# ---------------------------------------------------------------------------
# backend-selected entry points (the engine's dispatch targets)
# ---------------------------------------------------------------------------
def rate_advance(W, rate, t_last, now: float):
    """TPU: Pallas kernel.  CPU/GPU: the float64 numpy reference (the
    DES parity bound requires float64; no interpret-mode overhead)."""
    if jax.default_backend() == "tpu":
        return rate_advance_pallas(W, rate, t_last, now, interpret=False)
    return ref.rate_advance_ref(W, rate, t_last, now)


def segment_min(values, counts):
    if jax.default_backend() == "tpu":
        return segment_min_pallas(values, counts, interpret=False)
    return ref.segment_min_ref(values, counts)


def rate_advance_forced(W, rate, t_last, now: float):
    """Always the Pallas kernel (interpret off-TPU) — parity testing."""
    return rate_advance_pallas(W, rate, t_last, now)


def segment_min_forced(values, counts):
    return segment_min_pallas(values, counts)
