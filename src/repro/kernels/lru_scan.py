"""Chunked linear-recurrence scan (h_t = a_t * h_{t-1} + b_t) as a Pallas
TPU kernel — the compute core of RG-LRU (and any diagonal SSM).

TPU-native design: the recurrence is sequential in t but embarrassingly
parallel across channels and batch, so:

* grid = (B, n_w_blocks, n_s_chunks); the time-chunk dimension is innermost
  and sequential ("arbitrary"), carrying the hidden state h in VMEM scratch
  across chunks.
* within a chunk the kernel walks ``bs`` steps with a fori_loop; each step
  is a fused multiply-add over a (1, bw) vector — lane-parallel on the VPU.
* channel blocks (bw = 512 lanes) and time chunks (bs = 256) keep the
  working set (2 * bs * bw * 4B = 1 MB) comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, o_ref, h_ref, *, bs: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        at = a_ref[0, t, :]                     # (bw,)
        bt = b_ref[0, t, :]
        h = at * h + bt
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = lax.fori_loop(0, bs, step, h_ref[0])
    h_ref[0] = h


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def lru_scan_pallas(a: jax.Array, b: jax.Array, *, block_s: int = 256,
                    block_w: int = 512, interpret: bool = False) -> jax.Array:
    """a, b: (B, S, W) fp32 -> h: (B, S, W) fp32."""
    B, S, W = a.shape
    bs = min(block_s, S)
    bw = min(block_w, W)
    if S % bs or W % bw:
        raise ValueError(f"S={S}, W={W} must divide blocks ({bs},{bw})")
    ns, nw = S // bs, W // bw
    kernel = functools.partial(_lru_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
