"""Flash attention as a Pallas TPU kernel.

TPU-native design (HBM -> VMEM tiling via BlockSpec, MXU-aligned tiles):

* grid = (B*Hq, n_q_blocks, n_kv_blocks); the kv dimension is innermost and
  sequential ("arbitrary"), carrying the online-softmax state (m, l, acc) in
  VMEM scratch across kv steps — the classic flash recurrence.
* causal / sliding-window structure is exploited at *block* granularity:
  fully-masked kv blocks are skipped with ``pl.when`` (the jnp fallback
  cannot skip, so the kernel does ~2x less work on causal and O(S*w) on
  sliding windows).
* GQA is handled in the k/v BlockSpec index maps: q head -> kv head is a
  static integer division, so no k/v repetition is materialized.
* logit softcapping (gemma2) folds into the score block before the
  online-softmax update.

Block sizes default to (512, 512) — (8, 128)-lane aligned for f32/bf16 and
small enough that q,k,v,acc tiles fit VMEM (4 * 512 * hd * 4B ~= 2 MB at
hd=256, well under the ~16 MB/core budget with double buffering).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level structure: skip fully-masked kv blocks
    live = jnp.bool_(True)
    if causal:
        live = live & (k_start <= q_start + bq - 1)
    if window is not None:
        live = live & (k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                  # (bq, 1)... stored 2D
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret", "num_kv_heads"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         num_kv_heads: int,
                         causal: bool = True,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         block_q: int = 512, block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (B*Hq, S, hd); k, v: (B*Hkv, S, hd) -> (B*Hq, S, hd).

    Rows of q map to rows of k/v by static integer division (GQA).
    """
    BH, S, hd = q.shape
    Hkv_total = k.shape[0]
    rep = BH // Hkv_total
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq or S % bk:
        raise ValueError(f"S={S} must be divisible by block sizes {bq},{bk}")
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // rep, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """(B,S,Hq,hd) x (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    o = flash_attention_bhsd(qr, kr, vr, num_kv_heads=Hkv, causal=causal,
                             window=window, softcap=softcap,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return o.reshape(B, Hq, S, hd).transpose(0, 2, 1, 3)
