"""Placement search: H-EYE's predict -> check-constraints -> assign loop
applied to sharding-layout choice on a TPU fleet (the beyond-paper feature).

The paper's Orchestrator maps a Task onto a PU by querying a pluggable
``predict()`` and rejecting candidates that break constraints.  Here the
"task" is one training/serving step of an assigned architecture, the
"PUs" are candidate *layouts* (sharding policy x microbatching x remat x
optimizer dtype x cache sharding) on a fixed mesh, the constraint is HBM
capacity, and the objective is the predicted roofline step time.  The
prediction is the same three-term roofline the paper lists among its
supported model classes (core/predict.RooflineModel); the dry-run
(launch/dryrun.py) then *validates* the chosen plan by compiling it —
prediction vs. compiled reality is logged in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Optional

from .hwgraph import ProcessingUnit
from .predict import RooflineModel
from .task import Task
from .topology import TPU_V5E

HBM_BYTES = TPU_V5E["hbm_bytes"]
HBM_BUDGET = 0.90 * HBM_BYTES          # leave headroom for XLA scratch


@dataclass(frozen=True)
class Plan:
    """One candidate layout for a (arch x shape x mesh) cell."""

    policy: str = "tp_fsdp"            # param sharding (launch/sharding.py)
    microbatches: int = 1
    remat: str = "block"               # "none" | "block"
    state_dtype: str = "float32"       # optimizer m/v dtype
    param_dtype: str = "float32"       # master param dtype (bf16 = pure-bf16)
    accum_dtype: str = "float32"       # microbatch grad-accumulation dtype
    cache_mode: str = "batch"          # "batch" | "seq" | "heads"
    cache_dtype: str = "bfloat16"      # KV cache dtype (float8_e4m3fn = kv8)
    moe_group: int = 1024              # GShard dispatch group size
    notes: str = ""

    def describe(self) -> str:
        return (f"{self.policy}/mb{self.microbatches}/remat-{self.remat}"
                f"/opt-{self.state_dtype}/cache-{self.cache_mode}")


@dataclass
class PlanCost:
    """Analytic prediction for a Plan (all per-chip, seconds / bytes)."""

    mem_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    flops_chip: float
    coll_bytes_chip: float

    @property
    def t_step(self) -> float:
        # collectives overlap with compute at best; worst case serialize.
        # Use max(compute, memory) + 0.5*collective as the planner's blend.
        return max(self.t_compute, self.t_memory) + 0.5 * self.t_collective

    @property
    def fits(self) -> bool:
        return self.mem_bytes <= HBM_BUDGET


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------
def _param_shards(policy: str, dp: int, tp: int, pods: int) -> float:
    if policy in ("tp_fsdp", "tp_fsdp_moeff"):
        return dp * tp
    if policy == "fsdp_pod":
        return dp * tp * pods
    if policy == "tp_only":
        return tp
    if policy == "fsdp_only":
        return dp
    raise ValueError(policy)


def model_flops(cfg, tokens: float, mode: str) -> float:
    """MODEL_FLOPS per the assignment: 6*N*D train (2*N*D inference),
    N = active non-embedding params, + the unembed matmul."""
    n_act = cfg.active_param_count() - cfg.vocab * cfg.d_model * (
        1 if cfg.tie_embeddings else 2)
    fwd = 2.0 * n_act * tokens + 2.0 * tokens * cfg.d_model * cfg.vocab
    return 3.0 * fwd if mode == "train" else fwd


def cache_bytes_total(cfg, B: int, S: int, dtype_bytes: int = 2) -> float:
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.kind_of_layer(i)
        if kind == "global":
            total += 2 * B * S * cfg.n_kv * cfg.hd * dtype_bytes
        elif kind in ("local", "enc"):
            C = min(cfg.window, S)
            total += 2 * B * C * cfg.n_kv * cfg.hd * dtype_bytes
        elif kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            total += B * w * 4 + B * (cfg.conv1d_size - 1) * w * dtype_bytes
        elif kind == "rwkv":
            total += B * cfg.n_heads * cfg.hd * cfg.hd * 4
        if cfg.is_encdec and cfg.cross_attn and kind != "enc":
            total += 2 * B * cfg.src_seq * cfg.n_kv * cfg.hd * dtype_bytes
    return total


def predict_plan(cfg, shape, mesh_shape: tuple[int, ...],
                 mesh_axes: tuple[str, ...], plan: Plan) -> PlanCost:
    sizes = dict(zip(mesh_axes, mesh_shape))
    tp = sizes.get("model", 1)
    dp = sizes.get("data", 1)
    pods = sizes.get("pod", 1)
    n_chips = tp * dp * pods
    dp_total = dp * pods                       # batch shards over non-model axes

    B, S = shape.global_batch, shape.seq_len
    mode = shape.mode
    tokens = B * S if mode in ("train", "prefill") else B
    dtype_b = 2                                # bf16 compute
    N = cfg.param_count()
    pshards = _param_shards(plan.policy, dp, tp, pods)
    state_b = 4 if plan.state_dtype == "float32" else 2

    # ---- memory ----
    # superblock length P: remat=block checkpoints at superblock granularity,
    # so the backward peak holds P layers' intermediates simultaneously.
    P = len(cfg.layer_pattern)
    if cfg.n_experts > 0:
        P = P * cfg.moe_every // math.gcd(P, cfg.moe_every)
    P = min(P, cfg.n_layers)

    # TP policies shard the d_ff / head dims of intermediates over the model
    # axis (via ctx.shard constraints in the layer code).
    tp_act = tp if plan.policy in ("tp_fsdp", "tp_only", "fsdp_pod",
                                   "tp_fsdp_moeff") else 1

    def layer_stored(t_chip: float, backward: bool = True) -> float:
        """Bytes of live intermediates per layer (backward keeps more).
        Coefficients calibrated against compiled single-pod cells."""
        per = (2 * cfg.d_ff / tp_act + 10 * cfg.d_model
               + 2 * cfg.n_heads * cfg.hd / tp_act)
        if cfg.n_experts > 0:
            # einsum (GShard) dispatch one-hots: k slots x (E*C) entries per
            # token, experts sharded over the model axis.  Calibrated against
            # the compiled granite-moe cell (45 GB @ mb=1, g=64).
            EC = cfg.n_experts * math.ceil(
                plan.moe_group * cfg.capacity_factor * max(1, cfg.top_k)
                / cfg.n_experts)
            per += max(1, cfg.top_k) * EC * 2 / max(tp, 1)
        if backward:
            if "rglru" in cfg.layer_pattern:
                # associative_scan holds O(log S) fp32 (a,b) pairs in backward
                per += 15 * (cfg.lru_width or cfg.d_model)
            if "rwkv" in cfg.layer_pattern:
                # five fp32 projections + chunked-scan carries/outputs
                per += 4 * cfg.d_model
        return t_chip * per * dtype_b

    param_b = 4 if plan.param_dtype == "float32" else 2
    accum_b = 4 if plan.accum_dtype == "float32" else 2
    mem = float(param_b) * N / pshards         # master params
    if mode == "train":
        mem += 2.0 * state_b * N / pshards     # adam m, v
        mem += float(accum_b) * N / pshards    # grad accumulation buffer
        mb = max(1, plan.microbatches)
        t_chip = tokens / (mb * dp_total)      # tokens per chip per microbatch
        if plan.remat == "block":
            act = cfg.n_layers * t_chip * cfg.d_model * dtype_b   # residuals
            act += P * layer_stored(t_chip)    # recompute peak inside a block
        else:
            act = cfg.n_layers * layer_stored(t_chip)
        # fp32 logits + grad + softmax stats; vocab-TP only shards when the
        # vocab divides the model axis (odd vocabs replicate — pad to fix)
        tp_vocab = tp if cfg.vocab % tp == 0 else 1
        act += 3.0 * t_chip * cfg.vocab * 4 / tp_vocab
        # empirical calibration vs compiled cells: XLA (CPU-backend fusion,
        # scan double-buffers, fp32 norm saves) lands ~2.5x the naive count
        mem += 2.5 * act
    else:
        mem = 2.0 * N / pshards                # bf16 weights for serving
        cshards = 1.0
        if B % dp_total == 0:
            cshards *= dp_total
        # cache "ctp" roles shard over the model axis under EVERY policy
        if plan.cache_mode == "seq" and S % tp == 0:
            cshards *= tp
        elif plan.cache_mode == "heads" and cfg.n_kv % tp == 0:
            cshards *= tp
        mem += cache_bytes_total(cfg, B, S) / cshards
        t_chip = max(1.0, tokens / dp_total)
        if mode == "prefill":
            mem += 2.0 * layer_stored(t_chip, backward=False)  # live fwd set
        tp_vocab = tp if cfg.vocab % tp == 0 else 1
        # logits are computed for the last position only (B rows)
        mem += max(1.0, B / dp_total) * cfg.vocab * 4 / tp_vocab
        mem *= 1.15

    # ---- compute ----
    flops_total = model_flops(cfg, tokens, "train" if mode == "train" else "serve")
    flops_chip = flops_total / n_chips
    t_compute = flops_chip / TPU_V5E["peak_flops"]

    # ---- HBM traffic ----
    mb = max(1, plan.microbatches)
    if mode == "train":
        # params re-read per microbatch (fwd+bwd), opt state r/w once
        traffic = (2.0 * N / pshards) * 2 * mb + 4.0 * state_b * N / pshards
        traffic += tokens / dp_total * cfg.n_layers * cfg.d_model * dtype_b * 6
    else:
        traffic = 2.0 * N / pshards
        # per-step cache reads scale with the cache's shard count: seq/heads
        # modes spread the 32k cache over the model axis too (the gemma3-4b
        # decode hillclimb measured 10x on exactly this term)
        cache_shards = 1.0
        if B % dp_total == 0:
            cache_shards *= dp_total
        if plan.cache_mode == "seq" and S % tp == 0:
            cache_shards *= tp
        elif plan.cache_mode == "heads" and cfg.n_kv % tp == 0:
            cache_shards *= tp
        traffic += cache_bytes_total(cfg, B, S) / cache_shards
        traffic += tokens / dp_total * cfg.n_layers * cfg.d_model * dtype_b * 4
    t_memory = traffic / TPU_V5E["mem_bw"]

    # ---- collectives ----
    coll = 0.0
    t_tok = tokens / dp_total                  # tokens this chip processes
    if tp > 1:
        # per layer: all-reduce (or AG+RS pair) of the activation, fwd+bwd
        per_layer = 2.0 * t_tok * cfg.d_model * dtype_b * (tp - 1) / tp
        coll += per_layer * cfg.n_layers * (2 if mode == "train" else 1)
        if cfg.n_experts > 0:
            coll += 2.0 * t_tok * cfg.d_model * dtype_b * (
                sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i)))
    if mode == "train":
        if plan.policy in ("tp_fsdp", "fsdp_only", "fsdp_pod"):
            shard_n = dp_total if plan.policy == "fsdp_pod" else dp
            ag = 2.0 * N / tp * (shard_n - 1) / shard_n
            coll += ag * (mb + 1)              # re-gather per microbatch + bwd
            coll += 2.0 * ag                   # grad reduce-scatter (fp32->2x)
        else:
            coll += 2.0 * 4.0 * N / tp * (dp_total - 1) / dp_total  # grad AR
    t_collective = coll / TPU_V5E["link_bw"]

    return PlanCost(mem_bytes=mem, t_compute=t_compute, t_memory=t_memory,
                    t_collective=t_collective, flops_chip=flops_chip,
                    coll_bytes_chip=coll)


# ---------------------------------------------------------------------------
# the H-EYE loop over candidate layouts
# ---------------------------------------------------------------------------
def candidate_plans(cfg, shape) -> list[Plan]:
    out: list[Plan] = []
    if shape.mode == "train":
        moe_groups = [256] if cfg.n_experts >= 64 else (
            [64] if cfg.n_experts else [1024])
        # dtype regimes, most conservative first: fp32 master everywhere ->
        # low-precision optimizer -> pure-bf16 (master+accum+state bf16; the
        # documented escape hatch for 400B-class models on a 4 TB pod).
        regimes = [("float32", "float32", "float32"),
                   ("float32", "bfloat16", "float32"),
                   ("float32", "bfloat16", "bfloat16"),
                   ("bfloat16", "bfloat16", "bfloat16")]
        for policy in ("tp_fsdp", "fsdp_pod"):
            for mb in (1, 2, 4, 8, 16, 32):
                if shape.global_batch % mb:
                    continue
                for remat in ("block", "none"):
                    for pdt, sdt, adt in regimes:
                        for g in moe_groups:
                            out.append(Plan(policy=policy, microbatches=mb,
                                            remat=remat, state_dtype=sdt,
                                            param_dtype=pdt, accum_dtype=adt,
                                            moe_group=g))
    else:
        moe_g = 64 if cfg.n_experts else 1024
        for policy in ("tp_only", "fsdp_only", "tp_fsdp"):
            for cache in ("batch", "seq", "heads"):
                out.append(Plan(policy=policy, microbatches=1, remat="none",
                                cache_mode=cache, moe_group=moe_g))
    return out


def choose_plan(cfg, shape, mesh_shape: tuple[int, ...],
                mesh_axes: tuple[str, ...],
                chip: Optional[ProcessingUnit] = None) -> tuple[Plan, PlanCost]:
    """H-EYE's Alg.1 pattern over layouts: predict each candidate, reject the
    ones whose memory constraint fails, pick the best objective.  ``chip``
    (a ProcessingUnit from core.topology.build_tpu_fleet) carries the HW
    attrs; its RooflineModel is the pluggable predict() of the paper."""
    model = RooflineModel()
    feasible: list[tuple[Plan, PlanCost, float]] = []
    fallback: Optional[tuple[Plan, PlanCost, float]] = None
    for plan in candidate_plans(cfg, shape):
        cost = predict_plan(cfg, shape, mesh_shape, mesh_axes, plan)
        if chip is not None:
            task = Task(kind=f"{cfg.name}:{shape.name}",
                        attrs={"flops": cost.flops_chip,
                               "bytes": cost.t_memory * TPU_V5E["mem_bw"],
                               "coll_bytes": cost.coll_bytes_chip})
            t = model.predict(task, chip)      # paper predict() interface
            t = t + 0.5 * cost.t_collective
        else:
            t = cost.t_step
        entry = (plan, cost, t)
        if fallback is None or cost.mem_bytes < fallback[1].mem_bytes:
            fallback = entry
        if not cost.fits:                      # constraint check (Alg.1 l.11)
            continue
        feasible.append(entry)
    if not feasible:
        assert fallback is not None
        plan, cost, _ = fallback
        return replace(plan, notes="NO plan fits HBM; min-memory fallback"), cost
    # among near-optimal feasible plans (<=10% slower than the best), prefer
    # the most numerically conservative dtype regime
    t_best = min(e[2] for e in feasible)

    def bf16_count(p: Plan) -> int:
        return sum(d != "float32" for d in
                   (p.param_dtype, p.accum_dtype, p.state_dtype))

    near = [e for e in feasible if e[2] <= 1.10 * t_best]
    plan, cost, _ = min(near, key=lambda e: (bf16_count(e[0]), e[2]))
    return plan, cost
