"""Ground-truth simulator + experiment runtime.

The paper evaluates on a physical testbed; we stand in a discrete-event
ground truth built on the same contention-interval engine as the Traverser
but with *richer physics*: superlinear contention and per-task
irregular-access noise (see core/slowdown.truth_params).  Predictors under
test (H-EYE / ACE-like / LaTS-like) never see these parameters.

``Runtime`` co-drives an assignment policy and the ground truth:

  phase 1 (online assignment): tasks are presented in release order; the
  policy (an Orchestrator, or a baseline) assigns each using only its own
  predictions + its belief ledger.  Scheduling overhead is accrued per task
  and delays the task's release (the paper counts orchestrator communication
  as overhead, Fig. 14).

  phase 2 (execution): the full workload with the frozen mapping runs
  through the ground-truth engine, yielding real latencies / QoS failures.

Both phases are driven by :class:`core.session.SchedulerSession`:
``Runtime.run`` is a thin delegate that keeps the seed's strict per-task
release-order semantics by default and exposes the frontier-batched wave
discipline via ``frontier=True``.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .hwgraph import HWGraph, ProcessingUnit
from .orchestrator import ActiveLedger, MapResult, Orchestrator
from .session import RunStats, SchedulerSession, _any_supporting
from .slowdown import DecoupledSlowdown, SlowdownParams, heye_params, truth_params
from .task import Task, TaskGraph
from .traverser import Traverser


def ground_truth_traverser(graph: HWGraph, seed: int = 0,
                           params: Optional[SlowdownParams] = None) -> Traverser:
    p = params or truth_params()
    rng = np.random.default_rng(seed)
    sd = DecoupledSlowdown(graph, p)
    return Traverser(graph, slowdown=sd, noise=p.noise, rng=rng)


def heye_traverser(graph: HWGraph) -> Traverser:
    return Traverser(graph, slowdown=DecoupledSlowdown(graph, heye_params()))


class Runtime:
    """Drives (policy -> mapping) then (ground truth -> outcomes).

    Thin delegate over :class:`SchedulerSession`: the default keeps the
    seed's strict per-task release-order semantics; ``frontier=True``
    switches to dependency-frontier batching (``map_batch`` waves)."""

    def __init__(self, graph: HWGraph, seed: int = 0,
                 truth: Optional[Traverser] = None) -> None:
        self.graph = graph
        self.truth = truth or ground_truth_traverser(graph, seed=seed)

    def run(self, cfg: TaskGraph,
            assign: Callable[[Task, float], Optional[MapResult]],
            charge_overhead: bool = True, frontier: bool = False) -> RunStats:
        """``assign(task, now)`` returns a MapResult (policy decision)."""
        session = SchedulerSession(self.graph, assign, truth=self.truth,
                                   charge_overhead=charge_overhead,
                                   frontier=frontier)
        return session.run(cfg)


# ---------------------------------------------------------------------------
# Baseline assignment policies (§5.1.1)
# ---------------------------------------------------------------------------
class AcePolicy:
    """ACE-like: static application orchestration, contention-blind.

    Maps each task kind once (at first sight) to the PU with the best
    *standalone* time reachable under its deadline, then reuses that static
    choice — "limited to static application orchestration ... does not
    consider shared resource utilization".
    """

    def __init__(self, graph: HWGraph, blind_traverser: Traverser) -> None:
        self.graph = graph
        self.trav = blind_traverser
        self.static_choice: dict[tuple[str, str], str] = {}   # (origin, kind) -> pu

    def map_batch(self, tasks, now: float):
        """Baseline batch entry: per-task decisions in order (this policy
        carries no batchable state beyond its static-choice cache)."""
        return [self(t, now) for t in tasks]

    def __call__(self, task: Task, now: float) -> Optional[MapResult]:
        key = (task.origin or "", task.kind)
        if key not in self.static_choice:
            best, best_pred = None, None
            for pu in self.graph.pus():
                if pu.model is None or not pu.model.supports(task, pu):
                    continue
                if (task.attrs.get("pinned") and
                        self.graph.device_of(pu.name).name != task.origin):
                    continue
                pred = self.trav.predict_task(task, pu.name, [])
                if task.deadline is not None and pred.total > task.deadline:
                    continue
                if best_pred is None or pred.total < best_pred.total:
                    best, best_pred = pu.name, pred
            if best is None:
                return None
            self.static_choice[key] = best
        pu = self.static_choice[key]
        pred = self.trav.predict_task(task, pu, [])
        return MapResult(pu=pu, prediction=pred, overhead=20e-6, queries=1)


class LatsPolicy:
    """Hetero-Edge/LaTS-like: latency-aware, availability-monitored, but
    contention-blind — picks the *available* PU with the best standalone
    time + communication, no shared-resource model (§5.1.1)."""

    def __init__(self, graph: HWGraph, blind_traverser: Traverser,
                 ledger: Optional[ActiveLedger] = None) -> None:
        self.graph = graph
        self.trav = blind_traverser
        self.ledger = ledger or ActiveLedger()

    def __call__(self, task: Task, now: float) -> Optional[MapResult]:
        self.ledger.prune(now)
        best: Optional[MapResult] = None
        queries = 0
        for pu in self.graph.pus():
            if pu.model is None or not pu.model.supports(task, pu):
                continue
            if (task.attrs.get("pinned") and
                    self.graph.device_of(pu.name).name != task.origin):
                continue
            queries += 1
            pred = self.trav.predict_task(task, pu.name, [])
            busy = self.ledger.count(pu.name)
            if busy >= pu.max_tenancy:       # availability monitoring
                continue
            if best is None or pred.total < best.prediction.total:
                best = MapResult(pu=pu.name, prediction=pred)
        if best is not None:
            best.queries = queries
            best.overhead = queries * 5e-6
            self.ledger.add(task, best.pu, best.prediction, now)
        return best

    def map_batch(self, tasks, now: float):
        """Baseline batch entry: per-task decisions in order (availability
        monitoring reads its own ledger between decisions)."""
        return [self(t, now) for t in tasks]


class OrchestratorPolicy:
    """H-EYE: route each task to its origin device's ORC (paper §3.2)."""

    def __init__(self, root: Orchestrator) -> None:
        self.root = root

    def __call__(self, task: Task, now: float) -> Optional[MapResult]:
        orc = None
        if task.origin is not None:
            orc = self.root.find_device_orc(task.origin)
        if orc is None:
            orc = next((o for o in self.root.iter_tree() if o.is_device_orc()),
                       self.root)
        return orc.map_batch([task], now)[0]

    def map_batch(self, tasks, now: float):
        """Frontier entry: the whole batch goes through the root ORC's
        ``map_batch`` (origin-routed).  Subclasses that customize per-task
        ``__call__`` (sticky / grouped / direct-server strategies) keep
        their semantics — the batch falls back to per-task calls for them."""
        if type(self).__call__ is not OrchestratorPolicy.__call__:
            return [self(t, now) for t in tasks]
        return self.root.map_batch(tasks, now, route=True)
