"""Ground-truth simulator + experiment runtime.

The paper evaluates on a physical testbed; we stand in a discrete-event
ground truth built on the same contention-interval engine as the Traverser
but with *richer physics*: superlinear contention and per-task
irregular-access noise (see core/slowdown.truth_params).  Predictors under
test (H-EYE / ACE-like / LaTS-like) never see these parameters.

``Runtime`` co-drives an assignment policy and the ground truth:

  phase 1 (online assignment): tasks are presented in release order; the
  policy (an Orchestrator, or a baseline) assigns each using only its own
  predictions + its belief ledger.  Scheduling overhead is accrued per task
  and delays the task's release (the paper counts orchestrator communication
  as overhead, Fig. 14).

  phase 2 (execution): the full workload with the frozen mapping runs
  through the ground-truth engine, yielding real latencies / QoS failures.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .hwgraph import HWGraph, ProcessingUnit
from .orchestrator import ActiveLedger, MapResult, Orchestrator
from .slowdown import DecoupledSlowdown, SlowdownParams, heye_params, truth_params
from .task import Task, TaskGraph
from .traverser import Timeline, Traverser


def ground_truth_traverser(graph: HWGraph, seed: int = 0,
                           params: Optional[SlowdownParams] = None) -> Traverser:
    p = params or truth_params()
    rng = np.random.default_rng(seed)
    sd = DecoupledSlowdown(graph, p)
    return Traverser(graph, slowdown=sd, noise=p.noise, rng=rng)


def heye_traverser(graph: HWGraph) -> Traverser:
    return Traverser(graph, slowdown=DecoupledSlowdown(graph, heye_params()))


@dataclass
class RunStats:
    timeline: Timeline
    mapping: dict[int, str]
    overhead: dict[int, float] = field(default_factory=dict)   # uid -> seconds
    queries: dict[int, int] = field(default_factory=dict)
    hops: dict[int, int] = field(default_factory=dict)
    unmapped: list[int] = field(default_factory=list)

    def qos_failures(self, cfg: TaskGraph) -> int:
        return sum(0 if self.timeline.deadline_met(t) else 1 for t in cfg)

    def qos_failure_rate(self, cfg: TaskGraph) -> float:
        dl = [t for t in cfg if t.deadline is not None]
        if not dl:
            return 0.0
        return sum(0 if self.timeline.deadline_met(t) else 1
                   for t in dl) / len(dl)

    def mean_overhead_ratio(self, cfg: TaskGraph) -> float:
        """Fig. 14 metric: scheduling overhead / task execution time."""
        ratios = []
        for t in cfg:
            exec_t = (self.timeline.finish[t.uid] - self.timeline.start[t.uid])
            if exec_t > 0 and t.uid in self.overhead:
                ratios.append(self.overhead[t.uid] / exec_t)
        return float(np.mean(ratios)) if ratios else 0.0


class Runtime:
    """Drives (policy -> mapping) then (ground truth -> outcomes)."""

    def __init__(self, graph: HWGraph, seed: int = 0,
                 truth: Optional[Traverser] = None) -> None:
        self.graph = graph
        self.truth = truth or ground_truth_traverser(graph, seed=seed)

    def run(self, cfg: TaskGraph,
            assign: Callable[[Task, float], Optional[MapResult]],
            charge_overhead: bool = True) -> RunStats:
        """``assign(task, now)`` returns a MapResult (policy decision)."""
        mapping: dict[int, str] = {}
        stats_overhead: dict[int, float] = {}
        stats_q: dict[int, int] = {}
        stats_h: dict[int, int] = {}
        unmapped: list[int] = []
        for t in sorted(cfg, key=lambda t: (t.release_time, t.uid)):
            preds = cfg.preds(t)
            placed = [p.assigned_pu for p in preds if p.assigned_pu]
            if placed:
                t.attrs["src_devices"] = sorted(
                    {self.graph.device_of(pu).name for pu in placed})
            res = assign(t, t.release_time)
            if res is None:
                unmapped.append(t.uid)
                # fall back to any supporting PU so execution remains defined
                res = _any_supporting(self.graph, t)
                if res is None:
                    raise RuntimeError(f"no PU supports {t}")
            mapping[t.uid] = res.pu
            stats_overhead[t.uid] = res.overhead
            stats_q[t.uid] = res.queries
            stats_h[t.uid] = res.hops
            if charge_overhead:
                t.release_time += res.overhead
        tl = self.truth.traverse(cfg, mapping)
        return RunStats(timeline=tl, mapping=mapping, overhead=stats_overhead,
                        queries=stats_q, hops=stats_h, unmapped=unmapped)


def _any_supporting(graph: HWGraph, task: Task) -> Optional[MapResult]:
    from .traverser import TaskPrediction
    for pu in graph.pus():
        if pu.model is None or not pu.model.supports(task, pu):
            continue
        if (task.attrs.get("pinned") and
                graph.device_of(pu.name).name != task.origin):
            continue
        return MapResult(pu=pu.name,
                         prediction=TaskPrediction(pu.predict(task), 1.0, 0.0))
    return None


# ---------------------------------------------------------------------------
# Baseline assignment policies (§5.1.1)
# ---------------------------------------------------------------------------
class AcePolicy:
    """ACE-like: static application orchestration, contention-blind.

    Maps each task kind once (at first sight) to the PU with the best
    *standalone* time reachable under its deadline, then reuses that static
    choice — "limited to static application orchestration ... does not
    consider shared resource utilization".
    """

    def __init__(self, graph: HWGraph, blind_traverser: Traverser) -> None:
        self.graph = graph
        self.trav = blind_traverser
        self.static_choice: dict[tuple[str, str], str] = {}   # (origin, kind) -> pu

    def __call__(self, task: Task, now: float) -> Optional[MapResult]:
        key = (task.origin or "", task.kind)
        if key not in self.static_choice:
            best, best_pred = None, None
            for pu in self.graph.pus():
                if pu.model is None or not pu.model.supports(task, pu):
                    continue
                if (task.attrs.get("pinned") and
                        self.graph.device_of(pu.name).name != task.origin):
                    continue
                pred = self.trav.predict_task(task, pu.name, [])
                if task.deadline is not None and pred.total > task.deadline:
                    continue
                if best_pred is None or pred.total < best_pred.total:
                    best, best_pred = pu.name, pred
            if best is None:
                return None
            self.static_choice[key] = best
        pu = self.static_choice[key]
        pred = self.trav.predict_task(task, pu, [])
        return MapResult(pu=pu, prediction=pred, overhead=20e-6, queries=1)


class LatsPolicy:
    """Hetero-Edge/LaTS-like: latency-aware, availability-monitored, but
    contention-blind — picks the *available* PU with the best standalone
    time + communication, no shared-resource model (§5.1.1)."""

    def __init__(self, graph: HWGraph, blind_traverser: Traverser,
                 ledger: Optional[ActiveLedger] = None) -> None:
        self.graph = graph
        self.trav = blind_traverser
        self.ledger = ledger or ActiveLedger()

    def __call__(self, task: Task, now: float) -> Optional[MapResult]:
        self.ledger.prune(now)
        best: Optional[MapResult] = None
        queries = 0
        for pu in self.graph.pus():
            if pu.model is None or not pu.model.supports(task, pu):
                continue
            if (task.attrs.get("pinned") and
                    self.graph.device_of(pu.name).name != task.origin):
                continue
            queries += 1
            pred = self.trav.predict_task(task, pu.name, [])
            busy = self.ledger.count(pu.name)
            if busy >= pu.max_tenancy:       # availability monitoring
                continue
            if best is None or pred.total < best.prediction.total:
                best = MapResult(pu=pu.name, prediction=pred)
        if best is not None:
            best.queries = queries
            best.overhead = queries * 5e-6
            self.ledger.add(task, best.pu, best.prediction, now)
        return best


class OrchestratorPolicy:
    """H-EYE: route each task to its origin device's ORC (paper §3.2)."""

    def __init__(self, root: Orchestrator) -> None:
        self.root = root

    def __call__(self, task: Task, now: float) -> Optional[MapResult]:
        orc = None
        if task.origin is not None:
            orc = self.root.find_device_orc(task.origin)
        if orc is None:
            orc = next((o for o in self.root.iter_tree() if o.is_device_orc()),
                       self.root)
        return orc.map_task(task, now)
