"""Tasks and control-flow graphs (CFGs) of tasks.

A ``Task`` is the unit the Orchestrator maps onto a PU.  Per the paper it
carries (i) identification info used to look up modeled performance
(``kind``, ``size``), (ii) per-task constraints (a latency deadline), and
(iii) its *generalized resource usage* per shared resource class — the
quantity the decoupled slowdown models consume (requested memory bandwidth,
link bandwidth, PU utilization; §3.4 "Slowdown calculation" step 2).

A ``TaskGraph`` is a DAG with serial & parallel regions (paper Fig. 6/7/8).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional

_task_counter = itertools.count()


@dataclass
class Task:
    kind: str                                   # e.g. "render", "svm", "layer_fwd"
    size: float = 1.0                           # work-amount scale (1.0 = profiled size)
    deadline: Optional[float] = None            # latency constraint in seconds (None = best effort)
    input_bytes: float = 0.0                    # bytes that must reach the PU before start
    output_bytes: float = 0.0                   # bytes produced (to successors)
    origin: Optional[str] = None                # device name where the task is generated
    # generalized usage per shared-resource class, e.g. {"dram_bw": 6e9, "pu": 1.0}
    usage: dict[str, float] = field(default_factory=dict)
    attrs: dict[str, Any] = field(default_factory=dict)
    uid: int = field(default_factory=lambda: next(_task_counter))
    # runtime state (filled by Orchestrator / simulator)
    assigned_pu: Optional[str] = None
    release_time: float = 0.0                   # earliest start (arrival)

    def __hash__(self) -> int:
        return self.uid

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Task) and other.uid == self.uid

    def clone(self, **overrides: Any) -> "Task":
        t = Task(kind=self.kind, size=self.size, deadline=self.deadline,
                 input_bytes=self.input_bytes, output_bytes=self.output_bytes,
                 origin=self.origin, usage=dict(self.usage), attrs=dict(self.attrs))
        for k, v in overrides.items():
            setattr(t, k, v)
        return t

    def __repr__(self) -> str:  # keep logs readable
        dl = f", dl={self.deadline * 1e3:.1f}ms" if self.deadline else ""
        return f"Task({self.kind}#{self.uid}{dl})"


class TaskGraph:
    """A DAG of Tasks; edges are dependencies (data flows producer->consumer)."""

    def __init__(self, name: str = "cfg") -> None:
        self.name = name
        self.tasks: list[Task] = []
        self._succ: dict[int, list[Task]] = {}
        self._pred: dict[int, list[Task]] = {}

    # -- construction ------------------------------------------------------
    def add(self, task: Task, deps: Iterable[Task] = ()) -> Task:
        self.tasks.append(task)
        self._succ.setdefault(task.uid, [])
        self._pred.setdefault(task.uid, [])
        for d in deps:
            self.add_dep(d, task)
        return task

    def add_dep(self, producer: Task, consumer: Task) -> None:
        self._succ.setdefault(producer.uid, []).append(consumer)
        self._pred.setdefault(consumer.uid, []).append(producer)

    def chain(self, tasks: Iterable[Task]) -> list[Task]:
        """Convenience: serial region."""
        out: list[Task] = []
        prev: Optional[Task] = None
        for t in tasks:
            self.add(t, deps=[prev] if prev is not None else [])
            out.append(t)
            prev = t
        return out

    def remove(self, task: Task) -> None:
        """Withdraw ``task`` from the graph (the admission-rejection
        path).  Dependents of a removed task lose the dependency edge;
        callers withdrawing whole requests remove every member."""
        self.tasks = [t for t in self.tasks if t.uid != task.uid]
        for s in self._succ.pop(task.uid, []):
            self._pred[s.uid] = [p for p in self._pred.get(s.uid, [])
                                 if p.uid != task.uid]
        for p in self._pred.pop(task.uid, []):
            self._succ[p.uid] = [s for s in self._succ.get(p.uid, [])
                                 if s.uid != task.uid]

    # -- queries -----------------------------------------------------------
    def preds(self, task: Task) -> list[Task]:
        return self._pred.get(task.uid, [])

    def succs(self, task: Task) -> list[Task]:
        return self._succ.get(task.uid, [])

    def roots(self) -> list[Task]:
        return [t for t in self.tasks if not self._pred.get(t.uid)]

    def topological(self) -> list[Task]:
        indeg = {t.uid: len(self._pred.get(t.uid, [])) for t in self.tasks}
        ready = [t for t in self.tasks if indeg[t.uid] == 0]
        order: list[Task] = []
        i = 0
        while i < len(ready):
            t = ready[i]
            i += 1
            order.append(t)
            for s in self._succ.get(t.uid, []):
                indeg[s.uid] -= 1
                if indeg[s.uid] == 0:
                    ready.append(s)
        if len(order) != len(self.tasks):
            raise ValueError(f"cycle detected in TaskGraph {self.name!r}")
        return order

    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks)
