"""Compiled, array-native HW-GRAPH engine.

The HW-GRAPH lives in two layers:

* **Authoring layer** (`hwgraph.HWGraph`) — the mutable object graph the
  topology builders construct and the dynamic-adaptability hooks mutate
  (``mark_dead`` / ``mark_alive`` / ``set_bandwidth``).  It stays the
  single source of truth and the reference implementation for every
  query (``resource_path``, ``transfer_time``, ``shared_resources``).

* **Compiled layer** (this module) — an immutable, dense-array snapshot
  built once per topology version and shared by every consumer that
  evaluates *many* PUs or PU pairs per decision: the vectorized slowdown
  model (`slowdown.DecoupledSlowdown.factor_batch` / `slowdown_matrix`),
  the Traverser's contention-interval repricing, and the Orchestrator's
  batched candidate constraint checks.

``HWGraph.compiled()`` returns the current snapshot.  Construction-time
mutations (``add_node`` / ``add_edge``) drop it for a full lazy rebuild;
the *runtime* mutations (``mark_dead`` / ``mark_alive`` /
``set_bandwidth``) instead go through :meth:`CompiledHWGraph.apply_delta`,
which produces a cheap copy-on-write clone with only the affected arrays
patched — dead/revived PU masks, the transfer rows whose routes touch the
mutated subtree, the inverse-bandwidth entries of routes crossing a
re-provisioned link — so large fleets survive topology churn without
re-running the all-pairs builds.  The route table itself is layered:
death/revival patches own the *topology layer* (latency/routes/built
state, O(D^2) to copy) while ``set_bandwidth`` deltas own only a private
*bandwidth overlay* (per-row effective inverse-bandwidth shadows,
O(changed rows)), so bandwidth-volatile fleets never pay the holder
copy.  ``apply_delta`` returns ``None`` when a
mutation's effects exceed what can be patched (e.g. a cache dying under
still-alive PUs), and the graph falls back to the full rebuild.  All
precomputed quantities are bit-for-bit reproductions of the object-path
algorithms — parity is enforced to 1e-9 by ``tests/test_compiled.py``
and ``tests/test_session.py`` (delta vs. fresh recompile under churn):

* a **PU index space** (every ``ProcessingUnit``, alive or not, in
  insertion order) with per-PU effective-memory caps, PU-class kinds,
  tenancy limits and enclosing-device names;
* per-PU **compute-path membership masks** over the resource
  (STORAGE/CONTROLLER) index space;
* the all-pairs **nearest-common-resource matrix** ``ncr_res`` (and its
  resource-class projection ``ncr_rclass``) replacing pairwise
  ``shared_resources()`` path scans — entry ``[i, j]`` is the first
  resource on PU ``i``'s compute path that PU ``j``'s path also visits,
  i.e. the contention point of the pair (paper Fig. 4).  Compute paths
  never cross device boundaries, so the matrix is block-diagonal by
  device and is built per-device-block instead of scanning P x P;
* **transfer latency / inverse-bandwidth tables** over the routable
  (GROUP) nodes, plus the concrete ``EdgeAttr`` route lists so the
  Traverser's bandwidth-sharing transfer jobs skip per-query Dijkstra
  runs.  Route rows are **lazily materialized** (one Dijkstra on first
  access per source; ``ensure_routes`` batch-warms a working set), so
  snapshot construction is O(touched routes) and fleet-scale builds
  (mult=128 weak scaling) stay under a second — see ``docs/timeline.md``
  for the full lifecycle under ``apply_delta`` churn.
"""
from __future__ import annotations

import threading
import weakref
from typing import Optional, Sequence

import numpy as np

from .hwgraph import EdgeAttr, HWGraph, NodeKind, ProcessingUnit

# bandwidth-overlay compaction threshold: fold the overlay back into a
# solely-owned topology layer once this many distinct links are dirty
_OVERLAY_COMPACT_DIRTY = 64


class _RouteTopo:
    """The **topology layer** of the route table: dense latency matrix,
    build-time base inverse-bandwidth matrix, concrete ``EdgeAttr`` route
    lists, per-row materialization state, and the crossed-edge id set.

    This layer is shared copy-on-write across snapshots and is owned
    (privately copied) only by death/revival patches.  Lazy route-row
    builds *write through* to it — every sharer sees the same ``built``
    flags and freshly built rows, which is the invariant that used to
    force all-or-nothing holder sharing.  Built rows are never mutated
    while shared: bandwidth repricing lives in the per-snapshot overlay
    (:class:`_RouteTable`), and ``_invalidate_row`` only ever runs after
    a private topology copy."""

    __slots__ = ("lat", "ibw", "routes", "built", "edge_ids", "fast",
                 "owners")

    def __init__(self, D: int) -> None:
        self.lat = np.full((D, D), np.inf)
        np.fill_diagonal(self.lat, 0.0)
        self.ibw = np.zeros((D, D))
        self.routes: dict[tuple[int, int], list[EdgeAttr]] = {}
        self.built = np.zeros(D, dtype=bool)
        # ids of every EdgeAttr any built route crosses (delta prefilter)
        self.edge_ids: set[int] = set()
        # rows built by the batched builder: row -> (predecessor array
        # over the global node space, sorted edge ordinals the row's
        # shortest-path tree crosses).  Their concrete EdgeAttr route
        # lists materialize per pair on first route_edges() access.
        self.fast: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        # the _RouteTables currently sharing this layer (weak: dead
        # snapshots drop out) — overlay compaction is legal exactly when
        # one table is the sole surviving sharer
        self.owners: "weakref.WeakSet" = weakref.WeakSet()

    def copy(self) -> "_RouteTopo":
        c = object.__new__(_RouteTopo)
        c.lat = self.lat.copy()
        c.ibw = self.ibw.copy()
        c.routes = dict(self.routes)
        c.built = self.built.copy()
        c.edge_ids = set(self.edge_ids)
        c.fast = dict(self.fast)
        c.owners = weakref.WeakSet()
        return c


class _RouteTable:
    """One snapshot's route view: a shared :class:`_RouteTopo` plus a
    private **bandwidth overlay** — per-row effective inverse-bandwidth
    shadows (``over``) and the set of links repriced since the topology
    layer was last privately owned (``dirty``).

    The two layers have different copy-on-write owners:

    * ``apply_delta(kind="set_bandwidth")`` clones via
      :meth:`overlay_clone` — the topology layer stays shared and only
      the overlay dict is copied, so a bandwidth-only delta costs
      O(changed rows) instead of O(D^2);
    * death/revival patches clone via :meth:`copy` — a private topology
      copy with the overlay flattened into the base ``ibw`` (those paths
      mutate lat/routes/built in place, which is only legal on a private
      topology).

    Effective inverse bandwidth is read through :meth:`ibw_row` /
    :meth:`ibw_col`; there is deliberately no ``.ibw`` attribute, so a
    consumer reading the base matrix without the overlay fails loudly."""

    __slots__ = ("topo", "over", "dirty", "__weakref__")

    def __init__(self, D: int) -> None:
        self.topo = _RouteTopo(D)
        self.over: dict[int, np.ndarray] = {}
        self.dirty: set[str] = set()
        self.topo.owners.add(self)

    # -- topology-layer views (shared; see _RouteTopo) -------------------
    @property
    def lat(self) -> np.ndarray:
        return self.topo.lat

    @property
    def routes(self) -> dict:
        return self.topo.routes

    @property
    def built(self) -> np.ndarray:
        return self.topo.built

    @property
    def edge_ids(self) -> set:
        return self.topo.edge_ids

    @property
    def fast(self) -> dict:
        return self.topo.fast

    # -- effective inverse bandwidth (base + overlay) --------------------
    def ibw_row(self, i: int) -> np.ndarray:
        """Effective inverse-bandwidth row ``i`` (overlay shadow wins)."""
        r = self.over.get(i)
        return r if r is not None else self.topo.ibw[i]

    def ibw_col(self, rows: np.ndarray, j: int) -> np.ndarray:
        """Effective inverse bandwidth of the pairs ``(rows, j)``."""
        col = self.topo.ibw[rows, j]
        if self.over:
            for k, i in enumerate(np.asarray(rows).tolist()):
                r = self.over.get(int(i))
                if r is not None:
                    col[k] = r[j]
        return col

    # -- the two copy-on-write clones ------------------------------------
    def overlay_clone(self) -> "_RouteTable":
        """Bandwidth-delta clone: share the topology layer, copy the
        overlay dict (row arrays stay shared until shadowed)."""
        c = object.__new__(_RouteTable)
        c.topo = self.topo
        c.over = dict(self.over)
        c.dirty = set(self.dirty)
        self.topo.owners.add(c)
        return c

    def copy(self) -> "_RouteTable":
        """Topology-delta clone: private topology copy with the overlay
        flattened into the base ``ibw`` (O(D^2) — the death/revival
        price, paid only on aliveness churn)."""
        c = object.__new__(_RouteTable)
        c.topo = self.topo.copy()
        for i, row in self.over.items():
            c.topo.ibw[i, :] = row
        c.over = {}
        c.dirty = set()
        c.topo.owners.add(c)
        return c

    def compact(self) -> None:
        """Fold the bandwidth overlay back into the (solely owned)
        topology layer: ``over`` rows become the base ``ibw`` rows and
        both shadows clear.  Semantics-preserving for this table —
        ``ibw_row``/``ibw_col`` read identical values before and after —
        and ONLY legal when ``len(topo.owners) == 1`` (any other sharer
        would see the fold).  Long bandwidth-churn-heavy serving runs
        call this to keep ``dirty``/``over`` bounded."""
        for i, row in self.over.items():
            self.topo.ibw[i, :] = row
        self.over = {}
        self.dirty = set()


def _have_scipy() -> bool:
    global _SCIPY
    if _SCIPY is None:
        try:
            from scipy.sparse.csgraph import dijkstra  # noqa: F401
            _SCIPY = True
        except Exception:                # pragma: no cover - no scipy
            _SCIPY = False
    return _SCIPY


_SCIPY: Optional[bool] = None


class _FastRouteCtx:
    """Shared state of the batched route-row builder for one snapshot:
    the integer-compressed alive adjacency (a scipy CSR weight matrix),
    per-directed-pair best-edge value arrays, and gather tables over the
    edge-ordinal space.

    Node indices follow ``list(graph.nodes)`` order and edge ordinals
    enumerate ``CompiledHWGraph._best_edge`` insertion order — both are
    stable across ``apply_delta`` clones of one compile, so predecessor
    arrays and ordinal sets stored in the route table stay meaningful
    after the ctx itself is dropped.  The weight matrix bakes in
    aliveness (edges into dead nodes are absent, exactly the neighbors
    ``HWGraph.sssp`` skips), so ``_clone`` pops the ctx and the next
    batch build re-derives it against the post-delta graph."""

    __slots__ = ("idx", "N", "keys", "hlat", "hibw", "kord", "ord_ids",
                 "W", "r_idx")

    def __init__(self, comp: "CompiledHWGraph") -> None:
        from scipy.sparse import csr_matrix
        g = comp.graph
        names, idx = comp._node_space()
        self.idx = idx
        self.N = N = len(names)
        alive = np.fromiter((g.nodes[n].alive for n in names), bool, N)
        ord_edges = comp._edge_ord_edges()
        key_l: list[int] = []
        w_l: list[float] = []
        hl_l: list[float] = []
        hb_l: list[float] = []
        ko_l: list[int] = []
        for o, ((a, b), e) in enumerate(comp._best_edge.items()):
            bi = idx[b]
            if not alive[bi]:
                continue
            key_l.append(idx[a] * N + bi)
            # the exact sssp() weight rule: zero-latency hops cost 1e-9
            w_l.append(e.latency if e.latency > 0 else 1e-9)
            hl_l.append(e.latency)
            bw = e.bandwidth
            hb_l.append(0.0 if bw == float("inf") else 1.0 / bw)
            ko_l.append(o)
        keys = np.asarray(key_l, dtype=np.int64)
        order = np.argsort(keys, kind="stable")
        self.keys = keys[order]
        self.hlat = np.asarray(hl_l)[order]
        self.hibw = np.asarray(hb_l)[order]
        self.kord = np.asarray(ko_l, dtype=np.int64)[order]
        w = np.asarray(w_l)[order]
        self.W = csr_matrix((w, (self.keys // N, self.keys % N)),
                            shape=(N, N))
        self.ord_ids = np.fromiter((id(e) for e in ord_edges),
                                   dtype=np.int64, count=len(ord_edges))
        self.r_idx = np.fromiter((idx[nm] for nm in comp.routable_names),
                                 dtype=np.int64,
                                 count=len(comp.routable_names))


class CompiledHWGraph:
    """Immutable array-native snapshot of one topology version.

    ``version`` increases monotonically across ``apply_delta`` clones so
    downstream caches can key on snapshot identity or version."""

    def __init__(self, graph: HWGraph) -> None:
        self.graph = graph
        self.version = 0
        self._build_pus()
        self._build_ncr()
        self._build_routes()
        # serializes lazy route-row materialization: the sharded walk
        # driver fans group scans out over host threads, and ``built[i]``
        # flips True before the row's lat/ibw entries are written — the
        # lock makes check-then-build atomic (shared across delta clones;
        # they share the authoring graph and route-holder family anyway)
        self._rt_lock = threading.RLock()

    # ------------------------------------------------------------------
    # build: PU index space
    # ------------------------------------------------------------------
    def _build_pus(self) -> None:
        g = self.graph
        self.pu_names: list[str] = [n.name for n in g.nodes.values()
                                    if isinstance(n, ProcessingUnit)]
        self.pu_index: dict[str, int] = {n: i for i, n in enumerate(self.pu_names)}
        P = len(self.pu_names)
        self.pu_alive = np.zeros(P, dtype=bool)
        self.mem_cap = np.full(P, np.inf)
        self.max_tenancy = np.zeros(P, dtype=np.int64)
        self.pu_class_kind: list[str] = []
        self._pu_device_name: dict[str, str] = {}
        for i, name in enumerate(self.pu_names):
            pu = g.nodes[name]
            self.pu_alive[i] = pu.alive
            cap = pu.attrs.get("mem_usage_cap")
            if cap is not None:
                self.mem_cap[i] = cap
            self.max_tenancy[i] = pu.max_tenancy
            self.pu_class_kind.append(
                pu.attrs.get("pu_class_kind", pu.attrs.get("pu_class", "default")))
            self._pu_device_name[name] = g.device_of(name).name
        # enclosing-device name per PU index (vectorized pinned-task masks)
        self.pu_device = np.array(
            [self._pu_device_name[n] for n in self.pu_names], dtype=object)
        # dense device ordinals (block-diagonal slowdown pairing, comm LUTs)
        self.dev_ord: dict[str, int] = {}
        self.dev_ord_names: list[str] = []
        ords = np.empty(P, dtype=np.int64)
        for i, name in enumerate(self.pu_names):
            dev = self._pu_device_name[name]
            o = self.dev_ord.get(dev)
            if o is None:
                o = self.dev_ord[dev] = len(self.dev_ord_names)
                self.dev_ord_names.append(dev)
            ords[i] = o
        self.pu_dev_ord = ords

    # ------------------------------------------------------------------
    # build: compute paths + nearest-common-resource matrix
    # ------------------------------------------------------------------
    def _build_ncr(self) -> None:
        g = self.graph
        P = len(self.pu_names)
        paths: list[list[str]] = []
        self.resource_names: list[str] = []
        self.resource_index: dict[str, int] = {}
        for name in self.pu_names:
            node = g.nodes[name]
            path = (node.get_compute_path() if isinstance(node, ProcessingUnit)
                    else g.resource_path(name))
            paths.append(path)
            for r in path:
                if r not in self.resource_index:
                    self.resource_index[r] = len(self.resource_names)
                    self.resource_names.append(r)
        self.compute_paths: list[list[str]] = paths
        R = len(self.resource_names)
        self.rclass_names: list[str] = []
        rclass_index: dict[str, int] = {}
        self.resource_rclass = np.zeros(R, dtype=np.int64)
        for r, name in enumerate(self.resource_names):
            rc = g.nodes[name].attrs.get("rclass", "dram")
            if rc not in rclass_index:
                rclass_index[rc] = len(self.rclass_names)
                self.rclass_names.append(rc)
            self.resource_rclass[r] = rclass_index[rc]
        # membership mask: does PU j's compute path visit resource r?
        self.path_mask = np.zeros((P, R), dtype=bool)
        for j, path in enumerate(paths):
            for r in path:
                self.path_mask[j, self.resource_index[r]] = True
        # ncr_res[i, j] = first resource on i's path that j's path visits.
        # Compute paths never cross device boundaries (within-device SSSP),
        # so the matrix is block-diagonal by enclosing device: build each
        # device's tiny block independently instead of scanning the full
        # P x P space — O(sum_d p_d^2) work, and the cross-device entries
        # stay at the -1 the full scan would produce.
        # (int32/int16 keep the P x P matrices compact at fleet scale)
        self.ncr_res = np.full((P, P), -1, dtype=np.int32)
        self.ncr_rclass = np.full((P, P), -1, dtype=np.int16)
        by_dev: dict[str, list[int]] = {}
        for i, name in enumerate(self.pu_names):
            by_dev.setdefault(self._pu_device_name[name], []).append(i)
        for rows in by_dev.values():
            idx = np.asarray(rows, dtype=np.int64)
            for i in rows:
                unset = np.ones(len(rows), dtype=bool)
                for r in paths[i]:
                    ri = self.resource_index[r]
                    hit = unset & self.path_mask[idx, ri]
                    self.ncr_res[i, idx[hit]] = ri
                    self.ncr_rclass[i, idx[hit]] = self.resource_rclass[ri]
                    unset &= ~hit

    def _rclass_of(self, ncr: np.ndarray) -> np.ndarray:
        return np.where(ncr >= 0, self.resource_rclass[ncr.clip(0)],
                        -1).astype(np.int16)

    # ------------------------------------------------------------------
    # build: all-pairs transfer over routable (GROUP) nodes
    # ------------------------------------------------------------------
    # Route rows are **lazily materialized**: construction only sets up the
    # index space and the min-latency edge lookup (O(E)); a source's routes
    # are computed by one Dijkstra on first access (``_ensure_row``) and
    # batch-warmed via ``ensure_routes``.  Snapshot construction therefore
    # costs O(touched routes), not O(all pairs) — the all-pairs build was
    # the mult>=64 bottleneck (ROADMAP).  The route state lives in a
    # layered ``_RouteTable``: a *topology layer* (lat/routes/built/fast,
    # shared copy-on-write, privately owned only by death/revival
    # patches; lazy builds write through to every sharer) plus a
    # per-snapshot *bandwidth overlay* (effective inverse-bandwidth row
    # shadows + repriced-link set, owned by ``set_bandwidth`` deltas) —
    # so bandwidth-only churn copies O(changed rows), not O(D^2), and
    # clones never see half-patched rows.  A row built lazily always
    # reflects the authoring graph *at build time*; a stale snapshot kept
    # across topology churn (e.g. a frozen traverse) resolves unbuilt
    # rows against the post-churn graph.  See docs/timeline.md
    # ("Route-table layering") for the full lifecycle.

    def _build_routes(self) -> None:
        g = self.graph
        self.routable_names: list[str] = [n.name for n in g.nodes.values()
                                          if n.kind is NodeKind.GROUP]
        self.routable_index: dict[str, int] = {n: i for i, n
                                               in enumerate(self.routable_names)}
        # min-latency edge per ordered node pair: O(1) per reconstruction hop
        # instead of scanning the full adjacency of high-degree hubs
        self._best_edge: dict[tuple[str, str], EdgeAttr] = {}
        for a, adj in g._adj.items():
            for b, e in adj:
                cur = self._best_edge.get((a, b))
                if cur is None or e.latency < cur.latency:
                    self._best_edge[(a, b)] = e
        self._rt = _RouteTable(len(self.routable_names))

    def _ensure_row(self, i: int) -> None:
        if not self._rt.built[i]:
            with self._rt_lock:
                if self._rt.built[i]:
                    return
                if _have_scipy():
                    self._build_rows_fast([i])
                else:
                    self._rebuild_route_row(i)

    def _node_space(self) -> tuple[list, dict]:
        """Global node name list / index map in ``graph.nodes`` order —
        the coordinate space of fast-row predecessor arrays.  Stable
        across ``apply_delta`` clones (node additions force a full
        recompile), so it is built once per compile family and shared."""
        ns = self.__dict__.get("_node_names")
        if ns is None:
            ns = self._node_names = list(self.graph.nodes)
            self._node_idx = {n: k for k, n in enumerate(ns)}
        return ns, self._node_idx

    def _edge_ord_edges(self) -> list:
        """EdgeAttr per edge ordinal (``_best_edge`` insertion order) —
        the coordinate space of fast-row crossed-edge sets."""
        el = self.__dict__.get("_edge_ords_list")
        if el is None:
            el = self._edge_ords_list = list(self._best_edge.values())
        return el

    def _fast_ctx(self) -> _FastRouteCtx:
        ctx = self.__dict__.get("_fast_route_ctx")
        if ctx is None:
            ctx = self._fast_route_ctx = _FastRouteCtx(self)
        return ctx

    def ensure_routes(self, srcs) -> int:
        """Batch-materialize the route rows of ``srcs`` (names or indices);
        returns how many rows were actually built.  Used to warm exactly
        the rows a workload will touch (e.g. every origin device of a
        submitted TaskGraph) in one pass.  With scipy present every build
        goes through the batched builder (one multi-source Dijkstra — its
        per-call setup amortizes even for a single row on fleet-sized
        graphs); the per-row heapq path remains the no-scipy fallback."""
        with self._rt_lock:
            idxs: list[int] = []
            seen: set[int] = set()
            for s in srcs:
                i = self.routable_index.get(s) if isinstance(s, str) else int(s)
                if i is None or i in seen or self._rt.built[i]:
                    continue
                seen.add(i)
                idxs.append(i)
            if idxs and _have_scipy():
                self._build_rows_fast(idxs)
            else:
                for i in idxs:
                    self._rebuild_route_row(i)
            return len(idxs)

    def _build_rows_fast(self, idxs: list) -> None:
        """Materialize many route rows at once: one multi-source scipy
        Dijkstra over the alive adjacency, then a vectorized
        predecessor-tree accumulation per row.

        Bitwise parity with ``_rebuild_route_row``: per-hop latencies
        accumulate source-outward — the same left-to-right order as the
        oracle's ``sum(e.latency ...)`` — and the bottleneck inverse
        bandwidth is a running max of reciprocals, bit-identical to
        ``1/min(bandwidths)`` for positive floats
        (tests/test_compiled.py asserts both).  Where equal-latency
        shortest paths exist the predecessor tree may pick a different
        tie member than the heapq oracle — the same caveat as delta
        route repair; latency/bandwidth values are exact either way."""
        from scipy.sparse.csgraph import dijkstra
        ctx = self._fast_ctx()
        g = self.graph
        si = np.fromiter((ctx.idx[self.routable_names[i]] for i in idxs),
                         dtype=np.int64, count=len(idxs))
        dist, pred = dijkstra(ctx.W, directed=True, indices=si,
                              return_predecessors=True)
        dist = np.atleast_2d(dist)
        pred = np.atleast_2d(pred)
        for k, i in enumerate(idxs):
            self._fill_fast_row(i, int(si[k]), dist[k], pred[k], ctx)
            g.route_row_builds += 1

    def _fill_fast_row(self, i: int, s: int, d: np.ndarray, p: np.ndarray,
                       ctx: _FastRouteCtx) -> None:
        # writes go to the (possibly shared) topology layer: a lazy build
        # is a write-through so every sharer sees the same built flags —
        # values read the live graph, matching the stale-snapshot rule
        topo = self._rt.topo
        if topo.built[i]:
            # rebuilds only: a fresh row has no stale materialized routes
            for j in range(len(self.routable_names)):
                topo.routes.pop((i, j), None)
            topo.fast.pop(i, None)
        topo.built[i] = True
        reach = np.isfinite(d)
        reach[s] = False
        vs = np.flatnonzero(reach)
        if not vs.size:
            topo.lat[i, :] = np.inf
            topo.lat[i, i] = 0.0
            topo.ibw[i, :] = 0.0
            return
        # per reachable node: its tree edge (pred -> node), gathered from
        # the sorted directed-pair key table
        pv = p[vs].astype(np.int64)
        pos = np.searchsorted(ctx.keys, pv * ctx.N + vs)
        el = ctx.hlat[pos]
        eb = ctx.hibw[pos]
        lat_to = np.zeros(ctx.N)
        ibw_to = np.zeros(ctx.N)
        known = np.zeros(ctx.N, dtype=bool)
        known[s] = True
        rem = np.arange(vs.size)
        while rem.size:
            ready = known[pv[rem]]
            sel = rem[ready]
            v = vs[sel]
            lat_to[v] = lat_to[pv[sel]] + el[sel]
            ibw_to[v] = np.maximum(ibw_to[pv[sel]], eb[sel])
            known[v] = True
            rem = rem[~ready]
        fin = known[ctx.r_idx]
        topo.lat[i, :] = np.where(fin, lat_to[ctx.r_idx], np.inf)
        topo.lat[i, i] = 0.0
        topo.ibw[i, :] = np.where(fin, ibw_to[ctx.r_idx], 0.0)
        topo.ibw[i, i] = 0.0
        ue = np.unique(ctx.kord[pos])
        topo.fast[i] = (p, ue)
        topo.edge_ids.update(ctx.ord_ids[ue].tolist())

    def _route_from_fast(self, i: int, j: int) -> Optional[list]:
        """Materialize the concrete EdgeAttr route of pair ``(i, j)`` from
        fast row ``i``'s stored predecessor tree (first route_edges hit)."""
        fast = self._rt.fast.get(i)
        if fast is None:
            return None
        names, idx = self._node_space()
        s = idx[self.routable_names[i]]
        p = fast[0]
        seq = [idx[self.routable_names[j]]]
        while seq[-1] != s:
            a = int(p[seq[-1]])
            if a < 0:
                return None
            seq.append(a)
        seq.reverse()
        edges = [self._best_edge[(names[a], names[b])]
                 for a, b in zip(seq, seq[1:])]
        rt = self._rt
        rt.routes[(i, j)] = edges
        rt.edge_ids.update(id(e) for e in edges)
        return edges

    def _rebuild_route_row(self, i: int) -> None:
        """(Re)compute all routes from source ``i`` against the current
        authoring graph — the unit of repair/materialization."""
        g = self.graph
        topo = self._rt.topo          # write-through (see _fill_fast_row)
        src = self.routable_names[i]
        topo.lat[i, :] = np.inf
        topo.lat[i, i] = 0.0
        topo.ibw[i, :] = 0.0
        for j in range(len(self.routable_names)):
            topo.routes.pop((i, j), None)
        topo.fast.pop(i, None)
        topo.built[i] = True
        g.route_row_builds += 1
        if not g._adj[src]:
            return
        dist, pred = g.sssp(src)
        for j, dst in enumerate(self.routable_names):
            if i == j or dst not in dist:
                continue
            seq = [dst]
            while seq[-1] != src:
                seq.append(pred[seq[-1]])
            seq.reverse()
            edges = [self._best_edge[(a, b)] for a, b in zip(seq, seq[1:])]
            topo.routes[(i, j)] = edges
            topo.edge_ids.update(id(e) for e in edges)
            topo.lat[i, j] = sum(e.latency for e in edges)
            bw = min((e.bandwidth for e in edges), default=float("inf"))
            topo.ibw[i, j] = 0.0 if bw == float("inf") else 1.0 / bw

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def device_name(self, name: str) -> str:
        """Enclosing device-group name (precomputed for PUs)."""
        dev = self._pu_device_name.get(name)
        if dev is None:
            return self.graph.device_of(name).name
        return dev

    def nearest_common_resource(self, pu_a: str, pu_b: str) -> Optional[str]:
        """First resource on ``pu_a``'s compute path also on ``pu_b``'s."""
        i = self.pu_index.get(pu_a)
        j = self.pu_index.get(pu_b)
        if i is None or j is None:
            # non-PU queries keep the object-path semantics
            g = self.graph
            pa = self.compute_paths[i] if i is not None else g.resource_path(pu_a)
            pb = set(self.compute_paths[j] if j is not None
                     else g.resource_path(pu_b))
            return next((r for r in pa if r in pb), None)
        r = self.ncr_res[i, j]
        return self.resource_names[r] if r >= 0 else None

    def transfer_time(self, src: str, dst: str, nbytes: float) -> float:
        """Parity twin of ``HWGraph.transfer_time`` (KeyError when no path)."""
        if src == dst:
            return 0.0
        i = self.routable_index.get(src)
        j = self.routable_index.get(dst)
        if i is None or j is None:
            return self.graph.transfer_time(src, dst, nbytes)
        self._ensure_row(i)
        lat = self._rt.lat[i, j]
        if not np.isfinite(lat):
            raise KeyError(f"no path {src} -> {dst}")
        return float(lat + (nbytes * self._rt.ibw_row(i)[j]
                            if nbytes > 0 else 0.0))

    def route_edges(self, src: str, dst: str) -> list[EdgeAttr]:
        """The shortest-path interconnects src -> dst (shared EdgeAttr refs,
        so concurrent transfers keep contending on the same objects)."""
        i = self.routable_index.get(src)
        j = self.routable_index.get(dst)
        if i is None or j is None:
            return self.graph.route_edges(src, dst)
        if i == j:
            return []
        self._ensure_row(i)
        edges = self._rt.routes.get((i, j))
        if edges is None and np.isfinite(self._rt.lat[i, j]):
            edges = self._route_from_fast(i, j)
        if edges is None:
            raise KeyError(f"no path {src} -> {dst}")
        return edges

    # ------------------------------------------------------------------
    # incremental snapshot deltas (mark_dead / mark_alive / set_bandwidth)
    # ------------------------------------------------------------------
    def apply_delta(self, kind: str, names=(), edge_name: Optional[str] = None,
                    edge_names: Sequence[str] = (),
                    ) -> Optional["CompiledHWGraph"]:
        """Patch this snapshot into a *new* snapshot reflecting one
        authoring-layer mutation (already applied to ``self.graph``),
        without a full recompile.

        Returns a copy-on-write clone — only the arrays the mutation
        touches are copied — or ``None`` when the mutation's effects
        exceed what can be patched (the caller then rebuilds from
        scratch).  ``kind="set_bandwidth"`` accepts many links at once
        (``edge_names``; a coalesced ``Churn`` bandwidth batch pays one
        overlay copy) and never copies the topology layer.  Route repair
        note: where several equal-latency shortest paths exist, a
        patched route may legitimately differ from the one a fresh
        Dijkstra would pick; latency parity is exact either way.
        """
        if kind == "set_bandwidth":
            en = tuple(edge_names) or ((edge_name,) if edge_name else ())
            return self._delta_bandwidth(en)
        if kind in ("mark_dead", "mark_alive"):
            return self._delta_alive(kind == "mark_alive", set(names))
        return None

    def _clone(self) -> "CompiledHWGraph":
        c = object.__new__(CompiledHWGraph)
        c.__dict__.update(self.__dict__)
        c.version = self.version + 1
        # the batched-builder ctx bakes in aliveness; re-derive post-delta
        c.__dict__.pop("_fast_route_ctx", None)
        # per-group shard views slice aliveness/NCR state; re-slice lazily
        c.__dict__.pop("_sharded", None)
        return c

    def _delta_bandwidth(self, edge_names: Sequence[str],
                         ) -> "CompiledHWGraph":
        # Shortest-path selection weighs latency only, so routes never
        # change with bandwidth; the EdgeAttr objects are shared with the
        # authoring layer, so route_edges already sees the new values.
        # Only the effective inverse bandwidth of *built* rows crossing a
        # changed link needs repair — and that repair lives entirely in
        # the private bandwidth overlay: the topology layer stays shared
        # (route_holder_copies stays 0 under bandwidth-only churn) and
        # unbuilt rows read the live bandwidth when materialized.
        g = self.graph
        names = set(edge_names)
        rt = self._rt
        # overlay compaction (bounded-shadow invariant for long serving
        # runs): once the accumulated dirty-link set is large and no other
        # snapshot shares the topology layer, fold the overlay into it —
        # the successor then starts from an empty overlay instead of
        # dragging every link ever repriced
        if (len(rt.dirty) >= _OVERLAY_COMPACT_DIRTY
                and len(rt.topo.owners) == 1):
            rt.compact()
            g.route_overlay_compactions += 1
        c = self._clone()
        changed_ids = {id(e) for adj in g._adj.values() for _, e in adj
                       if e.name in names}
        if not (changed_ids & rt.edge_ids):
            return c          # no built route crosses a changed link:
                              # share both layers untouched
        c._rt = rt = rt.overlay_clone()
        g.route_overlay_copies += 1
        rt.dirty.update(names)
        topo = rt.topo
        # rows privately owned by *this* delta (safe to mutate in place);
        # rows inherited from the parent overlay stay shared until copied
        fresh: set[int] = set()
        replayed: set[int] = set()
        if topo.fast:
            # a fast-built row's unmaterialized pairs read effective ibw
            # straight off the stored row: when the row's shortest-path
            # tree crosses a changed link, replay the tree against the
            # live bandwidths into a private overlay row (routes are
            # bandwidth-independent, so the stored tree stays valid — no
            # Dijkstra, no shared-state mutation)
            name_ords = np.asarray(
                [o for o, e in enumerate(self._edge_ord_edges())
                 if e.name in names], dtype=np.int64)
            if name_ords.size:
                ctx = c._fast_ctx()
                for i, (p, eords) in topo.fast.items():
                    if bool(np.isin(name_ords, eords).any()):
                        rt.over[i] = c._overlay_row_from_tree(i, p, ctx)
                        fresh.add(i)
                        replayed.add(i)
        # materialized routes are authoritative per pair: repair every
        # pair crossing a changed link, and *all* materialized pairs of
        # tree-replayed rows (a revival-mirror pair is materialized but
        # invisible to the stored tree, so the replay zeroed it)
        for (i, j), edges in topo.routes.items():
            if not (i in replayed or any(e.name in names for e in edges)):
                continue
            row = rt.over.get(i)
            if i not in fresh:
                row = rt.over[i] = (row.copy() if row is not None
                                    else topo.ibw[i].copy())
                fresh.add(i)
            bw = min((e.bandwidth for e in edges), default=float("inf"))
            row[j] = 0.0 if bw == float("inf") else 1.0 / bw
        return c

    def _overlay_row_from_tree(self, i: int, p: np.ndarray,
                               ctx: _FastRouteCtx) -> np.ndarray:
        """Effective inverse-bandwidth row ``i`` replayed from the stored
        shortest-path tree against the live edge bandwidths — the same
        running-max-of-reciprocals accumulation as ``_fill_fast_row``
        (bit-identical to ``1/min(bandwidths)``), with hop values
        gathered from the post-mutation graph.  Hops into nodes that
        died since the row was built gather nothing (their columns were
        already wiped, and the finite-latency mask below zeroes them)."""
        topo = self._rt.topo
        row = np.zeros(len(self.routable_names))
        vs = np.flatnonzero(p >= 0)
        if not vs.size or not ctx.keys.size:
            return row
        s = int(ctx.idx[self.routable_names[i]])
        pv = p[vs].astype(np.int64)
        key = pv * ctx.N + vs
        pos = np.searchsorted(ctx.keys, key).clip(0, len(ctx.keys) - 1)
        eb = np.where(ctx.keys[pos] == key, ctx.hibw[pos], 0.0)
        ibw_to = np.zeros(ctx.N)
        known = np.zeros(ctx.N, dtype=bool)
        known[s] = True
        rem = np.arange(vs.size)
        while rem.size:
            ready = known[pv[rem]]
            sel = rem[ready]
            v = vs[sel]
            ibw_to[v] = np.maximum(ibw_to[pv[sel]], eb[sel])
            known[v] = True
            rem = rem[~ready]
        fin = known[ctx.r_idx] & np.isfinite(topo.lat[i, :])
        row[:] = np.where(fin, ibw_to[ctx.r_idx], 0.0)
        row[i] = 0.0
        return row

    def _delta_alive(self, alive: bool,
                     names: set) -> Optional["CompiledHWGraph"]:
        g = self.graph
        c = self._clone()
        # -- PU aliveness ------------------------------------------------
        rows = [self.pu_index[n] for n in names if n in self.pu_index]
        c.pu_alive = self.pu_alive.copy()
        if rows:
            c.pu_alive[rows] = alive
        # -- compute-path effects of dead/revived resources --------------
        # (ABSTRACT nodes are included conservatively: they could sit on
        # an intra-device shortest path even though they never appear in
        # the STORAGE/CONTROLLER path lists themselves)
        res_nodes = [n for n in names if g.nodes[n].kind in
                     (NodeKind.STORAGE, NodeKind.CONTROLLER, NodeKind.ABSTRACT)]
        if res_nodes:
            res_devs = {self.device_name(n) for n in res_nodes}
            stale = [i for i, p in enumerate(self.pu_names)
                     if self._pu_device_name[p] in res_devs]
            if not alive:
                # a resource dying under still-alive PUs re-routes their
                # compute paths: only the whole-subtree case is patchable
                # (the stale NCR entries then belong to dead PUs, which
                # eligibility masks filter; revival recomputes them)
                if any(c.pu_alive[i] for i in stale):
                    return None
            elif stale:
                c._refresh_ncr(stale)
        # -- transfer routes --------------------------------------------
        if not c._patch_routes(alive, names):
            return None
        return c

    def _refresh_ncr(self, rows: list) -> None:
        """Recompute compute paths + NCR rows/columns for ``rows`` (PUs of
        devices whose resources were revived), extending the resource
        space when the snapshot was first built while they were dead."""
        g = self.graph
        new_paths: dict[int, list[str]] = {}
        for i in rows:
            node = g.nodes[self.pu_names[i]]
            new_paths[i] = (node.get_compute_path()
                            if isinstance(node, ProcessingUnit)
                            else g.resource_path(self.pu_names[i]))
        # copy-on-write for everything this repair mutates
        self.compute_paths = list(self.compute_paths)
        self.resource_names = list(self.resource_names)
        self.resource_index = dict(self.resource_index)
        self.rclass_names = list(self.rclass_names)
        rclass_index = {rc: k for k, rc in enumerate(self.rclass_names)}
        fresh = [r for p in new_paths.values() for r in p
                 if r not in self.resource_index]
        res_rclass = list(self.resource_rclass)
        for r in dict.fromkeys(fresh):
            self.resource_index[r] = len(self.resource_names)
            self.resource_names.append(r)
            rc = g.nodes[r].attrs.get("rclass", "dram")
            if rc not in rclass_index:
                rclass_index[rc] = len(self.rclass_names)
                self.rclass_names.append(rc)
            res_rclass.append(rclass_index[rc])
        self.resource_rclass = np.asarray(res_rclass, dtype=np.int64)
        P = len(self.pu_names)
        R = len(self.resource_names)
        mask = np.zeros((P, R), dtype=bool)
        mask[:, :self.path_mask.shape[1]] = self.path_mask
        self.path_mask = mask
        self.ncr_res = self.ncr_res.copy()
        for i, path in new_paths.items():
            self.compute_paths[i] = path
            self.path_mask[i, :] = False
            for r in path:
                self.path_mask[i, self.resource_index[r]] = True
        rowset = set(rows)
        for i in rows:                       # rows of the refreshed PUs
            self.ncr_res[i, :] = -1
            unset = np.ones(P, dtype=bool)
            for r in new_paths[i]:
                ri = self.resource_index[r]
                hit = unset & self.path_mask[:, ri]
                self.ncr_res[i, hit] = ri
                unset &= ~hit
        cols = np.asarray(rows, dtype=np.int64)
        for j in range(P):                   # columns of the refreshed PUs
            if j in rowset:
                continue
            self.ncr_res[j, cols] = -1
            unset = np.ones(len(cols), dtype=bool)
            for r in self.compute_paths[j]:
                ri = self.resource_index[r]
                hit = unset & self.path_mask[cols, ri]
                self.ncr_res[j, cols[hit]] = ri
                unset &= ~hit
        self.ncr_rclass = self.ncr_rclass.copy()
        self.ncr_rclass[cols, :] = self._rclass_of(self.ncr_res[cols, :])
        self.ncr_rclass[:, cols] = self._rclass_of(self.ncr_res[:, cols])

    def _patch_routes(self, alive: bool, names: set) -> bool:
        """Repair the route table after an aliveness flip of ``names``.

        Death keeps the table warm: built rows are patched in place
        (endpoints into the dead subtree become unroutable; built routes
        *transiting* the subtree fall back to lazy) — leaf-device churn on
        tree-like fabrics costs no Dijkstra at all.  Revival invalidates
        exactly the built rows whose routes can change: the revived
        sources themselves, rows a boundary-node scan shows could improve
        through the revived subtree (which subsumes the old mirror repair
        — a formerly-unreachable revived destination reads as an
        improvement over +inf), and rows of still-dead sources the scan
        cannot see.  Invalidated rows re-derive on demand against the
        live graph; everything else stays warm."""
        g = self.graph
        if alive:
            # private topology copy (overlay flattened): aliveness repair
            # mutates lat/routes/built in place, which is only legal on
            # an owned topology layer
            self._rt = rt = self._rt.copy()
            g.route_holder_copies += 1
            r_s = sorted(self.routable_index[n] for n in names
                         if n in self.routable_index)
            for r in r_s:                # rows of revived sources (eager:
                self._rebuild_route_row(r)   # their columns mirror below)
            # mirror into the revived columns of built rows: undirected
            # fabric, so the reverse of a fresh shortest path is exact —
            # no per-row Dijkstra just to re-reach a revived destination
            built = np.nonzero(rt.built)[0]
            for r in r_s:
                for j in built.tolist():
                    if j == r or j in r_s:
                        continue
                    lat = rt.lat[r, j]
                    if np.isfinite(lat):
                        rt.routes[(j, r)] = list(
                            reversed(rt.routes[(r, j)]))
                        rt.lat[j, r] = lat
                        rt.topo.ibw[j, r] = rt.topo.ibw[r, j]
                    else:
                        rt.routes.pop((j, r), None)
                        rt.lat[j, r] = np.inf
                        rt.topo.ibw[j, r] = 0.0
            # transit improvements: a new shortest path through the
            # revived subtree must pass one of its boundary nodes — one
            # Dijkstra per boundary node flags exactly the built rows
            # that can improve; they fall back to lazy
            invalid: set[int] = set()
            boundary = [n for n in names
                        if any(v not in names and g.nodes[v].alive
                               for v, _ in g._adj.get(n, ()))]
            for b in boundary:
                dist, _ = g.sssp(b)
                d = np.array([dist.get(nm, np.inf)
                              for nm in self.routable_names])
                thru = d[:, None] + d[None, :]
                with np.errstate(invalid="ignore"):
                    imp = np.nonzero((thru < rt.lat).any(axis=1))[0]
                invalid.update(int(i) for i in imp if i not in r_s)
            # rows of still-dead sources are invisible to the boundary
            # scan (a dead node is unreachable as a destination but still
            # routes outward as a source)
            for j, nm in enumerate(self.routable_names):
                if j not in r_s and not g.nodes[nm].alive:
                    invalid.add(j)
            for i in invalid:
                if rt.built[i]:
                    self._invalidate_row(i)
            return True
        rt = self._rt
        # eid -> the subtree endpoints of that edge: a route *transits* the
        # subtree iff it crosses an edge owned by a node that is not one of
        # the route's own endpoints
        eid_owners: dict[int, set] = {}
        for n in names:
            for _, e in g._adj.get(n, ()):
                eid_owners.setdefault(id(e), set()).add(n)
        touched = set(eid_owners) & rt.edge_ids
        r_s = {self.routable_index[n] for n in names
               if n in self.routable_index}
        if not touched and not r_s:
            return True      # a node no built route crosses died
        self._rt = rt = rt.copy()    # private topology copy (see above)
        g.route_holder_copies += 1
        # endpoints into the dead subtree become unroutable (the object
        # path raises KeyError); routes *from* dead sources stay valid —
        # Dijkstra explores outward from a dead source
        stale: set[int] = set()
        for (i, j), edges in list(rt.routes.items()):
            if j in r_s:
                del rt.routes[(i, j)]
                continue
            si, sj = self.routable_names[i], self.routable_names[j]
            for e in edges:
                owners = eid_owners.get(id(e))
                if owners and not owners <= {si, sj}:
                    stale.add(i)
                    break
        if r_s:
            cols = sorted(r_s)
            rt.lat[:, cols] = np.inf
            rt.topo.ibw[:, cols] = 0.0
            for r in cols:
                rt.lat[r, r] = 0.0
        for i in stale:
            self._invalidate_row(i)
        # fast rows: unmaterialized pairs transiting the dead subtree are
        # exactly those whose predecessor chain passes a dead node as an
        # interior tree node (a dead node that is only a tree leaf serves
        # pairs *ending* there, which the column wipe already handles —
        # and a dead *source* keeps routing outward, like the object path)
        if rt.fast:
            _, idx = self._node_space()
            da = np.asarray([idx[n] for n in names if n in idx],
                            dtype=np.int64)
            if da.size:
                for i, (p, _) in list(rt.fast.items()):
                    si = idx[self.routable_names[i]]
                    hit = da[np.isin(da, p)]
                    if any(int(x) != si for x in hit):
                        self._invalidate_row(i)
        return True

    def _invalidate_row(self, i: int) -> None:
        """Return row ``i`` to the unbuilt state (rebuilt on next access).
        Only ever called on a privately owned topology layer — never
        while the topology is shared (the overlay is empty there)."""
        rt = self._rt
        rt.built[i] = False
        rt.lat[i, :] = np.inf
        rt.lat[i, i] = 0.0
        rt.topo.ibw[i, :] = 0.0
        for j in range(len(self.routable_names)):
            rt.routes.pop((i, j), None)
        rt.fast.pop(i, None)
        rt.over.pop(i, None)

    def summary(self) -> str:
        P = len(self.pu_names)
        return (f"CompiledHWGraph({P} PUs, {len(self.resource_names)} resources, "
                f"{len(self.rclass_names)} rclasses, "
                f"{len(self.routable_names)} routable, v{self.version})")

    # ------------------------------------------------------------------
    # per-ORC-group shard views (the sharded orchestration snapshot)
    # ------------------------------------------------------------------
    def sharded(self, groups: dict, validate: bool = True,
                ) -> "ShardedHWGraph":
        """Slice this snapshot into block-diagonal per-group views.

        ``groups`` maps a shard name (an ORC device-group subtree, e.g. a
        root ORC child) to the device-group names it owns.  The result is
        cached per (snapshot, partition) — ``_clone`` drops the cache, so
        post-delta snapshots re-slice lazily.  See ``docs/sharding.md``.
        """
        key = tuple(sorted((k, tuple(v)) for k, v in groups.items()))
        hit = self.__dict__.get("_sharded")
        if hit is not None and hit[0] == key:
            return hit[1]
        sh = ShardedHWGraph(self, groups, validate=validate)
        self._sharded = (key, sh)
        return sh


class GroupShard:
    """Block-diagonal view of one ORC device group: the group's PU rows
    remapped into a dense local index space, its NCR block, and slices of
    the per-PU state columns.  ``pu_idx`` maps local ordinals back to the
    parent snapshot's global PU ordinals (ascending, so slicing preserves
    global order)."""

    __slots__ = ("name", "devices", "pu_idx", "pu_names", "local_index",
                 "pu_alive", "mem_cap", "max_tenancy", "ncr_res",
                 "ncr_rclass", "pu_dev_ord")

    def __init__(self, comp: CompiledHWGraph, name: str,
                 devices: Sequence[str]) -> None:
        self.name = name
        self.devices = tuple(devices)
        ords = [comp.dev_ord[d] for d in self.devices if d in comp.dev_ord]
        sel = (np.flatnonzero(np.isin(comp.pu_dev_ord, ords)) if ords
               else np.zeros(0, dtype=np.int64))
        self.pu_idx = sel
        self.pu_names = [comp.pu_names[i] for i in sel]
        self.local_index = {n: k for k, n in enumerate(self.pu_names)}
        self.pu_alive = comp.pu_alive[sel]
        self.mem_cap = comp.mem_cap[sel]
        self.max_tenancy = comp.max_tenancy[sel]
        self.ncr_res = comp.ncr_res[np.ix_(sel, sel)]
        self.ncr_rclass = comp.ncr_rclass[np.ix_(sel, sel)]
        self.pu_dev_ord = comp.pu_dev_ord[sel]

    def __len__(self) -> int:
        return len(self.pu_names)

    def __repr__(self) -> str:
        return (f"GroupShard({self.name}: {len(self.pu_names)} PUs, "
                f"{len(self.devices)} devices)")


class ShardedHWGraph:
    """``CompiledHWGraph`` sliced into per-ORC-group :class:`GroupShard`
    block-diagonal views.

    The slices are sound because compute paths never cross device (and a
    fortiori group) boundaries: every cross-group NCR entry is ``-1`` by
    construction, which ``validate=True`` asserts pairwise.  The route
    table is **shared copy-on-write** with the parent snapshot — shards
    reference the same layered ``_RouteTable`` (shared topology layer +
    the parent's bandwidth overlay); ``apply_delta`` swaps the table on
    a *clone* (a bandwidth delta re-points only the overlay, an
    aliveness delta owns a fresh topology layer — shared built rows are
    never patched in place), and the clone re-slices its shards, so a
    shard's route view can never go half-patched.  Lazy row builds
    write through to the shared topology layer, so a build triggered
    through any shard (or the parent) is visible to all of them.  Cross-group work (the root ORC's boundary scan) keeps
    using the parent snapshot's full matrices — reconciliation happens
    through the NCR matrix, not through any shard."""

    def __init__(self, comp: CompiledHWGraph, groups: dict,
                 validate: bool = True) -> None:
        self.comp = comp
        self.routes = comp._rt           # shared COW route layer
        self.shards: list[GroupShard] = [
            GroupShard(comp, name, devs) for name, devs in groups.items()]
        self.shard_index = {s.name: i for i, s in enumerate(self.shards)}
        self.shard_of_device: dict[str, str] = {}
        claimed = np.zeros(len(comp.pu_names), dtype=bool)
        for s in self.shards:
            if claimed[s.pu_idx].any():
                raise ValueError(
                    f"shard {s.name!r} overlaps an earlier shard")
            claimed[s.pu_idx] = True
            for d in s.devices:
                self.shard_of_device[d] = s.name
        if validate:
            self._validate_block_diagonal()

    def _validate_block_diagonal(self) -> None:
        """The boundary-reconciliation invariant: PUs of different groups
        share no compute-path resource, so every cross-shard NCR entry is
        -1 and per-shard constraint checks compose exactly."""
        for a in self.shards:
            for b in self.shards:
                if a is b or not len(a.pu_idx) or not len(b.pu_idx):
                    continue
                blk = self.comp.ncr_res[np.ix_(a.pu_idx, b.pu_idx)]
                if (blk != -1).any():
                    raise ValueError(
                        f"groups {a.name!r} and {b.name!r} share a "
                        "compute-path resource: the partition is not "
                        "block-diagonal")

    def shard(self, name: str) -> GroupShard:
        return self.shards[self.shard_index[name]]

    def shard_of(self, device: str) -> Optional[str]:
        """Owning shard name of a device group (None when unclaimed)."""
        return self.shard_of_device.get(device)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def summary(self) -> str:
        parts = ", ".join(f"{s.name}:{len(s)}" for s in self.shards)
        return (f"ShardedHWGraph(v{self.comp.version}, "
                f"{len(self.shards)} shards [{parts}])")
