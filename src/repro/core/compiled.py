"""Compiled, array-native HW-GRAPH engine.

The HW-GRAPH lives in two layers:

* **Authoring layer** (`hwgraph.HWGraph`) — the mutable object graph the
  topology builders construct and the dynamic-adaptability hooks mutate
  (``mark_dead`` / ``mark_alive`` / ``set_bandwidth``).  It stays the
  single source of truth and the reference implementation for every
  query (``resource_path``, ``transfer_time``, ``shared_resources``).

* **Compiled layer** (this module) — an immutable, dense-array snapshot
  built once per topology version and shared by every consumer that
  evaluates *many* PUs or PU pairs per decision: the vectorized slowdown
  model (`slowdown.DecoupledSlowdown.factor_batch` / `slowdown_matrix`),
  the Traverser's contention-interval repricing, and the Orchestrator's
  batched candidate constraint checks.

``HWGraph.compiled()`` returns the current snapshot and rebuilds it
lazily after any topology mutation (the existing ``_invalidate_paths()``
hook drops the snapshot).  All precomputed quantities are bit-for-bit
reproductions of the object-path algorithms — parity is enforced to
1e-9 by ``tests/test_compiled.py``:

* a **PU index space** (every ``ProcessingUnit``, alive or not, in
  insertion order) with per-PU effective-memory caps, PU-class kinds,
  tenancy limits and enclosing-device names;
* per-PU **compute-path membership masks** over the resource
  (STORAGE/CONTROLLER) index space;
* the all-pairs **nearest-common-resource matrix** ``ncr_res`` (and its
  resource-class projection ``ncr_rclass``) replacing pairwise
  ``shared_resources()`` path scans — entry ``[i, j]`` is the first
  resource on PU ``i``'s compute path that PU ``j``'s path also visits,
  i.e. the contention point of the pair (paper Fig. 4);
* all-pairs **transfer latency / inverse-bandwidth matrices** over the
  routable (GROUP) nodes, plus the concrete ``EdgeAttr`` route lists so
  the Traverser's bandwidth-sharing transfer jobs skip per-query
  Dijkstra runs.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .hwgraph import EdgeAttr, HWGraph, NodeKind, ProcessingUnit


class CompiledHWGraph:
    """Immutable array-native snapshot of one topology version."""

    def __init__(self, graph: HWGraph) -> None:
        self.graph = graph
        self._build_pus()
        self._build_ncr()
        self._build_routes()

    # ------------------------------------------------------------------
    # build: PU index space
    # ------------------------------------------------------------------
    def _build_pus(self) -> None:
        g = self.graph
        self.pu_names: list[str] = [n.name for n in g.nodes.values()
                                    if isinstance(n, ProcessingUnit)]
        self.pu_index: dict[str, int] = {n: i for i, n in enumerate(self.pu_names)}
        P = len(self.pu_names)
        self.pu_alive = np.zeros(P, dtype=bool)
        self.mem_cap = np.full(P, np.inf)
        self.max_tenancy = np.zeros(P, dtype=np.int64)
        self.pu_class_kind: list[str] = []
        self._pu_device_name: dict[str, str] = {}
        for i, name in enumerate(self.pu_names):
            pu = g.nodes[name]
            self.pu_alive[i] = pu.alive
            cap = pu.attrs.get("mem_usage_cap")
            if cap is not None:
                self.mem_cap[i] = cap
            self.max_tenancy[i] = pu.max_tenancy
            self.pu_class_kind.append(
                pu.attrs.get("pu_class_kind", pu.attrs.get("pu_class", "default")))
            self._pu_device_name[name] = g.device_of(name).name

    # ------------------------------------------------------------------
    # build: compute paths + nearest-common-resource matrix
    # ------------------------------------------------------------------
    def _build_ncr(self) -> None:
        g = self.graph
        P = len(self.pu_names)
        paths: list[list[str]] = []
        self.resource_names: list[str] = []
        self.resource_index: dict[str, int] = {}
        for name in self.pu_names:
            node = g.nodes[name]
            path = (node.get_compute_path() if isinstance(node, ProcessingUnit)
                    else g.resource_path(name))
            paths.append(path)
            for r in path:
                if r not in self.resource_index:
                    self.resource_index[r] = len(self.resource_names)
                    self.resource_names.append(r)
        self.compute_paths: list[list[str]] = paths
        R = len(self.resource_names)
        self.rclass_names: list[str] = []
        rclass_index: dict[str, int] = {}
        self.resource_rclass = np.zeros(R, dtype=np.int64)
        for r, name in enumerate(self.resource_names):
            rc = g.nodes[name].attrs.get("rclass", "dram")
            if rc not in rclass_index:
                rclass_index[rc] = len(self.rclass_names)
                self.rclass_names.append(rc)
            self.resource_rclass[r] = rclass_index[rc]
        # membership mask: does PU j's compute path visit resource r?
        self.path_mask = np.zeros((P, R), dtype=bool)
        for j, path in enumerate(paths):
            for r in path:
                self.path_mask[j, self.resource_index[r]] = True
        # ncr_res[i, j] = first resource on i's path that j's path visits
        self.ncr_res = np.full((P, P), -1, dtype=np.int64)
        for i, path in enumerate(paths):
            unset = np.ones(P, dtype=bool)
            for r in path:
                hit = unset & self.path_mask[:, self.resource_index[r]]
                self.ncr_res[i, hit] = self.resource_index[r]
                unset &= ~hit
        self.ncr_rclass = np.where(self.ncr_res >= 0,
                                   self.resource_rclass[self.ncr_res.clip(0)],
                                   -1)

    # ------------------------------------------------------------------
    # build: all-pairs transfer over routable (GROUP) nodes
    # ------------------------------------------------------------------
    def _build_routes(self) -> None:
        g = self.graph
        self.routable_names: list[str] = [n.name for n in g.nodes.values()
                                          if n.kind is NodeKind.GROUP]
        self.routable_index: dict[str, int] = {n: i for i, n
                                               in enumerate(self.routable_names)}
        D = len(self.routable_names)
        self.trans_lat = np.full((D, D), np.inf)
        self.trans_ibw = np.zeros((D, D))
        np.fill_diagonal(self.trans_lat, 0.0)
        self._routes: dict[tuple[int, int], list[EdgeAttr]] = {}
        for i, src in enumerate(self.routable_names):
            if not g._adj[src]:
                continue
            dist, pred = g.sssp(src)
            for j, dst in enumerate(self.routable_names):
                if i == j or dst not in dist:
                    continue
                seq = [dst]
                while seq[-1] != src:
                    seq.append(pred[seq[-1]])
                seq.reverse()
                edges: list[EdgeAttr] = []
                for a, b in zip(seq, seq[1:]):
                    edges.append(min((e for v, e in g._adj[a] if v == b),
                                     key=lambda e: e.latency))
                self._routes[(i, j)] = edges
                self.trans_lat[i, j] = sum(e.latency for e in edges)
                bw = min((e.bandwidth for e in edges), default=float("inf"))
                self.trans_ibw[i, j] = 0.0 if bw == float("inf") else 1.0 / bw

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def device_name(self, name: str) -> str:
        """Enclosing device-group name (precomputed for PUs)."""
        dev = self._pu_device_name.get(name)
        if dev is None:
            return self.graph.device_of(name).name
        return dev

    def nearest_common_resource(self, pu_a: str, pu_b: str) -> Optional[str]:
        """First resource on ``pu_a``'s compute path also on ``pu_b``'s."""
        i = self.pu_index.get(pu_a)
        j = self.pu_index.get(pu_b)
        if i is None or j is None:
            # non-PU queries keep the object-path semantics
            g = self.graph
            pa = self.compute_paths[i] if i is not None else g.resource_path(pu_a)
            pb = set(self.compute_paths[j] if j is not None
                     else g.resource_path(pu_b))
            return next((r for r in pa if r in pb), None)
        r = self.ncr_res[i, j]
        return self.resource_names[r] if r >= 0 else None

    def transfer_time(self, src: str, dst: str, nbytes: float) -> float:
        """Parity twin of ``HWGraph.transfer_time`` (KeyError when no path)."""
        if src == dst:
            return 0.0
        i = self.routable_index.get(src)
        j = self.routable_index.get(dst)
        if i is None or j is None:
            return self.graph.transfer_time(src, dst, nbytes)
        lat = self.trans_lat[i, j]
        if not np.isfinite(lat):
            raise KeyError(f"no path {src} -> {dst}")
        return float(lat + (nbytes * self.trans_ibw[i, j] if nbytes > 0 else 0.0))

    def route_edges(self, src: str, dst: str) -> list[EdgeAttr]:
        """The shortest-path interconnects src -> dst (shared EdgeAttr refs,
        so concurrent transfers keep contending on the same objects)."""
        i = self.routable_index.get(src)
        j = self.routable_index.get(dst)
        if i is None or j is None:
            return self.graph.route_edges(src, dst)
        if i == j:
            return []
        edges = self._routes.get((i, j))
        if edges is None:
            raise KeyError(f"no path {src} -> {dst}")
        return edges

    def summary(self) -> str:
        P = len(self.pu_names)
        return (f"CompiledHWGraph({P} PUs, {len(self.resource_names)} resources, "
                f"{len(self.rclass_names)} rclasses, "
                f"{len(self.routable_names)} routable)")
