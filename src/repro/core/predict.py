"""Modular performance-model interface (paper §3.3 ``predict()``).

The paper's design principle: *different performance prediction models could
be integrated in a modular way* — empirical profiling, roofline, ML-based,
analytic.  Each model implements ``predict(task, pu, unit) -> float``.

Two concrete families are provided:

* ``ProfiledModel`` — a lookup table of standalone execution times per
  (task kind, PU), scaled by ``task.size``.  This is what the paper uses for
  its experiments ("we use profiling and record execution times of each TASK
  ... for every target PU").

* ``RooflineModel`` — three-term roofline used for the TPU-fleet adaptation:
  seconds = max(flops/peak_flops, bytes/mem_bw, coll_bytes/link_bw).
  The per-task flops/bytes come from ``task.attrs`` (filled from the compiled
  dry-run artifact or from analytic layer math).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .hwgraph import ProcessingUnit, Unit
from .task import Task


class PerfModel:
    def predict(self, task: Task, pu: ProcessingUnit, unit: Unit = Unit.SECONDS) -> float:
        raise NotImplementedError

    def supports(self, task: Task, pu: ProcessingUnit) -> bool:
        """Whether this PU can run this task kind at all."""
        try:
            self.predict(task, pu)
            return True
        except KeyError:
            return False


@dataclass
class ProfiledModel(PerfModel):
    """table[(task.kind, pu.name)] -> standalone seconds at size=1.0.

    ``fallback_by_class`` allows tables keyed by a PU *class* attribute
    (e.g. all "orin_agx.gpu"-class PUs share one profile) so fleets with
    thousands of identical devices need one profile per device type —
    exactly how the paper scales its simulations from individual profiles.
    """

    table: dict[tuple[str, str], float] = field(default_factory=dict)
    scaling: str = "linear"        # how seconds scale with task.size

    def key_for(self, task: Task, pu: ProcessingUnit) -> tuple[str, str]:
        cls = pu.attrs.get("pu_class", pu.name)
        if (task.kind, cls) in self.table:
            return (task.kind, cls)
        return (task.kind, pu.name)

    def predict(self, task: Task, pu: ProcessingUnit, unit: Unit = Unit.SECONDS) -> float:
        if unit is not Unit.SECONDS:
            raise ValueError(f"ProfiledModel only predicts SECONDS, not {unit}")
        base = self.table[self.key_for(task, pu)]
        if self.scaling == "linear":
            return base * task.size
        if self.scaling == "const":
            return base
        raise ValueError(f"unknown scaling {self.scaling!r}")

    def supports(self, task: Task, pu: ProcessingUnit) -> bool:
        cls = pu.attrs.get("pu_class", pu.name)
        return (task.kind, cls) in self.table or (task.kind, pu.name) in self.table


@dataclass
class RooflineModel(PerfModel):
    """Three-term roofline against the PU's hardware attrs.

    PU attrs used: ``peak_flops`` (FLOP/s), ``mem_bw`` (B/s), ``link_bw``
    (B/s aggregate off-chip).  Task attrs used: ``flops``, ``bytes``,
    ``coll_bytes`` (any may be absent -> term is 0).
    """

    def predict(self, task: Task, pu: ProcessingUnit, unit: Unit = Unit.SECONDS) -> float:
        flops = task.attrs.get("flops", 0.0) * task.size
        nbytes = task.attrs.get("bytes", 0.0) * task.size
        coll = task.attrs.get("coll_bytes", 0.0) * task.size
        if unit is Unit.FLOPS:
            return flops
        if unit is Unit.BYTES:
            return nbytes
        t_c = flops / pu.attrs["peak_flops"] if flops else 0.0
        t_m = nbytes / pu.attrs["mem_bw"] if nbytes else 0.0
        t_l = coll / pu.attrs["link_bw"] if coll else 0.0
        if t_c == t_m == t_l == 0.0:
            raise KeyError(f"task {task.kind} carries no cost attrs for roofline")
        return max(t_c, t_m, t_l)

    def supports(self, task: Task, pu: ProcessingUnit) -> bool:
        has_cost = any(k in task.attrs for k in ("flops", "bytes", "coll_bytes"))
        has_hw = "peak_flops" in pu.attrs and "mem_bw" in pu.attrs
        return has_cost and has_hw


@dataclass
class CallableModel(PerfModel):
    """Adapter for arbitrary analytic/learned predictors."""

    fn: Callable[[Task, ProcessingUnit, Unit], float]

    def predict(self, task: Task, pu: ProcessingUnit, unit: Unit = Unit.SECONDS) -> float:
        return self.fn(task, pu, unit)
