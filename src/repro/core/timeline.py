"""Array-native discrete-event timeline engine (paper §3.4, Alg. 2).

``TimelineEngine`` is the struct-of-arrays successor of the seed's
per-job ``heapq`` event loop (kept verbatim as
``Traverser.traverse_reference`` — the parity oracle and the benchmark
baseline).  The contention-interval semantics are identical; what
changes is the representation and the unit of work:

* **Dense job tables** — every compute job and transfer lives in numpy
  columns (remaining virtual work ``W``, progress ``rate``, last-settle
  time ``t_last``, projected completion ``eta``, device/PU ordinals,
  dependency counts) instead of per-job Python objects with
  version-stamped heap events.  Completion detection is an array
  compare against the shared timestamp, not a heap pop per job — the
  seed's biggest scaling cost (a fresh completion event per pool member
  per reprice) disappears entirely.
* **Per-timestamp draining** — all events sharing one timestamp drain
  before a single flush reprices the devices/links they touched
  (frontier batching, as in the seed), but the settle of every
  completion across all devices is **one array op** (the rate-advance
  kernel), and the flush reprices *every* dirty device pool in **one**
  ``factor_batch_idx`` call: compute paths never cross device
  boundaries, so the joint factors of the union pool are exactly the
  per-device factors (block-diagonal by construction).
* **Batched link repricing** — concurrent transfers share link
  bandwidth; the bottleneck share of each affected transfer is a
  segment-min over its route edges (the segment-min kernel), evaluated
  for the whole dirty set at once.

The two inner loops run as float64 numpy by default on every backend —
the parity bound is a hard 1e-9 and the per-flush batches are
memory-bound — with Pallas twins in ``kernels/timeline_kernel.py``
(oracle-checked) for TPU-resident pipelines that accept fp32 settles:
``REPRO_TIMELINE_KERNEL=pallas`` routes the engine through them (jax is
never imported otherwise, so pure-DES workflows stay jax-free).

**Interventions** (topology churn mid-run): ``traverse(...,
interventions=[(t, fn), ...])`` applies each ``fn()`` (e.g.
``graph.set_bandwidth`` / ``mark_dead``) at simulated time ``t`` and
reprices every active device pool and link set at that instant.  Both
engines implement the hook identically, so churn runs stay pinned to
the 1e-9 parity bound.

**Resident mode** (the serving path): ``TimelineEngine.open(...)``
brings an engine live without draining it, ``advance(until)`` drains
every event up to a wall-clock ``now`` and parks there, and
``inject(tasks)`` lands newly mapped work in the live job/transfer
tables mid-run — new rows append to the struct-of-arrays columns
(growable, +inf eta fill), releases enter the same event heap, and any
output handed over by an already-finished producer is priced by the
same one-flush reprice path as churn interventions.  Submitting a full
workload upfront through a resident engine reproduces ``run()`` (and
therefore the seed loop) to 1e-9: ingest builds the identical tables
and event sequence.  ``drain_finished``/``finish_of``/
``timeline(partial=True)`` observe progress without disturbing it; see
``docs/serving.md``.

Noise semantics: the ground-truth engine draws per-task irregularity
noise at job start, in event order — the array engine preserves the
draw order of the seed loop (timed events in push order, completions in
key order; the reference's simultaneous-event tie-break is pinned to
the same key order).  A *noisy slowdown model* (rng-bearing
``DecoupledSlowdown``) additionally draws inside ``factor()`` in pool
order; ``Traverser.traverse`` routes that configuration to the
reference loop so the rng stream stays byte-identical.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .hwgraph import EdgeAttr, ProcessingUnit
from .task import Task, TaskGraph

# settle tolerances of the seed event loop (virtual work residue below
# which a projected completion is real, not a stale float artifact)
CTOL = 1e-15        # compute jobs
XTOL = 1e-6         # transfers (bytes)


@dataclass
class Timeline:
    """Result of a CFG traverse."""

    start: dict[int, float] = field(default_factory=dict)      # task.uid -> t
    finish: dict[int, float] = field(default_factory=dict)
    ready: dict[int, float] = field(default_factory=dict)      # deps resolved at
    standalone: dict[int, float] = field(default_factory=dict)
    comm: dict[int, float] = field(default_factory=dict)       # inbound comm time
    queue_wait: dict[int, float] = field(default_factory=dict)
    mapping: dict[int, str] = field(default_factory=dict)
    n_intervals: int = 0
    n_events: int = 0        # drained DES events (timed + completions)

    @property
    def makespan(self) -> float:
        return max(self.finish.values(), default=0.0)

    def latency(self, task: Task) -> float:
        """Ready-to-finish latency (comm + queueing + slowdown + compute).

        'Ready' = dependencies resolved (or release time for roots) — the
        moment the paper's runtime hands the task to the Orchestrator."""
        t0 = self.ready.get(task.uid, task.release_time)
        return self.finish[task.uid] - t0

    def slowdown_of(self, task: Task) -> float:
        busy = self.finish[task.uid] - self.start[task.uid]
        sa = self.standalone[task.uid]
        return busy / sa if sa > 0 else 1.0

    def deadline_met(self, task: Task) -> bool:
        if task.deadline is None:
            return True
        return self.latency(task) <= task.deadline * (1 + 1e-9)


# ---------------------------------------------------------------------------
# kernel dispatch: rate-advance + segment-min (numpy refs inline so pure-DES
# workflows never import jax; Pallas on a live TPU backend)
# ---------------------------------------------------------------------------
def _rate_advance_np(W: np.ndarray, rate: np.ndarray, t_last: np.ndarray,
                     now: float) -> tuple[np.ndarray, np.ndarray]:
    """Settle virtual work to ``now`` and project completion times.

    Mirrors the seed's scalar ``settle`` + completion push exactly,
    including the float corner the scalar path has: ``max(0.0, W -
    inf*0.0)`` is ``0.0`` under Python's ``max`` (nan compares false),
    so nan residues clamp to zero here too.  ``eta`` is
    ``now + W'/rate`` where the rate is positive, +inf otherwise."""
    with np.errstate(invalid="ignore"):      # inf-rate x zero-dt corner
        raw = W - rate * (now - t_last)
    W2 = np.maximum(0.0, raw)
    nan = np.isnan(raw)
    if nan.any():
        W2[nan] = 0.0
    eta = np.divide(W2, rate, out=np.full(len(W2), np.inf),
                    where=rate > 0.0)
    eta += now
    return W2, eta


def _segment_min_np(values: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Per-segment min of ``values`` split into consecutive runs of
    ``counts[i]`` elements; empty segments yield +inf (an edgeless
    transfer is latency-only, i.e. unthrottled)."""
    out = np.full(len(counts), np.inf)
    nz = counts > 0
    if nz.any():
        starts = np.cumsum(counts) - counts
        out[nz] = np.minimum.reduceat(values, starts[nz])
    return out


_RATE_ADVANCE = None
_SEGMENT_MIN = None


def _select_kernels():
    """``auto`` keeps the float64 numpy settles on every backend: the DES
    parity contract is a hard 1e-9 bound against the seed loop, which the
    fp32 Pallas kernels cannot guarantee, and the per-flush batches are
    memory-bound (device offload is a round-trip, not a win).  The
    kernels remain reachable with ``REPRO_TIMELINE_KERNEL=pallas`` for
    TPU-resident pipelines that accept fp32 settles."""
    import os
    mode = os.environ.get("REPRO_TIMELINE_KERNEL", "auto").lower()
    if mode == "pallas":
        from ..kernels import timeline_kernel as tk
        return tk.rate_advance_forced, tk.segment_min_forced
    return _rate_advance_np, _segment_min_np


def _rate_advance(W, rate, t_last, now):
    global _RATE_ADVANCE, _SEGMENT_MIN
    if _RATE_ADVANCE is None:
        _RATE_ADVANCE, _SEGMENT_MIN = _select_kernels()
    return _RATE_ADVANCE(W, rate, t_last, now)


def _segment_min(values, counts):
    global _RATE_ADVANCE, _SEGMENT_MIN
    if _SEGMENT_MIN is None:
        _RATE_ADVANCE, _SEGMENT_MIN = _select_kernels()
    return _SEGMENT_MIN(values, counts)


def _settle_pos(W: np.ndarray, rate: np.ndarray, t_last: np.ndarray,
                now: float) -> np.ndarray:
    """Settle-only fast path for compute jobs: rates are 1/factor, always
    finite-positive, so the nan/inf corners of the full kernel cannot
    occur and eta is left to the caller."""
    return np.maximum(0.0, W - rate * (now - t_last))


def warm_transfer_routes(comp, cfg: TaskGraph, mapping: dict) -> int:
    """Batch-materialize every route row a traverse of ``cfg`` under
    ``mapping`` can touch: origins of root tasks with off-device initial
    payloads, and producer devices with off-device consumers.

    Both DES engines call this at traverse start, which restores the
    seed's frozen-route semantics under mid-run churn: all transfer
    routes are derived from the pre-churn topology, never lazily against
    a mutated graph (unroutable pairs stay quiet here and raise at
    launch time, as the seed did).  Returns the number of rows built."""
    srcs: set[str] = set()
    for t in cfg:
        dev = comp.device_name(mapping[t.uid])
        if (t.origin is not None and t.input_bytes > 0
                and not cfg.preds(t) and t.origin != dev):
            srcs.add(t.origin)
        if t.output_bytes > 0 and any(
                comp.device_name(mapping[s.uid]) != dev
                for s in cfg.succs(t)):
            srcs.add(dev)
    ensure = getattr(comp, "ensure_routes", None)
    if srcs and ensure is not None:
        return ensure(srcs)
    return 0


# timed-event kinds, ordered only by (time, push seq) like the seed heap
_INTERVENE, _RELEASE, _ARRIVE = 0, 1, 2

_ONE = np.ones(1)


class TimelineEngine:
    """A DES timeline over SoA state: one-shot (``run()``) or resident
    (``open``/``advance``/``inject``).

    Instantiated per ``Traverser.traverse`` call — or opened once per
    ``SchedulerSession`` for online serving; the engine freezes the
    compiled snapshot for transfer routes/device names (seed semantics)
    while slowdown factors read the *live* compiled snapshot through the
    model — exactly like the seed loop — so interventions that patch the
    topology take effect at the next contention-interval boundary.

    Representation notes: columns consumed by vectorized settles and the
    repricing kernels are numpy; columns only ever read one scalar at a
    time inside event handlers are plain Python lists (a numpy scalar
    index costs ~10x a list index, and handlers run once per event).
    """

    def __init__(self, traverser, cfg: TaskGraph, mapping: dict[int, str],
                 background: Sequence[tuple[Task, str, float]] = (),
                 interventions: Sequence[tuple[float, Callable[[], Any]]] = (),
                 ) -> None:
        self.trav = traverser
        self.graph = traverser.graph
        self.slowdown = traverser.slowdown
        self.noise = traverser.noise
        self.rng = traverser.rng
        self.cfg = cfg
        self.mapping = mapping
        self.background = list(background)
        self.interventions = list(interventions)
        self._opened = False

    # -- setup --------------------------------------------------------------
    _JCAP0 = 64         # initial job-table capacity (doubles on growth)

    def _jgrow(self, cap: int) -> None:
        """Grow the numpy job columns to ``cap`` slots.  The tail fill of
        ``eta`` is +inf so whole-array scans (``eta.min()``, the
        completion compare) never see unused capacity."""
        for col, fill in (("W", 0.0), ("rate", 1.0), ("t_last", 0.0),
                          ("eta", np.inf), ("U", 1.0), ("memraw", 1.0)):
            old = getattr(self, col, None)
            arr = np.full(cap, fill)
            if old is not None:
                arr[:len(old)] = old
            setattr(self, col, arr)
        for col in ("cstamp", "pu_i", "uid_col"):
            old = getattr(self, col, None)
            arr = np.zeros(cap, dtype=np.int64)
            if old is not None:
                arr[:len(old)] = old
            setattr(self, col, arr)

    def _init_state(self) -> None:
        g = self.graph
        comp = g.compiled()          # frozen: routes + device name space
        self.comp = comp
        self.n = 0
        self.slot_of: dict[int, int] = {}
        self._jgrow(self._JCAP0)
        self.pu_il: list[int] = []
        self.dev_ol: list[int] = []
        self.dev_name: list[str] = []
        self.pu_name: list[str] = []
        self.allt: list[Task] = []
        self.is_bg: list[bool] = []
        self.uidl: list[int] = []
        # generated workloads hand tasks over in uid order: slot order IS
        # uid order and the per-flush pool sorts drop the Python key fn
        self._uid_monotone = True
        self.irr: list[float] = []
        self.rel: list[float] = []
        self.in_bytes: list[float] = []
        self.sa: list[float] = []
        self.preds: list[list[int]] = []
        self.succs: list[list[int]] = []
        self.waiting: list[int] = []
        # reprice stamps emulate the reference heap's push sequence so
        # *simultaneous* completions settle in the seed's event order
        # (noise draw order is observable); see _complete_* argsorts
        self._stamp = 0
        # timeline columns
        self.start: list[float] = []
        self.finish: list[float] = []
        self.standalone: list[float] = []
        self.ready_t: list[float] = []
        self.comm_t: list[float] = []
        self.qwait: list[float] = []
        self.ready_at: list[float] = []
        # completion log for resident consumers (``drain_finished``)
        self._finish_log: list[int] = []
        self._finish_cursor = 0
        # tenancy
        self.pu_running = [0] * len(comp.pu_names)
        self.max_ten = comp.max_tenancy.tolist()
        self.pu_queue: dict[int, deque] = {}
        # device pools + repricing dirt
        self.dev_members: dict[int, set[int]] = {}
        self.dirty_devs: set[int] = set()
        self.dirty_edges: set[int] = set()
        self.n_intervals = 0
        self.n_events = 0
        # transfers (growable SoA) + edge table
        self.xcols = ("xW", "xrate", "xt_last", "xeta", "xlat")
        self._xgrow(64)
        self.xn = 0
        self.xlive = 0
        self.xconsumer: list[int] = []
        # per-transfer route edges in CSR form: xe_flat[xe_start[k] :
        # xe_start[k] + xe_cnt[k]] are transfer k's edge indices, so the
        # link-repricing flush gathers the whole dirty set's edge lists
        # with vectorized index math instead of per-transfer Python
        self.xe_flat = np.zeros(256, dtype=np.int64)
        self.xe_top = 0
        self.xe_start: list[int] = []
        self.xe_cnt: list[int] = []
        self._xe_start_arr: Optional[np.ndarray] = None
        self.edge_idx: dict[int, int] = {}
        self.edge_objs: list[EdgeAttr] = []
        self.edge_bw: list[float] = []
        self._edge_bw_arr: Optional[np.ndarray] = None
        self.edge_members: list[int] = []
        self.edge_xfers: dict[int, set[int]] = {}
        self.route_cache: dict[tuple[str, str], tuple[np.ndarray, float]] = {}
        # timed events
        self.heap: list[tuple[float, int, int, Any]] = []
        self.seq = itertools.count()
        self.time = 0.0
        # factor path: array-native when the model exposes ledger-column
        # scoring; otherwise per-device pools through the tuple surface
        self._fbi = getattr(self.slowdown, "factor_batch_idx", None)
        # memoized repricing: a pool's joint factors depend only on the
        # multiset of (PU, pu-usage, mem-usage) columns (uids are distinct
        # by construction — one job per task), so steady-state pools that
        # recur across readings/devices hit a canonical-order cache
        # instead of re-running the factor kernel.  Keyed per compiled
        # snapshot: topology churn drops the cache with the snapshot.
        self._fcache: dict = {}
        self._fcache_comp = None

    def _ingest(self, new_tasks: Sequence[Task]) -> None:
        """Append ``new_tasks`` to the live job tables.

        Dependencies must point at tasks in this batch or at ones already
        ingested (inject producers before — or together with — their
        consumers).  A producer that already *finished* hands its output
        over at the current instant: the cross-device transfer launches
        now and is priced by the caller's flush, exactly the churn
        repricing path."""
        cfg, mapping, g, comp = self.cfg, self.mapping, self.graph, self.comp
        base = self.n
        need = base + len(new_tasks)
        if need > len(self.W):
            cap = len(self.W)
            while cap < need:
                cap *= 2
            self._jgrow(cap)
        slot_of = self.slot_of
        last_uid = self.uidl[-1] if self.uidl else None
        mono = self._uid_monotone
        nan = float("nan")
        for i, t in enumerate(new_tasks):
            s = base + i
            if t.uid in slot_of:
                raise ValueError(f"{t} is already in the timeline")
            if t.uid not in mapping:
                raise KeyError(f"{t} has no mapping")
            pu_name = mapping[t.uid]
            pu = g.nodes[pu_name]
            assert isinstance(pu, ProcessingUnit), pu_name
            slot_of[t.uid] = s
            p = int(comp.pu_index[pu_name])
            self.pu_i[s] = p
            self.pu_il.append(p)
            d = int(comp.pu_dev_ord[p])
            self.dev_ol.append(d)
            self.dev_name.append(comp.dev_ord_names[d])
            self.pu_name.append(comp.pu_names[p])
            self.allt.append(t)
            self.is_bg.append(False)
            self.uid_col[s] = t.uid
            if mono and last_uid is not None and t.uid <= last_uid:
                mono = False
            last_uid = t.uid
            self.uidl.append(t.uid)
            self.U[s] = t.usage.get("pu", 1.0)
            self.memraw[s] = t.usage.get("mem", 1.0)
            self.irr.append(t.attrs.get("irregularity", 1.0))
            self.rel.append(t.release_time)
            self.in_bytes.append(t.input_bytes)
            # standalone predictions are pure per (task, PU)
            self.sa.append(g.nodes[pu_name].predict(t))
            self.W[s] = 0.0
            self.rate[s] = 1.0
            self.t_last[s] = 0.0
            self.eta[s] = np.inf
            self.cstamp[s] = 0
            for col in (self.start, self.finish, self.standalone,
                        self.ready_t, self.comm_t, self.qwait,
                        self.ready_at):
                col.append(nan)
        self._uid_monotone = mono
        self.n = need
        # dependency structure as slot lists: within-batch edges are wired
        # from cfg order (one-shot parity); cross-batch producers get this
        # consumer appended to their successor lists
        done_preds: list[tuple[int, int]] = []
        for i, t in enumerate(new_tasks):
            s = base + i
            pl: list[int] = []
            for pt in cfg.preds(t):
                ps = slot_of.get(pt.uid)
                if ps is None:
                    raise ValueError(
                        f"dependency {pt} of {t} is not in the timeline — "
                        "inject producers before (or together with) their "
                        "consumers")
                pl.append(ps)
                if ps < base:
                    self.succs[ps].append(s)
                    if self.finish[ps] == self.finish[ps]:   # already done
                        done_preds.append((s, ps))
            self.preds.append(pl)
            self.succs.append([slot_of[x.uid] for x in cfg.succs(t)
                               if slot_of.get(x.uid, -1) >= base])
            self.waiting.append(len(pl) + 1)   # +1: release event
        # pre-churn route freeze, batched per ingest (the incremental form
        # of warm_transfer_routes): origins of roots with off-device input
        # payloads, producer devices with off-device consumers
        srcs: set[str] = set()
        for i, t in enumerate(new_tasks):
            s = base + i
            dev = self.dev_name[s]
            if (t.origin is not None and t.input_bytes > 0
                    and not self.preds[s] and t.origin != dev):
                srcs.add(t.origin)
            if t.output_bytes > 0 and any(
                    self.dev_name[ss] != dev for ss in self.succs[s]):
                srcs.add(dev)
            for ps in self.preds[s]:
                if ps < base and self.allt[ps].output_bytes > 0 \
                        and self.dev_name[ps] != dev:
                    srcs.add(self.dev_name[ps])
        ensure = getattr(comp, "ensure_routes", None)
        if srcs and ensure is not None:
            ensure(srcs)
        # producers that finished before this batch arrived hand their
        # output over now; the release event still gates readiness (the
        # waiting floor is 1 until it drains), so a direct decrement never
        # starts compute early
        for s, ps in done_preds:
            ob = self.allt[ps].output_bytes
            if not self._launch(s, self.dev_name[ps], self.dev_name[s], ob):
                self.waiting[s] -= 1

    def _ingest_background(self) -> None:
        """Background jobs occupy their PU from t=0 with known remaining
        standalone work; they have no deps, releases, or successors."""
        comp = self.comp
        base = self.n
        need = base + len(self.background)
        if need > len(self.W):
            cap = len(self.W)
            while cap < need:
                cap *= 2
            self._jgrow(cap)
        last_uid = self.uidl[-1] if self.uidl else None
        mono = self._uid_monotone
        nan = float("nan")
        for k, (bt, bpu, brem) in enumerate(self.background):
            s = base + k
            self.slot_of[bt.uid] = s
            p = int(comp.pu_index[bpu])
            self.pu_i[s] = p
            self.pu_il.append(p)
            d = int(comp.pu_dev_ord[p])
            self.dev_ol.append(d)
            self.dev_name.append(comp.dev_ord_names[d])
            self.pu_name.append(comp.pu_names[p])
            self.allt.append(bt)
            self.is_bg.append(True)
            self.uid_col[s] = bt.uid
            if mono and last_uid is not None and bt.uid <= last_uid:
                mono = False
            last_uid = bt.uid
            self.uidl.append(bt.uid)
            self.U[s] = bt.usage.get("pu", 1.0)
            self.memraw[s] = bt.usage.get("mem", 1.0)
            self.irr.append(bt.attrs.get("irregularity", 1.0))
            self.rel.append(bt.release_time)
            self.in_bytes.append(0.0)
            self.sa.append(brem)
            self.preds.append([])
            self.succs.append([])
            self.waiting.append(0)
            # running from t=0: occupy the PU and dirty its device pool
            self.W[s] = brem
            self.rate[s] = 1.0
            self.t_last[s] = 0.0
            self.eta[s] = np.inf
            for col, v in ((self.start, 0.0), (self.finish, nan),
                           (self.standalone, brem), (self.ready_t, nan),
                           (self.comm_t, nan), (self.qwait, nan),
                           (self.ready_at, nan)):
                col.append(v)
            self.pu_running[p] += 1
            m = self.dev_members.get(d)
            if m is None:
                m = self.dev_members[d] = set()
            m.add(s)
            self.dirty_devs.add(d)
        self._uid_monotone = mono
        self.n = need

    def _xgrow(self, cap: int) -> None:
        for col in self.xcols:
            old = getattr(self, col, None)
            fill = np.inf if col == "xeta" else 0.0
            arr = np.full(cap, fill)
            if old is not None:
                arr[:len(old)] = old
            setattr(self, col, arr)
        old = getattr(self, "xstamp", None)
        self.xstamp = np.zeros(cap, dtype=np.int64)
        if old is not None:
            self.xstamp[:len(old)] = old

    def _push(self, t: float, kind: int, payload: Any) -> None:
        heapq.heappush(self.heap, (t, next(self.seq), kind, payload))

    # -- job lifecycle ------------------------------------------------------
    def _start_compute(self, s: int) -> None:
        p = self.pu_il[s]
        if self.pu_running[p] >= self.max_ten[p]:
            q = self.pu_queue.get(p)
            if q is None:
                q = self.pu_queue[p] = deque()
            q.append(s)
            return
        self.pu_running[p] = self.pu_running[p] + 1
        sa = self.sa[s]
        work = sa
        if self.noise > 0.0:
            work = sa * float(np.exp(self.rng.normal(
                0.0, self.noise * self.irr[s])))
        t = self.time
        self.W[s] = work
        self.rate[s] = 1.0
        self.t_last[s] = t
        self.start[s] = t
        self.standalone[s] = sa
        ra = self.ready_at[s]
        self.qwait[s] = t - (ra if ra == ra else self.rel[s])
        d = self.dev_ol[s]
        m = self.dev_members.get(d)
        if m is None:
            m = self.dev_members[d] = set()
        m.add(s)
        self.dirty_devs.add(d)

    def _route(self, src: str, dst: str) -> tuple[np.ndarray, float]:
        key = (src, dst)
        hit = self.route_cache.get(key)
        if hit is None:
            edges = self.comp.route_edges(src, dst)
            idxs = np.empty(len(edges), dtype=np.int64)
            lat = 0.0
            for i, e in enumerate(edges):
                ei = self.edge_idx.get(id(e))
                if ei is None:
                    ei = len(self.edge_objs)
                    self.edge_idx[id(e)] = ei
                    self.edge_objs.append(e)
                    self.edge_bw.append(e.bandwidth)
                    self.edge_members.append(0)
                    self._edge_bw_arr = None
                idxs[i] = ei
                lat += e.latency
            hit = self.route_cache[key] = (idxs, lat)
        return hit

    def _launch(self, consumer: int, src_dev: str, dst_dev: str,
                nbytes: float) -> bool:
        """Start a transfer for ``consumer``'s input; False = local/no data."""
        if src_dev == dst_dev or nbytes <= 0:
            return False
        eidx, lat = self._route(src_dev, dst_dev)
        k = self.xn
        if k == len(self.xW):
            self._xgrow(2 * k)
        self.xn = k + 1
        self.xlive += 1
        self.xW[k] = nbytes
        self.xrate[k] = 1.0
        self.xt_last[k] = self.time
        self.xeta[k] = np.inf          # priced at the flush
        self.xlat[k] = lat
        self.xconsumer.append(consumer)
        ne = len(eidx)
        top = self.xe_top
        if top + ne > len(self.xe_flat):
            buf = np.zeros(max(2 * len(self.xe_flat), top + ne),
                           dtype=np.int64)
            buf[:top] = self.xe_flat[:top]
            self.xe_flat = buf
        self.xe_flat[top:top + ne] = eidx
        self.xe_start.append(top)
        self.xe_cnt.append(ne)
        self.xe_top = top + ne
        self._xe_start_arr = None
        dirty = self.dirty_edges
        members = self.edge_members
        xfers = self.edge_xfers
        for e in eidx.tolist():
            members[e] += 1
            xs = xfers.get(e)
            if xs is None:
                xs = xfers[e] = set()
            xs.add(k)
            dirty.add(e)
        return True

    def _arrived(self, s: int) -> None:
        w = self.waiting[s] - 1
        self.waiting[s] = w
        if w == 0:
            t = self.time
            self.ready_at[s] = t
            dep = self.rel[s]
            for p in self.preds[s]:
                f = self.finish[p]
                if f > dep:
                    dep = f
            self.ready_t[s] = dep
            self.comm_t[s] = t - dep
            self._start_compute(s)

    def _finish(self, s: int) -> None:
        t = self.time
        self.eta[s] = np.inf
        p = self.pu_il[s]
        self.pu_running[p] = self.pu_running[p] - 1
        self.finish[s] = t
        d = self.dev_ol[s]
        self.dev_members[d].discard(s)
        self._finish_log.append(s)
        # successors: dependency bookkeeping + inter-device transfers
        # (background slots carry empty successor lists)
        out_bytes = self.allt[s].output_bytes
        src = self.dev_name[s]
        for ss in self.succs[s]:
            if not self._launch(ss, src, self.dev_name[ss], out_bytes):
                self._arrived(ss)
        q = self.pu_queue.get(p)
        if q:
            self._start_compute(q.popleft())
        self.dirty_devs.add(d)

    # -- repricing ----------------------------------------------------------
    def _pool_factors(self, members: np.ndarray) -> np.ndarray:
        if self._fbi is not None:
            P = self.pu_i[members]
            n = len(P)
            if n == 1:
                return _ONE        # a lone job has no co-runners
            U = self.U[members]
            mem = self.memraw[members]
            if n == 2:             # pair pools: scalar path beats the cache
                return self._fbi(P, U, mem, self.uid_col[members])
            comp = self.graph.compiled()
            if comp is not self._fcache_comp:
                self._fcache_comp = comp
                self._fcache = {}
            order = np.lexsort((mem, U, P))
            key = (P[order].tobytes(), U[order].tobytes(),
                   mem[order].tobytes())
            hit = self._fcache.get(key)
            if hit is not None:
                out = np.empty(len(hit))
                out[order] = hit
                return out
            f = np.asarray(self._fbi(P, U, mem, self.uid_col[members]),
                           dtype=np.float64)
            self._fcache[key] = f[order].copy()
            return f
        # tuple fallback (custom slowdown models): per-device pools, like
        # the seed — cross-device interactions are not assumed absent
        out = np.empty(len(members))
        fb = getattr(self.slowdown, "factor_batch", None)
        allt = self.allt
        devs = np.asarray([self.dev_ol[m] for m in members.tolist()])
        for d in np.unique(devs):
            sel = np.nonzero(devs == d)[0]
            pool = [(allt[m], self.pu_name[m]) for m in members[sel]]
            if fb is not None:
                out[sel] = np.asarray(fb(pool), dtype=np.float64)
            else:
                out[sel] = [self.slowdown.factor(tk, pu, pool)
                            for tk, pu in pool]
        return out

    def _flush(self) -> bool:
        """Reprice every dirty device pool (one factor call) and every
        dirty link set (one segment-min).  Returns True when any rate was
        re-projected — i.e. when same-timestamp work may now exist."""
        t = self.time
        flushed = False
        if self.dirty_devs:
            self.n_intervals += len(self.dirty_devs)
            dm = self.dev_members
            # pool order replays the reference's completion-push sequence
            # (device name, then uid) so reprice stamps line up exactly
            names = self.comp.dev_ord_names
            uidl = self.uidl
            mem_list: list[int] = []
            if self._uid_monotone:
                for d in sorted(self.dirty_devs, key=names.__getitem__):
                    mem_list.extend(sorted(dm[d]))
            else:
                for d in sorted(self.dirty_devs, key=names.__getitem__):
                    mem_list.extend(sorted(dm[d], key=uidl.__getitem__))
            self.dirty_devs.clear()
            total = len(mem_list)
            if total:
                members = np.asarray(mem_list, dtype=np.int64)
                self.cstamp[members] = np.arange(
                    self._stamp, self._stamp + total)
                self._stamp += total
                factors = np.asarray(self._pool_factors(members),
                                     dtype=np.float64)
                W2 = _settle_pos(self.W[members], self.rate[members],
                                 self.t_last[members], t)
                rate = 1.0 / factors
                self.W[members] = W2
                self.t_last[members] = t
                self.rate[members] = rate
                self.eta[members] = t + W2 / rate
                flushed = True
        if self.dirty_edges:
            affected: set[int] = set()
            xfers = self.edge_xfers
            for e in self.dirty_edges:
                xs = xfers.get(e)
                if xs:
                    affected |= xs
            self.dirty_edges.clear()
            if affected:
                ks = np.fromiter(sorted(affected), dtype=np.int64,
                                 count=len(affected))
                self.xstamp[ks] = np.arange(self._stamp,
                                            self._stamp + len(ks))
                self._stamp += len(ks)
                if self._xe_start_arr is None:
                    self._xe_start_arr = np.asarray(self.xe_start,
                                                    dtype=np.int64)
                    self._xe_cnt_arr = np.asarray(self.xe_cnt,
                                                  dtype=np.int64)
                starts = self._xe_start_arr[ks]
                counts = self._xe_cnt_arr[ks]
                K = int(counts.sum())
                if K:
                    within = np.arange(K) - np.repeat(
                        np.cumsum(counts) - counts, counts)
                    flat = self.xe_flat[np.repeat(starts, counts) + within]
                else:
                    flat = np.zeros(0, dtype=np.int64)
                if self._edge_bw_arr is None:
                    self._edge_bw_arr = np.asarray(self.edge_bw)
                    self._edge_mem_arr = np.asarray(self.edge_members)
                else:
                    self._edge_mem_arr = np.asarray(self.edge_members)
                shares = self._edge_bw_arr[flat] / np.maximum(
                    1, self._edge_mem_arr[flat])
                bw = _segment_min(shares, counts)
                W2, _ = _rate_advance(self.xW[ks], self.xrate[ks],
                                      self.xt_last[ks], t)
                self.xW[ks] = W2
                self.xt_last[ks] = t
                self.xrate[ks] = bw
                eta = np.divide(W2, bw, out=np.full(len(ks), np.inf),
                                where=bw > 0.0)
                self.xeta[ks] = t + eta
                flushed = True
        return flushed

    def _intervene(self, fn) -> None:
        from .hwgraph import Churn
        is_churn = isinstance(fn, Churn)
        if is_churn:
            # declarative delta batch: apply through the consolidated
            # churn surface instead of calling into user code (bandwidth
            # entries coalesce into one snapshot overlay copy there)
            self.graph.apply_churn(fn)
        else:
            fn()
        # an intervention may mutate anything factors depend on (topology
        # OR model params): drop the memoized pool factors outright
        self._fcache = {}
        self._fcache_comp = None
        # churn boundary: reprice every occupied device pool and active
        # link set against the post-mutation model/bandwidths
        for d, members in self.dev_members.items():
            if members:
                self.dirty_devs.add(d)
        if is_churn and not (fn.dead or fn.alive):
            # bandwidth-only batch: the churn surface names exactly which
            # links moved (the snapshot overlay's dirty-link set), so
            # only those slots of the segment-min repricing input need a
            # refresh — every other edge's bandwidth is unchanged by
            # construction
            changed = {name for name, _ in fn.bandwidth}
            for i, e in enumerate(self.edge_objs):
                if e.name in changed:
                    self.edge_bw[i] = e.bandwidth
        else:
            for i, e in enumerate(self.edge_objs):
                self.edge_bw[i] = e.bandwidth
        self._edge_bw_arr = None
        for e, xs in self.edge_xfers.items():
            if xs:
                self.dirty_edges.add(e)

    # -- completions --------------------------------------------------------
    def _complete_compute(self, done: np.ndarray) -> None:
        t = self.time
        if len(done) > 1:   # simultaneous: settle in reprice-stamp order
            done = done[np.argsort(self.cstamp[done], kind="stable")]
        W2 = _settle_pos(self.W[done], self.rate[done],
                         self.t_last[done], t)
        self.W[done] = W2
        self.t_last[done] = t
        fin = W2 <= CTOL
        if not fin.all():   # float residue: keep running, fresh estimate
            resid = done[~fin]
            self.eta[resid] = t + self.W[resid] / self.rate[resid]
        self.n_events += len(done)
        for s in done[fin].tolist():
            self._finish(s)

    def _complete_transfers(self, done: np.ndarray) -> None:
        t = self.time
        if len(done) > 1:   # simultaneous: settle in reprice-stamp order
            done = done[np.argsort(self.xstamp[done], kind="stable")]
        W2, eta = _rate_advance(self.xW[done], self.xrate[done],
                                self.xt_last[done], t)
        self.xW[done] = W2
        self.xt_last[done] = t
        fin = W2 <= XTOL
        if not fin.all():
            resid = done[~fin]
            self.xeta[resid] = eta[~fin]
        self.n_events += len(done)
        members = self.edge_members
        for k in done[fin].tolist():
            self.xeta[k] = np.inf
            self.xlive -= 1
            st = self.xe_start[k]
            for e in self.xe_flat[st:st + self.xe_cnt[k]].tolist():
                members[e] -= 1
                self.edge_xfers[e].discard(k)
                self.dirty_edges.add(e)
            lat = float(self.xlat[k])
            if lat > 0:
                # latency tail: arrival after the fixed route latency
                self._push(t + lat, _ARRIVE, self.xconsumer[k])
            else:
                self._arrived(self.xconsumer[k])

    # -- lifecycle ----------------------------------------------------------
    def _start(self) -> None:
        """Bring the engine live: ingest the initial CFG + background jobs,
        price the opening intervals, and enqueue releases.  Event push
        order (interventions, then releases) replays the one-shot loop's
        sequence numbers exactly."""
        if self._opened:
            raise RuntimeError("TimelineEngine is already open")
        self._init_state()
        self._ingest(list(self.cfg))
        for t, fn in self.interventions:
            self._push(float(t), _INTERVENE, fn)
        self._ingest_background()
        self._flush()
        for t in self.cfg:
            self._push(t.release_time, _RELEASE, self.slot_of[t.uid])
        self._opened = True

    @classmethod
    def open(cls, traverser, cfg: Optional[TaskGraph] = None,
             mapping: Optional[dict[int, str]] = None,
             background: Sequence[tuple[Task, str, float]] = (),
             interventions: Sequence[tuple[float, Callable[[], Any]]] = (),
             ) -> "TimelineEngine":
        """Open a **session-resident** engine: live immediately, advanced
        incrementally (``advance``), and accepting ``inject`` mid-run.

        ``cfg``/``mapping`` may start empty (the serving case) or carry an
        initial workload; ``mapping`` is read live, so a dict shared with
        a ``SchedulerSession`` picks up later commits without copying.
        Noisy *slowdown models* (rng-bearing ``factor()``) are rejected:
        their draw stream only replays on the reference loop, which has
        no resident form."""
        eng = cls(traverser,
                  cfg if cfg is not None else TaskGraph("resident"),
                  mapping if mapping is not None else {},
                  background, interventions)
        noisy = getattr(eng.slowdown, "_noisy", None)
        if noisy is not None and noisy():
            raise ValueError(
                "resident timelines require a deterministic slowdown "
                "model (noisy factor() draws only replay on "
                "Traverser.traverse_reference)")
        eng._start()
        return eng

    def inject(self, tasks: Sequence[Task],
               mapping: Optional[dict[int, str]] = None) -> "TimelineEngine":
        """Land newly mapped work in the live job tables mid-run.

        Each task enters at its own ``release_time`` (>= the engine clock:
        injecting into the past would rewrite settled intervals).  Output
        handed over by an already-finished producer launches its transfer
        immediately and is priced by the same one-flush reprice path as
        churn interventions."""
        if not self._opened:
            raise RuntimeError(
                "inject() requires an open engine — TimelineEngine.open() "
                "or SchedulerSession.open_timeline()")
        tasks = list(tasks)
        if mapping:
            self.mapping.update(mapping)
        for t in tasks:
            if t.release_time < self.time:
                raise ValueError(
                    f"{t} releases at {t.release_time:.6g}, before the "
                    f"engine clock {self.time:.6g}")
        self._ingest(tasks)
        for t in tasks:
            self._push(t.release_time, _RELEASE, self.slot_of[t.uid])
        if self.dirty_devs or self.dirty_edges:
            self._flush()
        return self

    def schedule(self, t: float, fn) -> None:
        """Queue an intervention at simulated time ``t`` — the resident
        counterpart of the ``interventions=`` argument.  ``fn`` is either
        a zero-arg callable or a declarative :class:`~.hwgraph.Churn`
        delta batch."""
        self._push(float(t), _INTERVENE, fn)

    def apply_churn(self, churn) -> "TimelineEngine":
        """Apply a :class:`~.hwgraph.Churn` delta batch (or a zero-arg
        callable) at the current engine clock, through the same one-flush
        reprice path as scheduled interventions: mutate, drop memoized
        pool factors, reprice every occupied pool and active link set."""
        self._intervene(churn)
        self._flush()
        return self

    def finish_of(self, uid: int) -> float:
        """Finish time of task ``uid`` (nan while pending or running)."""
        s = self.slot_of.get(uid)
        return float("nan") if s is None else self.finish[s]

    def drain_finished(self) -> list[Task]:
        """Tasks that completed since the previous drain (background slots
        excluded) — the ledger-reconciliation feed for serving loops."""
        log = self._finish_log
        out = [self.allt[s] for s in log[self._finish_cursor:]
               if not self.is_bg[s]]
        self._finish_cursor = len(log)
        return out

    @property
    def live_jobs(self) -> int:
        """Compute jobs currently occupying a PU."""
        return int(sum(self.pu_running))

    def next_event_time(self) -> float:
        """Timestamp of the earliest pending event (compute finish,
        transfer finish, or heap entry), ``inf`` at quiescence — the same
        minimum :meth:`advance` computes before draining, so
        ``next_event_time() > until`` means ``advance(until)`` would only
        park the clock (serving loops use this to skip the call)."""
        em = float(self.eta.min()) if len(self.eta) else np.inf
        xm = float(self.xeta[:self.xn].min()) if self.xlive else np.inf
        t_next = self.heap[0][0] if self.heap else np.inf
        return min(em, xm, t_next)

    # -- main loop ----------------------------------------------------------
    def advance(self, until: float = np.inf) -> "TimelineEngine":
        """Drain every event with timestamp <= ``until``, then park the
        clock at ``until`` (when finite).  ``advance()`` with no bound
        drains to quiescence — the one-shot behaviour."""
        heap = self.heap
        eta = self.eta
        while True:
            em = float(eta.min()) if len(eta) else np.inf
            xm = float(self.xeta[:self.xn].min()) if self.xlive else np.inf
            t_next = heap[0][0] if heap else np.inf
            if em < t_next:
                t_next = em
            if xm < t_next:
                t_next = xm
            if t_next == np.inf or t_next > until:
                break
            if t_next > self.time:
                self.time = t_next
            time = self.time
            # all events at this timestamp drain before one flush reprices
            # what they touched; repeat while the flush re-projected rates
            # (zero-duration pileups surface as fresh same-time work)
            first = True
            while True:
                while heap and heap[0][0] <= time:
                    _, _, kind, payload = heapq.heappop(heap)
                    self.n_events += 1
                    if kind == _RELEASE:
                        s = payload
                        task = self.allt[s]
                        # initial input payload from the origin device
                        if (task.origin is not None and self.in_bytes[s] > 0
                                and not self.preds[s]):
                            if self._launch(s, task.origin, self.dev_name[s],
                                            self.in_bytes[s]):
                                continue
                        self._arrived(s)
                    elif kind == _ARRIVE:
                        self._arrived(payload)
                    else:
                        self._intervene(payload)
                if first or em <= time:
                    done = np.nonzero(eta <= time)[0]
                    if len(done):
                        self._complete_compute(done)
                if self.xlive and (first or xm <= time):
                    xdone = np.nonzero(self.xeta[:self.xn] <= time)[0]
                    if len(xdone):
                        self._complete_transfers(xdone)
                first = False
                if not self._flush():
                    break
                # a flush ran: re-projected rates may complete at `time`
                em = float(eta.min()) if len(eta) else np.inf
                xm = float(self.xeta[:self.xn].min()) if self.xlive \
                    else np.inf
                if em > time and xm > time and not (heap and
                                                    heap[0][0] <= time):
                    break
        if until != np.inf and until > self.time:
            self.time = until
        return self

    def run(self) -> Timeline:
        """One-shot traverse: open, drain to quiescence, report."""
        self._start()
        self.advance()
        return self._timeline()

    def timeline(self, partial: bool = False) -> Timeline:
        """Snapshot the timeline.  ``partial=True`` reports whatever has
        happened so far (pending/running tasks simply lack entries);
        ``partial=False`` asserts quiescence, as ``run()`` does."""
        return self._timeline(partial=partial)

    def _timeline(self, partial: bool = False) -> Timeline:
        if not partial:
            missing = [self.uidl[s] for s in range(self.n)
                       if not self.is_bg[s]
                       and self.finish[s] != self.finish[s]]
            if missing:
                raise RuntimeError(
                    f"traverse deadlock: unfinished {missing[:5]}")
        tl = Timeline(mapping=dict(self.mapping))
        tl.n_intervals = self.n_intervals
        tl.n_events = self.n_events
        for s in range(self.n):
            uid = self.uidl[s]
            if self.is_bg[s]:
                # background jobs may legitimately still be running; report
                # a projected finish assuming the final interval persists
                tl.start[uid] = self.start[s]
                tl.standalone[uid] = self.standalone[s]
                if not math.isnan(self.finish[s]):
                    tl.finish[uid] = self.finish[s]
                elif s in self.dev_members.get(self.dev_ol[s], ()):
                    tl.finish[uid] = self.time + float(self.W[s]
                                                       / self.rate[s])
                continue
            if not math.isnan(self.standalone[s]):
                tl.start[uid] = self.start[s]
                tl.standalone[uid] = self.standalone[s]
            if not math.isnan(self.finish[s]):
                tl.finish[uid] = self.finish[s]
            if not math.isnan(self.ready_t[s]):
                tl.ready[uid] = self.ready_t[s]
                tl.comm[uid] = self.comm_t[s]
            if not math.isnan(self.qwait[s]):
                tl.queue_wait[uid] = self.qwait[s]
        return tl
