"""Array-native discrete-event timeline engine (paper §3.4, Alg. 2).

``TimelineEngine`` is the struct-of-arrays successor of the seed's
per-job ``heapq`` event loop (kept verbatim as
``Traverser.traverse_reference`` — the parity oracle and the benchmark
baseline).  The contention-interval semantics are identical; what
changes is the representation and the unit of work:

* **Dense job tables** — every compute job and transfer lives in numpy
  columns (remaining virtual work ``W``, progress ``rate``, last-settle
  time ``t_last``, projected completion ``eta``, device/PU ordinals,
  dependency counts) instead of per-job Python objects with
  version-stamped heap events.  Completion detection is an array
  compare against the shared timestamp, not a heap pop per job — the
  seed's biggest scaling cost (a fresh completion event per pool member
  per reprice) disappears entirely.
* **Per-timestamp draining** — all events sharing one timestamp drain
  before a single flush reprices the devices/links they touched
  (frontier batching, as in the seed), but the settle of every
  completion across all devices is **one array op** (the rate-advance
  kernel), and the flush reprices *every* dirty device pool in **one**
  ``factor_batch_idx`` call: compute paths never cross device
  boundaries, so the joint factors of the union pool are exactly the
  per-device factors (block-diagonal by construction).
* **Batched link repricing** — concurrent transfers share link
  bandwidth; the bottleneck share of each affected transfer is a
  segment-min over its route edges (the segment-min kernel), evaluated
  for the whole dirty set at once.

The two inner loops run as float64 numpy by default on every backend —
the parity bound is a hard 1e-9 and the per-flush batches are
memory-bound — with Pallas twins in ``kernels/timeline_kernel.py``
(oracle-checked) for TPU-resident pipelines that accept fp32 settles:
``REPRO_TIMELINE_KERNEL=pallas`` routes the engine through them (jax is
never imported otherwise, so pure-DES workflows stay jax-free).

**Interventions** (topology churn mid-run): ``traverse(...,
interventions=[(t, fn), ...])`` applies each ``fn()`` (e.g.
``graph.set_bandwidth`` / ``mark_dead``) at simulated time ``t`` and
reprices every active device pool and link set at that instant.  Both
engines implement the hook identically, so churn runs stay pinned to
the 1e-9 parity bound.

Noise semantics: the ground-truth engine draws per-task irregularity
noise at job start, in event order — the array engine preserves the
draw order of the seed loop (timed events in push order, completions in
key order; the reference's simultaneous-event tie-break is pinned to
the same key order).  A *noisy slowdown model* (rng-bearing
``DecoupledSlowdown``) additionally draws inside ``factor()`` in pool
order; ``Traverser.traverse`` routes that configuration to the
reference loop so the rng stream stays byte-identical.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .hwgraph import EdgeAttr, ProcessingUnit
from .task import Task, TaskGraph

# settle tolerances of the seed event loop (virtual work residue below
# which a projected completion is real, not a stale float artifact)
CTOL = 1e-15        # compute jobs
XTOL = 1e-6         # transfers (bytes)


@dataclass
class Timeline:
    """Result of a CFG traverse."""

    start: dict[int, float] = field(default_factory=dict)      # task.uid -> t
    finish: dict[int, float] = field(default_factory=dict)
    ready: dict[int, float] = field(default_factory=dict)      # deps resolved at
    standalone: dict[int, float] = field(default_factory=dict)
    comm: dict[int, float] = field(default_factory=dict)       # inbound comm time
    queue_wait: dict[int, float] = field(default_factory=dict)
    mapping: dict[int, str] = field(default_factory=dict)
    n_intervals: int = 0
    n_events: int = 0        # drained DES events (timed + completions)

    @property
    def makespan(self) -> float:
        return max(self.finish.values(), default=0.0)

    def latency(self, task: Task) -> float:
        """Ready-to-finish latency (comm + queueing + slowdown + compute).

        'Ready' = dependencies resolved (or release time for roots) — the
        moment the paper's runtime hands the task to the Orchestrator."""
        t0 = self.ready.get(task.uid, task.release_time)
        return self.finish[task.uid] - t0

    def slowdown_of(self, task: Task) -> float:
        busy = self.finish[task.uid] - self.start[task.uid]
        sa = self.standalone[task.uid]
        return busy / sa if sa > 0 else 1.0

    def deadline_met(self, task: Task) -> bool:
        if task.deadline is None:
            return True
        return self.latency(task) <= task.deadline * (1 + 1e-9)


# ---------------------------------------------------------------------------
# kernel dispatch: rate-advance + segment-min (numpy refs inline so pure-DES
# workflows never import jax; Pallas on a live TPU backend)
# ---------------------------------------------------------------------------
def _rate_advance_np(W: np.ndarray, rate: np.ndarray, t_last: np.ndarray,
                     now: float) -> tuple[np.ndarray, np.ndarray]:
    """Settle virtual work to ``now`` and project completion times.

    Mirrors the seed's scalar ``settle`` + completion push exactly,
    including the float corner the scalar path has: ``max(0.0, W -
    inf*0.0)`` is ``0.0`` under Python's ``max`` (nan compares false),
    so nan residues clamp to zero here too.  ``eta`` is
    ``now + W'/rate`` where the rate is positive, +inf otherwise."""
    with np.errstate(invalid="ignore"):      # inf-rate x zero-dt corner
        raw = W - rate * (now - t_last)
    W2 = np.maximum(0.0, raw)
    nan = np.isnan(raw)
    if nan.any():
        W2[nan] = 0.0
    eta = np.divide(W2, rate, out=np.full(len(W2), np.inf),
                    where=rate > 0.0)
    eta += now
    return W2, eta


def _segment_min_np(values: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Per-segment min of ``values`` split into consecutive runs of
    ``counts[i]`` elements; empty segments yield +inf (an edgeless
    transfer is latency-only, i.e. unthrottled)."""
    out = np.full(len(counts), np.inf)
    nz = counts > 0
    if nz.any():
        starts = np.cumsum(counts) - counts
        out[nz] = np.minimum.reduceat(values, starts[nz])
    return out


_RATE_ADVANCE = None
_SEGMENT_MIN = None


def _select_kernels():
    """``auto`` keeps the float64 numpy settles on every backend: the DES
    parity contract is a hard 1e-9 bound against the seed loop, which the
    fp32 Pallas kernels cannot guarantee, and the per-flush batches are
    memory-bound (device offload is a round-trip, not a win).  The
    kernels remain reachable with ``REPRO_TIMELINE_KERNEL=pallas`` for
    TPU-resident pipelines that accept fp32 settles."""
    import os
    mode = os.environ.get("REPRO_TIMELINE_KERNEL", "auto").lower()
    if mode == "pallas":
        from ..kernels import timeline_kernel as tk
        return tk.rate_advance_forced, tk.segment_min_forced
    return _rate_advance_np, _segment_min_np


def _rate_advance(W, rate, t_last, now):
    global _RATE_ADVANCE, _SEGMENT_MIN
    if _RATE_ADVANCE is None:
        _RATE_ADVANCE, _SEGMENT_MIN = _select_kernels()
    return _RATE_ADVANCE(W, rate, t_last, now)


def _segment_min(values, counts):
    global _RATE_ADVANCE, _SEGMENT_MIN
    if _SEGMENT_MIN is None:
        _RATE_ADVANCE, _SEGMENT_MIN = _select_kernels()
    return _SEGMENT_MIN(values, counts)


def _settle_pos(W: np.ndarray, rate: np.ndarray, t_last: np.ndarray,
                now: float) -> np.ndarray:
    """Settle-only fast path for compute jobs: rates are 1/factor, always
    finite-positive, so the nan/inf corners of the full kernel cannot
    occur and eta is left to the caller."""
    return np.maximum(0.0, W - rate * (now - t_last))


def warm_transfer_routes(comp, cfg: TaskGraph, mapping: dict) -> int:
    """Batch-materialize every route row a traverse of ``cfg`` under
    ``mapping`` can touch: origins of root tasks with off-device initial
    payloads, and producer devices with off-device consumers.

    Both DES engines call this at traverse start, which restores the
    seed's frozen-route semantics under mid-run churn: all transfer
    routes are derived from the pre-churn topology, never lazily against
    a mutated graph (unroutable pairs stay quiet here and raise at
    launch time, as the seed did).  Returns the number of rows built."""
    srcs: set[str] = set()
    for t in cfg:
        dev = comp.device_name(mapping[t.uid])
        if (t.origin is not None and t.input_bytes > 0
                and not cfg.preds(t) and t.origin != dev):
            srcs.add(t.origin)
        if t.output_bytes > 0 and any(
                comp.device_name(mapping[s.uid]) != dev
                for s in cfg.succs(t)):
            srcs.add(dev)
    ensure = getattr(comp, "ensure_routes", None)
    if srcs and ensure is not None:
        return ensure(srcs)
    return 0


# timed-event kinds, ordered only by (time, push seq) like the seed heap
_INTERVENE, _RELEASE, _ARRIVE = 0, 1, 2

_ONE = np.ones(1)


class TimelineEngine:
    """One traverse of a CFG under a fixed mapping, on SoA state.

    Instantiated per ``Traverser.traverse`` call; the engine freezes the
    compiled snapshot for transfer routes/device names (seed semantics)
    while slowdown factors read the *live* compiled snapshot through the
    model — exactly like the seed loop — so interventions that patch the
    topology take effect at the next contention-interval boundary.

    Representation notes: columns consumed by vectorized settles and the
    repricing kernels are numpy; columns only ever read one scalar at a
    time inside event handlers are plain Python lists (a numpy scalar
    index costs ~10x a list index, and handlers run once per event).
    """

    def __init__(self, traverser, cfg: TaskGraph, mapping: dict[int, str],
                 background: Sequence[tuple[Task, str, float]] = (),
                 interventions: Sequence[tuple[float, Callable[[], Any]]] = (),
                 ) -> None:
        self.trav = traverser
        self.graph = traverser.graph
        self.slowdown = traverser.slowdown
        self.noise = traverser.noise
        self.rng = traverser.rng
        self.cfg = cfg
        self.mapping = mapping
        self.background = list(background)
        self.interventions = list(interventions)

    # -- setup --------------------------------------------------------------
    def _setup(self) -> None:
        cfg, mapping = self.cfg, self.mapping
        g = self.graph
        comp = g.compiled()          # frozen: routes + device name space
        self.comp = comp
        tasks = list(cfg)
        self.tasks = tasks
        nt = len(tasks)
        self.nt = nt
        n = nt + len(self.background)
        self.n = n
        slot_of: dict[int, int] = {}
        pu_i = np.empty(n, dtype=np.int64)
        for i, t in enumerate(tasks):
            if t.uid not in mapping:
                raise KeyError(f"{t} has no mapping")
            pu_name = mapping[t.uid]
            pu = g.nodes[pu_name]
            assert isinstance(pu, ProcessingUnit), pu_name
            slot_of[t.uid] = i
            pu_i[i] = comp.pu_index[pu_name]
        for k, (bt, bpu, _) in enumerate(self.background):
            slot_of[bt.uid] = nt + k
            pu_i[nt + k] = comp.pu_index[bpu]
        self.slot_of = slot_of
        self.pu_i = pu_i
        dev_o = comp.pu_dev_ord[pu_i]
        self.pu_il = pu_i.tolist()
        self.dev_ol = dev_o.tolist()
        self.dev_name = [comp.dev_ord_names[o] for o in self.dev_ol]
        pu_names = [comp.pu_names[p] for p in self.pu_il]
        self.pu_name = pu_names
        # per-slot task columns (slowdown inputs + noise irregularity);
        # numpy for the flush gathers, lists for the scalar handlers
        bg_tasks = [bt for bt, _, _ in self.background]
        allt = tasks + bg_tasks
        self.allt = allt
        self.uid_col = np.fromiter((t.uid for t in allt),
                                   dtype=np.int64, count=n)
        self.uidl = self.uid_col.tolist()
        # generated workloads hand tasks over in uid order: slot order IS
        # uid order and the per-flush pool sorts drop the Python key fn
        self._uid_monotone = all(a < b for a, b in
                                 zip(self.uidl, self.uidl[1:]))
        self.U = np.fromiter((t.usage.get("pu", 1.0) for t in allt),
                             dtype=np.float64, count=n)
        self.memraw = np.fromiter((t.usage.get("mem", 1.0) for t in allt),
                                  dtype=np.float64, count=n)
        self.irr = [t.attrs.get("irregularity", 1.0) for t in allt]
        self.rel = [t.release_time for t in tasks]
        self.in_bytes = [t.input_bytes for t in tasks]
        # standalone predictions are pure per (task, PU): one table upfront
        self.sa = [g.nodes[pu_names[i]].predict(t)
                   for i, t in enumerate(tasks)]
        self.sa.extend(brem for _, _, brem in self.background)
        # dependency structure as slot lists
        self.preds = [[slot_of[p.uid] for p in cfg.preds(t)] for t in tasks]
        self.succs = [[slot_of[s.uid] for s in cfg.succs(t)] for t in tasks]
        self.waiting = [len(p) + 1 for p in self.preds]   # +1: release event
        # pre-churn route freeze: one batched pass instead of a lazy
        # Dijkstra at each source's first mid-run transfer
        warm_transfer_routes(comp, cfg, mapping)
        # work state (vector-settled)
        self.W = np.zeros(n)
        self.rate = np.ones(n)
        self.t_last = np.zeros(n)
        self.eta = np.full(n, np.inf)
        # reprice stamps emulate the reference heap's push sequence so
        # *simultaneous* completions settle in the seed's event order
        # (noise draw order is observable); see _complete_* argsorts
        self.cstamp = np.zeros(n, dtype=np.int64)
        self._stamp = 0
        # timeline columns
        nan = float("nan")
        self.start = [nan] * n
        self.finish = [nan] * n
        self.standalone = [nan] * n
        self.ready_t = [nan] * n
        self.comm_t = [nan] * n
        self.qwait = [nan] * n
        self.ready_at = [nan] * n
        # tenancy
        self.pu_running = [0] * len(comp.pu_names)
        self.max_ten = comp.max_tenancy.tolist()
        self.pu_queue: dict[int, deque] = {}
        # device pools + repricing dirt
        self.dev_members: dict[int, set[int]] = {}
        self.dirty_devs: set[int] = set()
        self.dirty_edges: set[int] = set()
        self.n_intervals = 0
        self.n_events = 0
        # transfers (growable SoA) + edge table
        self.xcols = ("xW", "xrate", "xt_last", "xeta", "xlat")
        self._xgrow(64)
        self.xn = 0
        self.xlive = 0
        self.xconsumer: list[int] = []
        # per-transfer route edges in CSR form: xe_flat[xe_start[k] :
        # xe_start[k] + xe_cnt[k]] are transfer k's edge indices, so the
        # link-repricing flush gathers the whole dirty set's edge lists
        # with vectorized index math instead of per-transfer Python
        self.xe_flat = np.zeros(256, dtype=np.int64)
        self.xe_top = 0
        self.xe_start: list[int] = []
        self.xe_cnt: list[int] = []
        self._xe_start_arr: Optional[np.ndarray] = None
        self.edge_idx: dict[int, int] = {}
        self.edge_objs: list[EdgeAttr] = []
        self.edge_bw: list[float] = []
        self._edge_bw_arr: Optional[np.ndarray] = None
        self.edge_members: list[int] = []
        self.edge_xfers: dict[int, set[int]] = {}
        self.route_cache: dict[tuple[str, str], tuple[np.ndarray, float]] = {}
        # timed events
        self.heap: list[tuple[float, int, int, Any]] = []
        self.seq = itertools.count()
        self.time = 0.0
        # factor path: array-native when the model exposes ledger-column
        # scoring; otherwise per-device pools through the tuple surface
        self._fbi = getattr(self.slowdown, "factor_batch_idx", None)
        # memoized repricing: a pool's joint factors depend only on the
        # multiset of (PU, pu-usage, mem-usage) columns (uids are distinct
        # by construction — one job per task), so steady-state pools that
        # recur across readings/devices hit a canonical-order cache
        # instead of re-running the factor kernel.  Keyed per compiled
        # snapshot: topology churn drops the cache with the snapshot.
        self._fcache: dict = {}
        self._fcache_comp = None

    def _xgrow(self, cap: int) -> None:
        for col in self.xcols:
            old = getattr(self, col, None)
            fill = np.inf if col == "xeta" else 0.0
            arr = np.full(cap, fill)
            if old is not None:
                arr[:len(old)] = old
            setattr(self, col, arr)
        old = getattr(self, "xstamp", None)
        self.xstamp = np.zeros(cap, dtype=np.int64)
        if old is not None:
            self.xstamp[:len(old)] = old

    def _push(self, t: float, kind: int, payload: Any) -> None:
        heapq.heappush(self.heap, (t, next(self.seq), kind, payload))

    # -- job lifecycle ------------------------------------------------------
    def _start_compute(self, s: int) -> None:
        p = self.pu_il[s]
        if self.pu_running[p] >= self.max_ten[p]:
            q = self.pu_queue.get(p)
            if q is None:
                q = self.pu_queue[p] = deque()
            q.append(s)
            return
        self.pu_running[p] = self.pu_running[p] + 1
        sa = self.sa[s]
        work = sa
        if self.noise > 0.0:
            work = sa * float(np.exp(self.rng.normal(
                0.0, self.noise * self.irr[s])))
        t = self.time
        self.W[s] = work
        self.rate[s] = 1.0
        self.t_last[s] = t
        self.start[s] = t
        self.standalone[s] = sa
        ra = self.ready_at[s]
        self.qwait[s] = t - (ra if ra == ra else self.rel[s])
        d = self.dev_ol[s]
        m = self.dev_members.get(d)
        if m is None:
            m = self.dev_members[d] = set()
        m.add(s)
        self.dirty_devs.add(d)

    def _route(self, src: str, dst: str) -> tuple[np.ndarray, float]:
        key = (src, dst)
        hit = self.route_cache.get(key)
        if hit is None:
            edges = self.comp.route_edges(src, dst)
            idxs = np.empty(len(edges), dtype=np.int64)
            lat = 0.0
            for i, e in enumerate(edges):
                ei = self.edge_idx.get(id(e))
                if ei is None:
                    ei = len(self.edge_objs)
                    self.edge_idx[id(e)] = ei
                    self.edge_objs.append(e)
                    self.edge_bw.append(e.bandwidth)
                    self.edge_members.append(0)
                    self._edge_bw_arr = None
                idxs[i] = ei
                lat += e.latency
            hit = self.route_cache[key] = (idxs, lat)
        return hit

    def _launch(self, consumer: int, src_dev: str, dst_dev: str,
                nbytes: float) -> bool:
        """Start a transfer for ``consumer``'s input; False = local/no data."""
        if src_dev == dst_dev or nbytes <= 0:
            return False
        eidx, lat = self._route(src_dev, dst_dev)
        k = self.xn
        if k == len(self.xW):
            self._xgrow(2 * k)
        self.xn = k + 1
        self.xlive += 1
        self.xW[k] = nbytes
        self.xrate[k] = 1.0
        self.xt_last[k] = self.time
        self.xeta[k] = np.inf          # priced at the flush
        self.xlat[k] = lat
        self.xconsumer.append(consumer)
        ne = len(eidx)
        top = self.xe_top
        if top + ne > len(self.xe_flat):
            buf = np.zeros(max(2 * len(self.xe_flat), top + ne),
                           dtype=np.int64)
            buf[:top] = self.xe_flat[:top]
            self.xe_flat = buf
        self.xe_flat[top:top + ne] = eidx
        self.xe_start.append(top)
        self.xe_cnt.append(ne)
        self.xe_top = top + ne
        self._xe_start_arr = None
        dirty = self.dirty_edges
        members = self.edge_members
        xfers = self.edge_xfers
        for e in eidx.tolist():
            members[e] += 1
            xs = xfers.get(e)
            if xs is None:
                xs = xfers[e] = set()
            xs.add(k)
            dirty.add(e)
        return True

    def _arrived(self, s: int) -> None:
        w = self.waiting[s] - 1
        self.waiting[s] = w
        if w == 0:
            t = self.time
            self.ready_at[s] = t
            dep = self.rel[s]
            for p in self.preds[s]:
                f = self.finish[p]
                if f > dep:
                    dep = f
            self.ready_t[s] = dep
            self.comm_t[s] = t - dep
            self._start_compute(s)

    def _finish(self, s: int) -> None:
        t = self.time
        self.eta[s] = np.inf
        p = self.pu_il[s]
        self.pu_running[p] = self.pu_running[p] - 1
        self.finish[s] = t
        d = self.dev_ol[s]
        self.dev_members[d].discard(s)
        if s < self.nt:
            # successors: dependency bookkeeping + inter-device transfers
            out_bytes = self.tasks[s].output_bytes
            src = self.dev_name[s]
            for ss in self.succs[s]:
                if not self._launch(ss, src, self.dev_name[ss], out_bytes):
                    self._arrived(ss)
        q = self.pu_queue.get(p)
        if q:
            self._start_compute(q.popleft())
        self.dirty_devs.add(d)

    # -- repricing ----------------------------------------------------------
    def _pool_factors(self, members: np.ndarray) -> np.ndarray:
        if self._fbi is not None:
            P = self.pu_i[members]
            n = len(P)
            if n == 1:
                return _ONE        # a lone job has no co-runners
            U = self.U[members]
            mem = self.memraw[members]
            if n == 2:             # pair pools: scalar path beats the cache
                return self._fbi(P, U, mem, self.uid_col[members])
            comp = self.graph.compiled()
            if comp is not self._fcache_comp:
                self._fcache_comp = comp
                self._fcache = {}
            order = np.lexsort((mem, U, P))
            key = (P[order].tobytes(), U[order].tobytes(),
                   mem[order].tobytes())
            hit = self._fcache.get(key)
            if hit is not None:
                out = np.empty(len(hit))
                out[order] = hit
                return out
            f = np.asarray(self._fbi(P, U, mem, self.uid_col[members]),
                           dtype=np.float64)
            self._fcache[key] = f[order].copy()
            return f
        # tuple fallback (custom slowdown models): per-device pools, like
        # the seed — cross-device interactions are not assumed absent
        out = np.empty(len(members))
        fb = getattr(self.slowdown, "factor_batch", None)
        allt = self.allt
        devs = np.asarray([self.dev_ol[m] for m in members.tolist()])
        for d in np.unique(devs):
            sel = np.nonzero(devs == d)[0]
            pool = [(allt[m], self.pu_name[m]) for m in members[sel]]
            if fb is not None:
                out[sel] = np.asarray(fb(pool), dtype=np.float64)
            else:
                out[sel] = [self.slowdown.factor(tk, pu, pool)
                            for tk, pu in pool]
        return out

    def _flush(self) -> bool:
        """Reprice every dirty device pool (one factor call) and every
        dirty link set (one segment-min).  Returns True when any rate was
        re-projected — i.e. when same-timestamp work may now exist."""
        t = self.time
        flushed = False
        if self.dirty_devs:
            self.n_intervals += len(self.dirty_devs)
            dm = self.dev_members
            # pool order replays the reference's completion-push sequence
            # (device name, then uid) so reprice stamps line up exactly
            names = self.comp.dev_ord_names
            uidl = self.uidl
            mem_list: list[int] = []
            if self._uid_monotone:
                for d in sorted(self.dirty_devs, key=names.__getitem__):
                    mem_list.extend(sorted(dm[d]))
            else:
                for d in sorted(self.dirty_devs, key=names.__getitem__):
                    mem_list.extend(sorted(dm[d], key=uidl.__getitem__))
            self.dirty_devs.clear()
            total = len(mem_list)
            if total:
                members = np.asarray(mem_list, dtype=np.int64)
                self.cstamp[members] = np.arange(
                    self._stamp, self._stamp + total)
                self._stamp += total
                factors = np.asarray(self._pool_factors(members),
                                     dtype=np.float64)
                W2 = _settle_pos(self.W[members], self.rate[members],
                                 self.t_last[members], t)
                rate = 1.0 / factors
                self.W[members] = W2
                self.t_last[members] = t
                self.rate[members] = rate
                self.eta[members] = t + W2 / rate
                flushed = True
        if self.dirty_edges:
            affected: set[int] = set()
            xfers = self.edge_xfers
            for e in self.dirty_edges:
                xs = xfers.get(e)
                if xs:
                    affected |= xs
            self.dirty_edges.clear()
            if affected:
                ks = np.fromiter(sorted(affected), dtype=np.int64,
                                 count=len(affected))
                self.xstamp[ks] = np.arange(self._stamp,
                                            self._stamp + len(ks))
                self._stamp += len(ks)
                if self._xe_start_arr is None:
                    self._xe_start_arr = np.asarray(self.xe_start,
                                                    dtype=np.int64)
                    self._xe_cnt_arr = np.asarray(self.xe_cnt,
                                                  dtype=np.int64)
                starts = self._xe_start_arr[ks]
                counts = self._xe_cnt_arr[ks]
                K = int(counts.sum())
                if K:
                    within = np.arange(K) - np.repeat(
                        np.cumsum(counts) - counts, counts)
                    flat = self.xe_flat[np.repeat(starts, counts) + within]
                else:
                    flat = np.zeros(0, dtype=np.int64)
                if self._edge_bw_arr is None:
                    self._edge_bw_arr = np.asarray(self.edge_bw)
                    self._edge_mem_arr = np.asarray(self.edge_members)
                else:
                    self._edge_mem_arr = np.asarray(self.edge_members)
                shares = self._edge_bw_arr[flat] / np.maximum(
                    1, self._edge_mem_arr[flat])
                bw = _segment_min(shares, counts)
                W2, _ = _rate_advance(self.xW[ks], self.xrate[ks],
                                      self.xt_last[ks], t)
                self.xW[ks] = W2
                self.xt_last[ks] = t
                self.xrate[ks] = bw
                eta = np.divide(W2, bw, out=np.full(len(ks), np.inf),
                                where=bw > 0.0)
                self.xeta[ks] = t + eta
                flushed = True
        return flushed

    def _intervene(self, fn: Callable[[], Any]) -> None:
        fn()
        # an intervention may mutate anything factors depend on (topology
        # OR model params): drop the memoized pool factors outright
        self._fcache = {}
        self._fcache_comp = None
        # churn boundary: reprice every occupied device pool and active
        # link set against the post-mutation model/bandwidths
        for d, members in self.dev_members.items():
            if members:
                self.dirty_devs.add(d)
        for i, e in enumerate(self.edge_objs):
            self.edge_bw[i] = e.bandwidth
        self._edge_bw_arr = None
        for e, xs in self.edge_xfers.items():
            if xs:
                self.dirty_edges.add(e)

    # -- completions --------------------------------------------------------
    def _complete_compute(self, done: np.ndarray) -> None:
        t = self.time
        if len(done) > 1:   # simultaneous: settle in reprice-stamp order
            done = done[np.argsort(self.cstamp[done], kind="stable")]
        W2 = _settle_pos(self.W[done], self.rate[done],
                         self.t_last[done], t)
        self.W[done] = W2
        self.t_last[done] = t
        fin = W2 <= CTOL
        if not fin.all():   # float residue: keep running, fresh estimate
            resid = done[~fin]
            self.eta[resid] = t + self.W[resid] / self.rate[resid]
        self.n_events += len(done)
        for s in done[fin].tolist():
            self._finish(s)

    def _complete_transfers(self, done: np.ndarray) -> None:
        t = self.time
        if len(done) > 1:   # simultaneous: settle in reprice-stamp order
            done = done[np.argsort(self.xstamp[done], kind="stable")]
        W2, eta = _rate_advance(self.xW[done], self.xrate[done],
                                self.xt_last[done], t)
        self.xW[done] = W2
        self.xt_last[done] = t
        fin = W2 <= XTOL
        if not fin.all():
            resid = done[~fin]
            self.xeta[resid] = eta[~fin]
        self.n_events += len(done)
        members = self.edge_members
        for k in done[fin].tolist():
            self.xeta[k] = np.inf
            self.xlive -= 1
            st = self.xe_start[k]
            for e in self.xe_flat[st:st + self.xe_cnt[k]].tolist():
                members[e] -= 1
                self.edge_xfers[e].discard(k)
                self.dirty_edges.add(e)
            lat = float(self.xlat[k])
            if lat > 0:
                # latency tail: arrival after the fixed route latency
                self._push(t + lat, _ARRIVE, self.xconsumer[k])
            else:
                self._arrived(self.xconsumer[k])

    # -- main loop ----------------------------------------------------------
    def run(self) -> Timeline:
        self._setup()
        for t, fn in self.interventions:
            self._push(float(t), _INTERVENE, fn)
        # background jobs run from t=0 with known remaining standalone work
        for k, (bt, bpu, brem) in enumerate(self.background):
            s = self.nt + k
            self.W[s] = brem
            self.start[s] = 0.0
            self.standalone[s] = brem
            self.pu_running[self.pu_il[s]] += 1
            d = self.dev_ol[s]
            self.dev_members.setdefault(d, set()).add(s)
            self.dirty_devs.add(d)
        self._flush()
        for i, t in enumerate(self.tasks):
            self._push(t.release_time, _RELEASE, i)

        heap = self.heap
        eta = self.eta
        while True:
            em = float(eta.min()) if len(eta) else np.inf
            xm = float(self.xeta[:self.xn].min()) if self.xlive else np.inf
            t_next = heap[0][0] if heap else np.inf
            if em < t_next:
                t_next = em
            if xm < t_next:
                t_next = xm
            if t_next == np.inf:
                break
            if t_next > self.time:
                self.time = t_next
            time = self.time
            # all events at this timestamp drain before one flush reprices
            # what they touched; repeat while the flush re-projected rates
            # (zero-duration pileups surface as fresh same-time work)
            first = True
            while True:
                ne = self.n_events
                while heap and heap[0][0] <= time:
                    _, _, kind, payload = heapq.heappop(heap)
                    self.n_events += 1
                    if kind == _RELEASE:
                        s = payload
                        task = self.tasks[s]
                        # initial input payload from the origin device
                        if (task.origin is not None and self.in_bytes[s] > 0
                                and not self.preds[s]):
                            if self._launch(s, task.origin, self.dev_name[s],
                                            self.in_bytes[s]):
                                continue
                        self._arrived(s)
                    elif kind == _ARRIVE:
                        self._arrived(payload)
                    else:
                        self._intervene(payload)
                if first or em <= time:
                    done = np.nonzero(eta <= time)[0]
                    if len(done):
                        self._complete_compute(done)
                if self.xlive and (first or xm <= time):
                    xdone = np.nonzero(self.xeta[:self.xn] <= time)[0]
                    if len(xdone):
                        self._complete_transfers(xdone)
                first = False
                if not self._flush():
                    break
                # a flush ran: re-projected rates may complete at `time`
                em = float(eta.min()) if len(eta) else np.inf
                xm = float(self.xeta[:self.xn].min()) if self.xlive \
                    else np.inf
                if em > time and xm > time and not (heap and
                                                    heap[0][0] <= time):
                    break
        return self._timeline()

    def _timeline(self) -> Timeline:
        missing = [t.uid for i, t in enumerate(self.tasks)
                   if self.finish[i] != self.finish[i]]
        if missing:
            raise RuntimeError(f"traverse deadlock: unfinished {missing[:5]}")
        tl = Timeline(mapping=dict(self.mapping))
        tl.n_intervals = self.n_intervals
        tl.n_events = self.n_events
        for i, t in enumerate(self.tasks):
            uid = t.uid
            tl.start[uid] = self.start[i]
            tl.finish[uid] = self.finish[i]
            tl.standalone[uid] = self.standalone[i]
            if not math.isnan(self.ready_t[i]):
                tl.ready[uid] = self.ready_t[i]
                tl.comm[uid] = self.comm_t[i]
            if not math.isnan(self.qwait[i]):
                tl.queue_wait[uid] = self.qwait[i]
        # background tasks may legitimately still be running; report their
        # projected finish assuming the final interval persists
        for k, (bt, _, _) in enumerate(self.background):
            s = self.nt + k
            tl.start[bt.uid] = self.start[s]
            tl.standalone[bt.uid] = self.standalone[s]
            if not math.isnan(self.finish[s]):
                tl.finish[bt.uid] = self.finish[s]
            elif s in self.dev_members.get(self.dev_ol[s], ()):
                tl.finish[bt.uid] = self.time + float(self.W[s]
                                                      / self.rate[s])
        return tl
