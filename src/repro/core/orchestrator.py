"""Hierarchical, de-centralized Orchestrator (paper §3.5, Alg. 1).

ORCs form a tree mirroring the upper layers of the HW-GRAPH: a root ORC,
one ORC per virtual cluster (edge cluster / server cluster / pod), and one
ORC per device.  Each ORC knows only its parent and children (resource
segregation); a device ORC has full knowledge of the PUs inside its device.

``map_task`` implements Alg. 1:

  TraverseChildren: check own leaf PUs (constraint check via the Traverser,
  including *existing* tasks' constraints) and recurse into child ORCs;
  if nothing satisfies the constraints, AskParent: the parent tries the
  siblings, then escalates further up (DFS).  Communication latency from the
  task's origin to a remote PU is folded into the constraint check, and every
  remote hop is charged to the *scheduling overhead* ledger (paper Fig. 14).

All candidate PUs of an ORC are scored in one vectorized constraint check
(``_check_candidates``) against the graph's compiled arrays — slowdown
factors of the newcomer *and* the Alg. 1 line 15 re-check of every active
task's constraints come from a single ``factors_with_candidates`` call
instead of one Traverser query per candidate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .hwgraph import HWGraph, ProcessingUnit
from .task import Task
from .traverser import TaskPrediction, Traverser

QUERY_BYTES = 1024.0          # size of a MapTask query/response message


@dataclass
class ActiveEntry:
    task: Task
    pu: str
    est_finish: float
    factor: float

    def remaining_standalone(self, now: float) -> float:
        return max(0.0, self.est_finish - now) / max(self.factor, 1e-12)


class ActiveLedger:
    """The runtime's belief of which tasks occupy which PUs.

    Estimates come from the Orchestrator's own predictions (it cannot observe
    ground truth — the paper's runtime monitors assignments, not hardware
    counters on remote devices).
    """

    def __init__(self) -> None:
        self.by_pu: dict[str, list[ActiveEntry]] = {}

    def add(self, task: Task, pu: str, pred: TaskPrediction, now: float) -> ActiveEntry:
        e = ActiveEntry(task=task, pu=pu, est_finish=now + pred.total,
                        factor=pred.factor)
        self.by_pu.setdefault(pu, []).append(e)
        return e

    def prune(self, now: float) -> None:
        for pu in list(self.by_pu):
            self.by_pu[pu] = [e for e in self.by_pu[pu] if e.est_finish > now]
            if not self.by_pu[pu]:
                del self.by_pu[pu]

    def remove(self, task: Task) -> None:
        for pu in list(self.by_pu):
            self.by_pu[pu] = [e for e in self.by_pu[pu] if e.task.uid != task.uid]
            if not self.by_pu[pu]:
                del self.by_pu[pu]

    def on_device(self, graph: HWGraph, pu_name: str) -> list[ActiveEntry]:
        comp = graph.compiled()
        dev = comp.device_name(pu_name)
        out: list[ActiveEntry] = []
        for pu, entries in self.by_pu.items():
            if comp.device_name(pu) == dev:
                out.extend(entries)
        return out

    def pairs_on_device(self, graph: HWGraph, pu_name: str) -> list[tuple[Task, str]]:
        return [(e.task, e.pu) for e in self.on_device(graph, pu_name)]

    def count(self, pu: str) -> int:
        return len(self.by_pu.get(pu, []))


@dataclass
class MapResult:
    pu: str
    prediction: TaskPrediction
    overhead: float = 0.0        # scheduling overhead in seconds (Fig. 14)
    queries: int = 0             # constraint checks performed
    hops: int = 0                # remote ORC-to-ORC messages


@dataclass
class OrcConfig:
    local_query_cost: float = 5e-6    # CPU time per candidate constraint check
    objective: str = "best_fit"       # "best_fit" | "first_fit" | "min_load"
    allow_best_effort: bool = True    # if nothing satisfies, pick least-bad PU


class Orchestrator:
    def __init__(self, graph: HWGraph, group: str, traverser: Traverser,
                 ledger: ActiveLedger, config: Optional[OrcConfig] = None,
                 parent: Optional["Orchestrator"] = None) -> None:
        self.graph = graph
        self.group = group
        self.traverser = traverser
        self.ledger = ledger
        self.config = config or OrcConfig()
        self.parent = parent
        self.children: list["Orchestrator"] = []
        self.leaf_pus: list[str] = []

    # -- hierarchy ----------------------------------------------------------
    def add_child(self, child: "Orchestrator") -> "Orchestrator":
        child.parent = self
        self.children.append(child)
        return child

    def is_device_orc(self) -> bool:
        return bool(self.leaf_pus)

    def __repr__(self) -> str:
        return f"ORC({self.group})"

    # -- Alg. 1 --------------------------------------------------------------
    def map_task(self, task: Task, now: float = 0.0,
                 commit: bool = True) -> Optional[MapResult]:
        """Entry point (called on the task's *local* device ORC)."""
        self.ledger.prune(now)
        res = self._traverse_children(task, now)
        if res is None:
            res = self._ask_parent(task, now, origin=self)
        if res is None and self.config.allow_best_effort:
            res = self._best_effort(task, now)
        if res is not None and commit:
            self.ledger.add(task, res.pu, res.prediction, now)
            task.assigned_pu = res.pu
        return res

    # TraverseChildren (Alg. 1 line 20)
    def _traverse_children(self, task: Task, now: float) -> Optional[MapResult]:
        candidates: list[MapResult] = []
        queries = 0
        hops = 0
        overhead = 0.0
        checks = self._check_candidates(task, self.leaf_pus, now)
        for pu_name, (ok, pred) in zip(self.leaf_pus, checks):
            queries += 1
            if ok:
                r = MapResult(pu=pu_name, prediction=pred)
                if self.config.objective == "first_fit":
                    r.queries = queries
                    r.overhead = overhead + queries * self.config.local_query_cost
                    r.hops = hops
                    return r
                candidates.append(r)
        for child in self.children:
            hops += 1
            overhead += self._hop_cost(child)
            sub = child._traverse_children(task, now)
            if sub is not None:
                queries += sub.queries
                hops += sub.hops
                overhead += sub.overhead
                if self.config.objective == "first_fit":
                    sub.queries = queries
                    sub.hops = hops
                    sub.overhead = overhead + queries * self.config.local_query_cost
                    return sub
                candidates.append(sub)
        if not candidates:
            return None
        best = self._select(candidates)
        best.queries = queries
        best.hops = hops
        best.overhead = overhead + queries * self.config.local_query_cost
        return best

    # AskParent (Alg. 1 line 30)
    def _ask_parent(self, task: Task, now: float,
                    origin: "Orchestrator") -> Optional[MapResult]:
        if self.parent is None:
            return None
        parent = self.parent
        results: list[MapResult] = []
        hops = 1                       # message up to the parent
        overhead = self._hop_cost(parent)
        queries = 0
        for sibling in parent.children:
            if sibling is self:
                continue
            hops += 1
            overhead += parent._hop_cost(sibling)
            sub = sibling._traverse_children(task, now)
            if sub is not None:
                sub.hops += hops
                sub.overhead += overhead
                if parent.config.objective == "first_fit":
                    return sub
                results.append(sub)
                queries += sub.queries
        if results:
            best = self._select(results)
            return best
        # no sibling satisfies: propagate the search further up (DFS)
        return parent._ask_parent(task, now, origin=origin)

    # CheckTaskConstraints (Alg. 1 line 11)
    def _check_constraints(self, task: Task, pu_name: str,
                           now: float) -> tuple[bool, TaskPrediction]:
        return self._check_candidates(task, [pu_name], now)[0]

    def _check_candidates(self, task: Task, pu_names: list[str],
                          now: float) -> list[tuple[bool, TaskPrediction]]:
        """CheckTaskConstraints over every candidate PU in one shot."""
        return self._score_candidates(task, pu_names, now,
                                      with_constraints=True)

    # -- helpers --------------------------------------------------------------
    def _score_candidates(self, task: Task, pu_names: list[str], now: float,
                          *, with_constraints: bool,
                          ) -> list[tuple[bool, TaskPrediction]]:
        """Vectorized candidate scoring against the compiled HW-GRAPH.

        Per candidate: standalone prediction, inbound communication, the
        newcomer's slowdown factor amid the device's active tasks, and —
        when ``with_constraints`` — the tenancy queueing wait, the deadline
        check, and Alg. 1 line 15 (existing tasks keep their constraints).
        The factor work for all candidates of a device comes from a single
        ``factors_with_candidates`` call.

        Predictions are *pipeline-aware*: if this task's output must
        return to a pinned consumer on the origin device, that transfer is
        charged here — otherwise a remote placement looks cheap while the
        return leg destroys the downstream task's budget (cf. §5.4.1
        CloudVR comparison: balance computation AND communication)."""
        graph = self.graph
        comp = graph.compiled()
        infeasible = (False, TaskPrediction(float("inf"), 1.0, 0.0))
        results: list[Optional[tuple[bool, TaskPrediction]]] = \
            [None] * len(pu_names)
        eligible: list[int] = []
        for i, name in enumerate(pu_names):
            pu = graph.nodes.get(name)
            if (not isinstance(pu, ProcessingUnit) or not pu.alive
                    or (pu.model is not None
                        and not pu.model.supports(task, pu))
                    # device-local peripherals pin a task to its origin
                    or (task.attrs.get("pinned")
                        and comp.device_name(name) != task.origin)):
                results[i] = infeasible
            else:
                eligible.append(i)
        if not eligible:
            return results
        sd = self.traverser.slowdown
        batch = getattr(sd, "factors_with_candidates", None)
        by_dev: dict[str, list[int]] = {}
        for i in eligible:
            by_dev.setdefault(comp.device_name(pu_names[i]), []).append(i)
        ret_bytes = task.attrs.get("succ_pinned_bytes", 0.0)
        for dev, idxs in by_dev.items():
            names = [pu_names[i] for i in idxs]
            entries = self.ledger.on_device(graph, names[0])
            pairs = [(e.task, e.pu) for e in entries]
            if batch is not None:
                new_f, act_f = batch(task, names, pairs)
            else:
                new_f = [sd.factor(task, p, pairs) for p in names]
                act_f = None
            comm = self.traverser.comm_time(task, names[0], comp)
            if ret_bytes > 0 and task.origin is not None and dev != task.origin:
                comm += comp.transfer_time(dev, task.origin, ret_bytes)
            for c, i in enumerate(idxs):
                name = names[c]
                pu = graph.nodes[name]
                pred = TaskPrediction(standalone=pu.predict(task),
                                      factor=float(new_f[c]), comm=comm)
                if not with_constraints:
                    results[i] = (True, pred)
                    continue
                # tenancy cap: queueing wait behind the earliest finisher
                on_pu = self.ledger.by_pu.get(name, [])
                if len(on_pu) >= pu.max_tenancy:
                    wait = min(e.est_finish for e in on_pu) - now
                    pred = TaskPrediction(standalone=pred.standalone,
                                          factor=pred.factor,
                                          comm=pred.comm + max(0.0, wait))
                if task.deadline is not None and pred.total > task.deadline:
                    results[i] = (False, pred)
                    continue
                # existing tasks keep their constraints (Alg. 1 l.15)
                ok = True
                if entries:
                    if act_f is None:
                        new_factors = self.traverser.predict_active_with(
                            task, name, pairs)
                    for a, e in enumerate(entries):
                        if e.task.deadline is None:
                            continue
                        f = (float(act_f[c, a]) if act_f is not None
                             else new_factors[e.task.uid])
                        rem = e.remaining_standalone(now)
                        new_finish = now + rem * f
                        if (new_finish - e.task.release_time
                                > e.task.deadline * (1 + 1e-9)):
                            ok = False
                            break
                results[i] = (ok, pred)
        return results

    def _select(self, candidates: list[MapResult]) -> MapResult:
        if self.config.objective == "min_load":
            return min(candidates, key=lambda r: self.ledger.count(r.pu))
        return min(candidates, key=lambda r: r.prediction.total)

    def _hop_cost(self, other: "Orchestrator") -> float:
        """Round-trip query cost between this ORC's group and another's."""
        try:
            one_way = self.graph.compiled().transfer_time(
                self.group, other.group, QUERY_BYTES)
        except KeyError:
            one_way = 0.0
        return 2.0 * one_way

    def _best_effort(self, task: Task, now: float) -> Optional[MapResult]:
        """Nothing satisfies the deadline anywhere: pick the globally least-bad
        PU so the system degrades instead of dropping work (QoS failure is
        recorded by the evaluation layer)."""
        root = self
        while root.parent is not None:
            root = root.parent
        best: Optional[MapResult] = None
        for orc in root.iter_tree():
            if not orc.leaf_pus:
                continue
            scores = self._score_candidates(task, orc.leaf_pus, now,
                                            with_constraints=False)
            for pu_name, (ok, pred) in zip(orc.leaf_pus, scores):
                if not ok:
                    continue
                if best is None or pred.total < best.prediction.total:
                    best = MapResult(pu=pu_name, prediction=pred)
        return best

    def iter_tree(self):
        yield self
        for c in self.children:
            yield from c.iter_tree()

    def find_device_orc(self, device: str) -> Optional["Orchestrator"]:
        for orc in self.iter_tree():
            if orc.group == device:
                return orc
        return None


def build_orchestrators(graph: HWGraph, traverser: Traverser,
                        ledger: Optional[ActiveLedger] = None,
                        config: Optional[OrcConfig] = None,
                        max_fanout: Optional[int] = None) -> Orchestrator:
    """Build the ORC tree from GROUP nodes tagged with attrs['orc_level'].

    Levels: 'root' (exactly one), 'cluster' (virtual groupings), 'device'
    (manages every PU in its subtree).  Matches Fig. 4b.

    ``max_fanout``: the paper's scalability device (§3.5) — "if a virtual
    cluster gets too large, the logarithmic complexity could be maintained
    by inserting virtual nodes and corresponding ORCs".  When a cluster ORC
    ends up with more than max_fanout children, intermediate virtual ORCs
    are inserted so every node's fanout stays bounded and a MapTask
    escalation touches O(log n) ORCs instead of O(n) siblings.
    """
    ledger = ledger or ActiveLedger()
    config = config or OrcConfig()
    roots = [n for n in graph.nodes.values()
             if n.attrs.get("orc_level") == "root"]
    if len(roots) != 1:
        raise ValueError(f"expected exactly one root group, got {len(roots)}")
    root = Orchestrator(graph, roots[0].name, traverser, ledger, config)

    def attach(parent_orc: Orchestrator, group_name: str) -> None:
        for child in graph.children_of(group_name):
            lvl = child.attrs.get("orc_level")
            if lvl == "cluster":
                orc = parent_orc.add_child(
                    Orchestrator(graph, child.name, traverser, ledger, config))
                attach(orc, child.name)
            elif lvl == "device":
                orc = parent_orc.add_child(
                    Orchestrator(graph, child.name, traverser, ledger, config))
                orc.leaf_pus = [p.name for p in graph.pus(under=child.name)]
            elif child.kind.name == "GROUP":
                attach(parent_orc, child.name)

    attach(root, roots[0].name)
    if max_fanout is not None and max_fanout >= 2:
        for orc in list(root.iter_tree()):
            _bound_fanout(orc, max_fanout)
    return root


def _bound_fanout(orc: Orchestrator, k: int) -> None:
    """Insert virtual intermediate ORCs under ``orc`` until every node in
    its subtree has at most k children (device ORCs are leaves)."""
    level = 0
    while len(orc.children) > k:
        groups: list[Orchestrator] = []
        kids = orc.children
        for i in range(0, len(kids), k):
            chunk = kids[i:i + k]
            if len(chunk) == 1:
                groups.append(chunk[0])
                continue
            virt = Orchestrator(orc.graph, f"{orc.group}.virt{level}_{i // k}",
                                orc.traverser, orc.ledger, orc.config)
            virt.parent = orc
            for c in chunk:
                c.parent = virt
                virt.children.append(c)
            groups.append(virt)
        orc.children = groups
        level += 1
