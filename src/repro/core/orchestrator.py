"""Hierarchical, de-centralized Orchestrator (paper §3.5, Alg. 1).

ORCs form a tree mirroring the upper layers of the HW-GRAPH: a root ORC,
one ORC per virtual cluster (edge cluster / server cluster / pod), and one
ORC per device.  Each ORC knows only its parent and children (resource
segregation); a device ORC has full knowledge of the PUs inside its device.

The scheduling surface is **batch-first**: ``map_batch`` maps a whole
frontier of ready tasks in one call.  Each task's placement still follows
Alg. 1 —

  TraverseChildren: check own leaf PUs (constraint check via the Traverser,
  including *existing* tasks' constraints) and recurse into child ORCs;
  if nothing satisfies the constraints, AskParent: the parent tries the
  siblings, then escalates further up (DFS).  Communication latency from the
  task's origin to a remote PU is folded into the constraint check, and every
  remote hop is charged to the *scheduling overhead* ledger (paper Fig. 14).

— but the batch amortizes everything that is shared across the frontier:
one ledger prune, per-kind PU support masks and standalone-latency vectors,
per-device communication estimates, and the struct-of-arrays ``ActiveLedger``
views.  Mapping is optimistic-concurrency: every task is first scored
against the ledger as it stood at the start of the batch, then committed in
task order; a task is re-scored only when an earlier commit landed on a
device its search actually scored, which keeps ``map_batch`` bit-identical
to N sequential one-task batches (pinned by ``tests/test_session.py``).

``map_task`` was removed in PR 8 (deprecated since PR 3): map one-element
frontiers with ``map_batch([task], now)[0]`` or drive whole TaskGraphs
through ``core.session.SchedulerSession``.

At a root ORC with two or more group subtrees the walk additionally runs
**group-sharded** (``REPRO_SHARDED_WALK``, default on): the compiled
snapshot is partitioned into block-diagonal per-group views
(``CompiledHWGraph.sharded``), the ledger into per-group shards
(``ShardedLedger``), and each group's phase-1 walks drive their scan-plan
reduces independently — batched entry reduces where shapes align, host
threads across groups — reconciling only at the root ORC boundary via the
NCR matrix.  ``REPRO_SHARDED_WALK=0`` keeps the fused single-shard walk as
a bit-identical parity oracle (see ``docs/sharding.md``).

All candidate PUs of an ORC are scored in one vectorized constraint check
(``_check_candidates``) against the graph's compiled arrays — eligibility
masks (alive / supports / pinned), standalone predictions, tenancy queueing
and the Alg. 1 line 15 re-check of every active task's constraints are pure
array ops over the compiled snapshot and the ledger columns.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Iterable, Optional, Sequence

import numpy as np

from .hwgraph import HWGraph, ProcessingUnit
from .task import Task
from .traverser import TaskPrediction, Traverser

QUERY_BYTES = 1024.0          # size of a MapTask query/response message

_SCAN_REDUCE = None
_SCAN_REDUCE_BATCH = None


def _scan_reduce_kernel():
    """Lazily bind ``kernels.walk_kernel.scan_reduce`` — importing the
    kernels package pulls in jax, which mapping-only flows should pay at
    most once (and never at plain module import)."""
    global _SCAN_REDUCE
    if _SCAN_REDUCE is None:
        from ..kernels.walk_kernel import scan_reduce
        _SCAN_REDUCE = scan_reduce
    return _SCAN_REDUCE


def _scan_reduce_batch_kernel():
    """Lazily bind ``kernels.walk_kernel.scan_reduce_batch`` (stacked
    same-shape scans reduced in one call; jax path vmaps, numpy path is
    a bit-identical row loop)."""
    global _SCAN_REDUCE_BATCH
    if _SCAN_REDUCE_BATCH is None:
        from ..kernels.walk_kernel import scan_reduce_batch
        _SCAN_REDUCE_BATCH = scan_reduce_batch
    return _SCAN_REDUCE_BATCH


@dataclass
class ActiveEntry:
    """Object view of one ledger row (compat surface for callers that
    predate the struct-of-arrays ledger)."""

    task: Task
    pu: str
    est_finish: float
    factor: float

    def remaining_standalone(self, now: float) -> float:
        return max(0.0, self.est_finish - now) / max(self.factor, 1e-12)


class _LedgerView:
    """Dense columns of live ledger rows (one device, or the device-sorted
    global view with per-device-ordinal segment offsets)."""

    __slots__ = ("rows", "pu_names", "P", "est", "fac", "dl", "rel",
                 "upu", "umem", "Ma", "uid", "tasks", "Da", "astart", "na")

    def __len__(self) -> int:
        return len(self.rows)

    def pairs(self) -> list[tuple[Task, str]]:
        return list(zip(self.tasks, self.pu_names))


class ActiveLedger:
    """The runtime's belief of which tasks occupy which PUs.

    Estimates come from the Orchestrator's own predictions (it cannot observe
    ground truth — the paper's runtime monitors assignments, not hardware
    counters on remote devices).

    Storage is struct-of-arrays: one row per active task with dense numpy
    columns (estimated finish, slowdown factor, deadline, release, usage,
    uid) plus incremental dict indexes (live count per PU, live rows per
    device), so candidate eligibility, tenancy queueing and the Alg. 1 l.15
    re-check are array lookups instead of object-list scans.  ``by_pu`` /
    ``on_device`` remain as object-view compatibility accessors.
    """

    def __init__(self) -> None:
        self._n = 0
        self._tasks: list[Optional[Task]] = []
        self._pus: list[Optional[str]] = []
        self._est = np.empty(0)
        self._fac = np.empty(0)
        self._dl = np.empty(0)
        self._upu = np.empty(0)
        self._umem = np.empty(0)
        self._uid = np.empty(0, dtype=np.int64)
        self._live = np.empty(0, dtype=bool)
        self._pu_idx = np.empty(0, dtype=np.int64)   # compiled PU index
        self._pu_idx_comp = None                     # snapshot the column is for
        self._dead = 0
        self.version = 0
        self._count: dict[str, int] = {}
        self._pu_dev: dict[str, str] = {}          # pu name -> device name
        self._dev_rows: Optional[dict[str, list[int]]] = None
        self._live_view: Optional[tuple] = None    # (comp id, version, view)
        # fine-grained invalidation: adds, device-attributed kills and
        # ``touch`` bump only their device's version; unattributable
        # mutations bump the epoch hammer (batch contexts key views on
        # these).  ``mut_log`` journals the device name of every
        # attributed mutation in order — persistent scan states refresh
        # exactly the suffix they have not seen yet.
        self.dev_epoch = 0
        self.dev_version: dict[str, int] = {}
        self.mut_log: list[str] = []

    # -- bookkeeping -------------------------------------------------------
    def __len__(self) -> int:
        return self._n - self._dead

    def _grow(self) -> None:
        cap = max(16, 2 * len(self._est))
        for col in ("_est", "_fac", "_dl", "_upu", "_umem"):
            arr = np.empty(cap)
            arr[:self._n] = getattr(self, col)[:self._n]
            setattr(self, col, arr)
        for col in ("_uid", "_pu_idx"):
            arr = np.empty(cap, dtype=np.int64)
            arr[:self._n] = getattr(self, col)[:self._n]
            setattr(self, col, arr)
        live = np.zeros(cap, dtype=bool)
        live[:self._n] = self._live[:self._n]
        self._live = live

    def add(self, task: Task, pu: str, pred: TaskPrediction,
            now: float) -> ActiveEntry:
        if self._n == len(self._est):
            self._grow()
        i = self._n
        self._n += 1
        est = now + pred.total
        self._tasks.append(task)
        self._pus.append(pu)
        self._est[i] = est
        self._fac[i] = pred.factor
        self._dl[i] = task.deadline if task.deadline is not None else np.inf
        self._upu[i] = task.usage.get("pu", 1.0)
        self._umem[i] = task.usage.get("mem", 1.0)
        self._uid[i] = task.uid
        # compiled PU index column (pu_index dicts are shared across delta
        # clones, so the column survives topology patches)
        self._pu_idx[i] = (self._pu_idx_comp.get(pu, -1)
                           if self._pu_idx_comp is not None else -1)
        self._live[i] = True
        self._count[pu] = self._count.get(pu, 0) + 1
        self.version += 1
        dev = self._pu_dev.get(pu)
        if dev is None:
            self.dev_epoch += 1
        else:
            self.dev_version[dev] = self.dev_version.get(dev, 0) + 1
            self.mut_log.append(dev)
        if self._dev_rows is not None:
            if dev is None:
                self._dev_rows = None
            else:
                self._dev_rows.setdefault(dev, []).append(i)
        return ActiveEntry(task=task, pu=pu, est_finish=est, factor=pred.factor)

    def _kill(self, rows: np.ndarray) -> None:
        # attribute each kill to its device where possible so persistent
        # scan states only re-check those devices; fall back to the epoch
        # hammer when any row's PU has no known device
        devs: Optional[list[str]] = []
        for i in rows:
            pu = self._pus[i]
            dev = self._pu_dev.get(pu) if devs is not None else None
            if devs is not None:
                if dev is None:
                    devs = None
                else:
                    devs.append(dev)
            self._live[i] = False
            self._count[pu] -= 1
            if not self._count[pu]:
                del self._count[pu]
            self._tasks[i] = None
            self._dead += 1
        self.version += 1
        if devs is None:
            self.dev_epoch += 1
            self._dev_rows = None
        else:
            killed = set(rows.tolist())
            for dev in set(devs):
                self.dev_version[dev] = self.dev_version.get(dev, 0) + 1
                self.mut_log.append(dev)
                if self._dev_rows is not None:
                    old = self._dev_rows.get(dev)
                    if old is not None:
                        self._dev_rows[dev] = [i for i in old
                                               if i not in killed]
        if self._dead > 32 and self._dead * 2 > self._n:
            self._compact()

    def _compact(self) -> None:
        keep = np.nonzero(self._live[:self._n])[0]
        self._tasks = [self._tasks[i] for i in keep]
        self._pus = [self._pus[i] for i in keep]
        for col in ("_est", "_fac", "_dl", "_upu", "_umem", "_uid",
                    "_pu_idx"):
            setattr(self, col, getattr(self, col)[keep].copy())
        self._live = np.ones(len(keep), dtype=bool)
        self._n = len(keep)
        self._dead = 0
        # row numbers changed; per-device row lists must be rebuilt (values
        # read through the compacted arrays stay correct, so no epoch bump)
        self._dev_rows = None

    def touch(self, dev: str) -> None:
        """Record an out-of-band state change on device ``dev`` (e.g. the
        session charging scheduling overhead into a resident task's
        release_time) so cached views and persistent scan states refresh
        that device's rows."""
        self.version += 1
        self.dev_version[dev] = self.dev_version.get(dev, 0) + 1
        self.mut_log.append(dev)
        self._live_view = None

    def occupied_devices(self, comp) -> set:
        """Device names with at least one live ledger row — the rows whose
        tenancy-wait / l.15 terms depend on ``now`` and must be re-checked
        when a persistent scan state is reused at a later wall-clock."""
        out = set()
        dev_of = self._pu_dev
        for pu in self._count:
            dev = dev_of.get(pu)
            if dev is None:
                dev = dev_of[pu] = comp.device_name(pu)
            out.add(dev)
        return out

    def prune(self, now: float) -> None:
        if not self._n:
            return
        kill = self._live[:self._n] & (self._est[:self._n] <= now)
        if kill.any():
            self._kill(np.nonzero(kill)[0])

    def remove(self, task: Task) -> None:
        if not self._n:
            return
        kill = self._live[:self._n] & (self._uid[:self._n] == task.uid)
        if kill.any():
            self._kill(np.nonzero(kill)[0])

    def retire(self, uids) -> int:
        """Batch-remove *actually completed* tasks (serving-loop ledger
        reconciliation: the resident timeline's ``drain_finished`` feed,
        vs. ``prune``'s estimated-finish beliefs).  Returns rows killed;
        uids already pruned or never ledgered are ignored."""
        if not self._n:
            return 0
        uids = np.asarray(list(uids), dtype=np.int64)
        if not len(uids):
            return 0
        kill = self._live[:self._n] & np.isin(self._uid[:self._n], uids)
        n = int(kill.sum())
        if n:
            self._kill(np.nonzero(kill)[0])
        return n

    def count(self, pu: str) -> int:
        return self._count.get(pu, 0)

    def shard_for(self, dev: str) -> "ActiveLedger":
        """The ledger shard owning device ``dev`` — a monolithic ledger
        is its own (only) shard.  The single dispatch point the batch
        context and walk drivers use, so :class:`ShardedLedger` routes
        per-device accesses without any call-site branching."""
        return self

    # -- array views -------------------------------------------------------
    def _fill_pu_idx(self, comp) -> None:
        """(Re)fill the compiled-index column for this snapshot family —
        ``add`` keeps it current incrementally afterwards (pu_index dicts
        are shared across delta clones, so it survives topology patches)."""
        if self._pu_idx_comp is not comp.pu_index:
            self._pu_idx_comp = comp.pu_index
            for i in range(self._n):
                pu = self._pus[i]
                self._pu_idx[i] = (comp.pu_index.get(pu, -1)
                                   if pu is not None else -1)

    def _device_rows(self, comp) -> dict[str, list[int]]:
        if self._dev_rows is None:
            dev_of = self._pu_dev
            rows: dict[str, list[int]] = {}
            for i in range(self._n):
                if not self._live[i]:
                    continue
                pu = self._pus[i]
                dev = dev_of.get(pu)
                if dev is None:
                    dev = dev_of[pu] = comp.device_name(pu)
                rows.setdefault(dev, []).append(i)
            self._dev_rows = rows
        return self._dev_rows

    def device_view(self, comp, dev: str) -> _LedgerView:
        """Dense ledger columns of the live rows on device ``dev``.

        Carries the same per-device-ordinal segment arrays as
        :meth:`live_view` (zero everywhere but ``dev``), so the
        block-diagonal kernel accepts either view interchangeably."""
        rows = self._device_rows(comp).get(dev, ())
        v = _LedgerView()
        r = np.fromiter(rows, dtype=np.int64, count=len(rows))
        v.rows = r
        v.pu_names = [self._pus[i] for i in rows]
        self._fill_pu_idx(comp)
        v.P = self._pu_idx[r]
        v.est = self._est[r]
        v.fac = self._fac[r]
        v.dl = self._dl[r]
        v.upu = self._upu[r]
        v.umem = self._umem[r]
        v.Ma = np.minimum(v.umem, comp.mem_cap[v.P])
        v.uid = self._uid[r]
        v.tasks = [self._tasks[i] for i in rows]
        # release times are read LIVE from the tasks: the runtime charges
        # scheduling overhead into release_time after a commit, and the
        # Alg. 1 l.15 re-check must see the charged value (seed semantics)
        v.rel = np.array([t.release_time for t in v.tasks]) if rows \
            else np.zeros(0)
        o = comp.dev_ord.get(dev)
        nd = len(comp.dev_ord_names)
        v.na = np.zeros(nd, dtype=np.int64)
        v.astart = np.zeros(nd, dtype=np.int64)
        if o is not None:
            v.na[o] = len(rows)
            v.Da = np.full(len(rows), o, dtype=np.int64)
        else:
            v.Da = np.zeros(len(rows), dtype=np.int64)
        return v

    def live_view(self, comp) -> _LedgerView:
        """All live rows, sorted by device ordinal (stable, so per-device
        row order matches ``device_view``), with segment offsets for the
        block-diagonal constraint-check kernel.  Cached per (snapshot,
        ledger version)."""
        cached = self._live_view
        if cached is not None and cached[0] is comp and cached[1] == self.version:
            return cached[2]
        self._fill_pu_idx(comp)
        v = _LedgerView()
        r = np.nonzero(self._live[:self._n])[0]
        P = self._pu_idx[r]
        D = comp.pu_dev_ord[P] if len(r) else np.zeros(0, dtype=np.int64)
        order = np.argsort(D, kind="stable")
        r, P, D = r[order], P[order], D[order]
        v.rows = r
        v.pu_names = [self._pus[i] for i in r]
        v.P = P
        v.Da = D
        v.est = self._est[r]
        v.fac = self._fac[r]
        v.dl = self._dl[r]
        v.upu = self._upu[r]
        v.umem = self._umem[r]
        v.Ma = np.minimum(v.umem, comp.mem_cap[P]) if len(r) \
            else np.zeros(0)
        v.uid = self._uid[r]
        v.tasks = [self._tasks[i] for i in r]
        # live release_time reads — see device_view
        v.rel = (np.array([t.release_time for t in v.tasks]) if len(r)
                 else np.zeros(0))
        nd = len(comp.dev_ord_names)
        v.na = np.bincount(D, minlength=nd) if len(r) \
            else np.zeros(nd, dtype=np.int64)
        v.astart = np.cumsum(v.na) - v.na
        self._live_view = (comp, self.version, v)
        return v

    # -- object-view compatibility accessors (deprecated) ------------------
    def _entry(self, i: int) -> ActiveEntry:
        return ActiveEntry(task=self._tasks[i], pu=self._pus[i],
                           est_finish=float(self._est[i]),
                           factor=float(self._fac[i]))

    @property
    def by_pu(self) -> dict[str, list[ActiveEntry]]:
        out: dict[str, list[ActiveEntry]] = {}
        for i in range(self._n):
            if self._live[i]:
                out.setdefault(self._pus[i], []).append(self._entry(i))
        return out

    def on_device(self, graph: HWGraph, pu_name: str) -> list[ActiveEntry]:
        comp = graph.compiled()
        dev = comp.device_name(pu_name)
        return [self._entry(i)
                for i in self._device_rows(comp).get(dev, ())]

    def pairs_on_device(self, graph: HWGraph, pu_name: str) -> list[tuple[Task, str]]:
        return [(e.task, e.pu) for e in self.on_device(graph, pu_name)]


class _ShardDevVersions:
    """Dict-shaped dispatch of per-device version stamps to the owning
    ledger shard (the surface scan states read via ``dev_version.get``)."""

    __slots__ = ("_led",)

    def __init__(self, led: "ShardedLedger") -> None:
        self._led = led

    def get(self, dev: str, default: int = 0) -> int:
        return self._led.shard_for(dev).dev_version.get(dev, default)


class ShardedLedger:
    """Per-ORC-group :class:`ActiveLedger` shards behind the monolithic
    ledger surface.

    Each shard owns exactly the rows of its group's devices (commits
    dispatch by the committed PU's enclosing device), so per-device reads
    — the unit every constraint check consumes — hit one shard with no
    cross-shard coordination, and independent groups' walks can fan out
    over threads without sharing ledger state.  The **thin cross-group
    reconciler** is :meth:`live_view`: the root ORC's boundary scan is the
    only consumer that needs all groups at once, and the merged view
    interleaves the shards' device segments back into global device-
    ordinal order (stable, preserving per-device insertion order), which
    makes it bit-identical to the monolithic ledger's global view.

    Installed by ``Orchestrator.prepare`` when group sharding is enabled;
    every content-bearing accessor returns exactly what a monolithic
    ledger holding the same rows would (the sharded-vs-fused parity suite
    pins this)."""

    def __init__(self, comp, sharded_hw) -> None:
        self.hw = sharded_hw
        self.shards: list[ActiveLedger] = [ActiveLedger()
                                           for _ in sharded_hw.shards]
        self._pu_dev: dict[str, str] = {}      # shared by every shard
        self._by_dev: dict[str, ActiveLedger] = {}
        self._by_pu: dict[str, ActiveLedger] = {}
        self._default = self.shards[0]
        for gs, led in zip(sharded_hw.shards, self.shards):
            led._pu_dev = self._pu_dev
            for d in gs.devices:
                self._by_dev[d] = led
            for p in gs.pu_names:
                self._by_pu[p] = led
        self._pu_dev.update(comp._pu_device_name)
        self._dev_versions = _ShardDevVersions(self)
        self._merged: Optional[tuple] = None
        # one shared mutation journal across shards: attributed mutations
        # must stay globally ordered for persistent scan-state refreshes
        self.mut_log: list[str] = []
        for led in self.shards:
            led.mut_log = self.mut_log

    # -- shard dispatch ----------------------------------------------------
    def shard_for(self, dev: str) -> ActiveLedger:
        return self._by_dev.get(dev, self._default)

    def _shard_for_pu(self, pu: str) -> ActiveLedger:
        led = self._by_pu.get(pu)
        if led is None:
            dev = self._pu_dev.get(pu)
            led = self._by_dev.get(dev, self._default) if dev is not None \
                else self._default
        return led

    # -- monolithic surface ------------------------------------------------
    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    @property
    def version(self) -> int:
        return sum(s.version for s in self.shards)

    @property
    def dev_epoch(self) -> int:
        return sum(s.dev_epoch for s in self.shards)

    @property
    def dev_version(self) -> _ShardDevVersions:
        return self._dev_versions

    @property
    def _live_view(self) -> Optional[tuple]:
        return self._merged

    @_live_view.setter
    def _live_view(self, value) -> None:
        # map_batch drops the cross-batch global view (release times may
        # have been charged since); propagate to every shard's cache
        self._merged = value
        if value is None:
            for s in self.shards:
                s._live_view = None

    def add(self, task: Task, pu: str, pred: TaskPrediction,
            now: float) -> ActiveEntry:
        return self._shard_for_pu(pu).add(task, pu, pred, now)

    def prune(self, now: float) -> None:
        for s in self.shards:
            s.prune(now)

    def remove(self, task: Task) -> None:
        for s in self.shards:
            s.remove(task)

    def retire(self, uids) -> int:
        uids = list(uids)
        return sum(s.retire(uids) for s in self.shards)

    def count(self, pu: str) -> int:
        return self._shard_for_pu(pu).count(pu)

    def touch(self, dev: str) -> None:
        self.shard_for(dev).touch(dev)
        self._merged = None

    def occupied_devices(self, comp) -> set:
        out: set = set()
        for s in self.shards:
            out |= s.occupied_devices(comp)
        return out

    def _fill_pu_idx(self, comp) -> None:
        for s in self.shards:
            s._fill_pu_idx(comp)

    def device_view(self, comp, dev: str) -> _LedgerView:
        return self.shard_for(dev).device_view(comp, dev)

    def live_view(self, comp) -> _LedgerView:
        """The cross-group reconciler: every shard's live rows interleaved
        back into global device-ordinal order.  Within one device ordinal
        all rows come from the one shard owning that device, already in
        insertion order, so a stable sort over the concatenation is
        bit-identical to the monolithic global view."""
        cached = self._merged
        if cached is not None and cached[0] is comp \
                and cached[1] == self.version:
            return cached[2]
        views = [s.live_view(comp) for s in self.shards]
        v = _LedgerView()
        D = np.concatenate([w.Da for w in views])
        order = np.argsort(D, kind="stable")
        v.Da = D[order]
        for col in ("rows", "P", "est", "fac", "dl", "rel", "upu",
                    "umem", "Ma", "uid"):
            v_col = np.concatenate([getattr(w, col) for w in views])
            setattr(v, col, v_col[order])
        names = [n for w in views for n in w.pu_names]
        tasks = [t for w in views for t in w.tasks]
        idx = order.tolist()
        v.pu_names = [names[i] for i in idx]
        v.tasks = [tasks[i] for i in idx]
        nd = len(comp.dev_ord_names)
        v.na = (np.bincount(v.Da, minlength=nd) if len(v.Da)
                else np.zeros(nd, dtype=np.int64))
        v.astart = np.cumsum(v.na) - v.na
        self._merged = (comp, self.version, v)
        return v

    # -- object-view compatibility accessors -------------------------------
    @property
    def by_pu(self) -> dict[str, list[ActiveEntry]]:
        out: dict[str, list[ActiveEntry]] = {}
        for s in self.shards:
            for pu, entries in s.by_pu.items():
                out.setdefault(pu, []).extend(entries)
        return out

    def on_device(self, graph: HWGraph, pu_name: str) -> list[ActiveEntry]:
        comp = graph.compiled()
        dev = comp.device_name(pu_name)
        return self.shard_for(dev).on_device(graph, pu_name)

    def pairs_on_device(self, graph: HWGraph,
                        pu_name: str) -> list[tuple[Task, str]]:
        return [(e.task, e.pu) for e in self.on_device(graph, pu_name)]


@dataclass
class MapResult:
    pu: str
    prediction: TaskPrediction
    overhead: float = 0.0        # scheduling overhead in seconds (Fig. 14)
    queries: int = 0             # constraint checks performed
    hops: int = 0                # remote ORC-to-ORC messages


@dataclass
class OrcConfig:
    local_query_cost: float = 5e-6    # CPU time per candidate constraint check
    objective: str = "best_fit"       # "best_fit" | "first_fit" | "min_load"
    allow_best_effort: bool = True    # if nothing satisfies, pick least-bad PU


class _StaticScore:
    """The ledger-independent half of a fused candidate scoring: shared
    across a batch for every (task signature, candidate set) pair."""

    __slots__ = ("pu_names", "cols", "cand_idx", "cand_dev", "sa", "comm",
                 "maxten", "single_dev")


class _ScanPlan:
    """One ORC subtree lowered to arrays: the preorder node list of a scan
    root with per-node subtree PU ranges, leaf/child counts, summed hop
    costs and depths — everything ``kernels.walk_kernel.scan_reduce`` needs
    to replay Alg. 1's TraverseChildren accounting in closed form.  Built
    lazily per compiled snapshot (hop costs are snapshot functions)."""

    __slots__ = ("pus", "pu_lo", "pu_hi", "leafcnt", "nchild", "hopsum",
                 "depth", "leaf_groups", "devs", "dev_ranges", "dev_sublists")


class _ChildPlan:
    """One ORC's children lowered for the AskParent sibling scan: every
    child subtree concatenated into one candidate list, with per-child
    slice bounds and the running hop-cost prefix Alg. 1 charges while
    iterating siblings.  One plan serves every asking child (the asker's
    own slice is masked out at selection time), so its scan state — and
    the kernel work behind it — is shared across all escalations through
    this parent."""

    __slots__ = ("children", "child_pos", "pus", "bounds", "hc",
                 "hop_prefix", "devs", "dev_ranges", "dev_sublists",
                 "leaf_groups")


class _ScanState:
    """The origin-independent core of one (task core, candidate list)
    scan — eligibility+l.15 feasibility, standalone, factor and additive
    tenancy-wait columns — plus the freshness stamps that tell a later
    walk which device segments an intervening commit invalidated.

    Everything origin-dependent (the comm column, the deadline mask) is
    layered on per task signature by ``Orchestrator._effective``, so all
    signatures sharing a core (same kind/size/usage/compute attrs) share
    one state and one set of kernel calls."""

    __slots__ = ("ok", "sa", "f", "wait", "epoch", "stamps", "log_pos",
                 "now", "refresh_log", "expiry")

    def __init__(self, n: int) -> None:
        self.ok = np.zeros(n, dtype=bool)
        self.sa = np.full(n, np.inf)
        self.f = np.ones(n)
        self.wait = np.zeros(n)
        # per-device valid-until instant of the last splice: an occupied
        # device whose constraint outputs are provably constant until a
        # known flip time skips clock-move re-splices entirely
        self.expiry: dict = {}
        # wall-clock the columns were checked at: occupied devices'
        # tenancy-wait / l.15 terms are now-dependent, so a later-wave
        # reuse re-splices exactly those devices (empty devices are
        # now-independent — A==0 skips both blocks in the fused scorer)
        self.now = None
        # journal of device names this state re-spliced (per-signature
        # effective layers patch the union of the commit-log suffix and
        # this log's suffix they have not seen)
        self.refresh_log: list[str] = []


class _Walk:
    """One deduplicated phase-1 walk being wave-stepped through Alg. 1."""

    __slots__ = ("orc", "task", "cur", "scored", "res")

    def __init__(self, orc: "Orchestrator", task: Task) -> None:
        self.orc = orc
        self.task = task
        self.cur = orc          # the ORC whose parent is asked next
        self.scored: set = set()
        self.res: Optional["MapResult"] = None


class _BatchContext:
    """Per-``map_batch`` caches shared by every walk in one frontier.

    Everything here is a pure function of (snapshot, task signature) or of
    (ledger version, device), so sharing across the batch cannot change any
    individual mapping decision — it only removes repeated Python work."""

    def __init__(self, graph: HWGraph, comp, traverser: Traverser,
                 ledger: ActiveLedger) -> None:
        self.graph = graph
        self.comp = comp
        self.trav = traverser
        self.ledger = ledger
        self._supports: dict = {}
        self._standalone: dict = {}
        self._comm: dict = {}
        self._views: dict = {}
        self._static: dict = {}
        self._sigs: dict = {}
        self._cores: dict = {}
        self._mkeys: dict = {}
        self._puidx: dict = {}
        self._static_core: dict = {}
        # fused-walk scan states: (task sig, candidate-list id) -> _ScanState
        # holding that scan's constraint-check results plus freshness stamps;
        # commits splice in per-device refreshes instead of rescanning
        self.scan_states: dict = {}
        # per-(task sig, plan) effective columns (ok/cm/key), patched per
        # committed device on reuse — small FIFO, re-walk runs of equal
        # signatures dominate its hit pattern
        self.eff_cache: dict = {}
        # canonical-pattern cache of single-device core checks (splices):
        # (core sig, canonical device state) -> (ok, sa, f, wait) columns
        self.splice_cache: dict = {}
        # slowdown-factor cache of single-device checks, keyed by view
        # *identity* instead of content: (core sig, dev) -> (view, static,
        # factors).  Factors are now-independent, so a clock-moved
        # re-splice of an unchanged device skips the kernel (and both
        # canonical-key constructions) and re-runs only the constraint
        # block at the new instant
        self.factor_cache: dict = {}
        # the ledger's attributed-mutation journal (commits, retires,
        # touches), aliased so scan states refresh exactly the suffix of
        # mutations — in-batch commits *and* cross-wave session traffic —
        # they have not seen yet
        self.commit_log: list[str] = ledger.mut_log
        # teach the ledger every PU's device up front so commits bump only
        # their device's version (not the global epoch) — the fine-grained
        # signal the tracked scan states key their splices on
        ledger._pu_dev.update(comp._pu_device_name)

    def rebase(self, comp) -> None:
        """Adopt a bandwidth-only successor snapshot without dropping the
        persistent walk state.  Only the comm-bearing caches go (comm
        times, per-signature static scores and effective layers); the
        core scan states, canonical splices, views and static cores are
        bandwidth-independent (the caller has verified ``pu_alive`` /
        route topology / NCR identity)."""
        self.comp = comp
        self._comm = {}
        self._static = {}
        self.eff_cache = {}
        self.factor_cache = {}

    def _model_key(self, task: Task) -> tuple:
        hit = self._mkeys.get(id(task))
        if hit is None:
            hit = ((task.kind, task.size,
                    tuple((k, task.attrs[k]) for k in ("flops", "bytes",
                                                       "coll_bytes")
                          if k in task.attrs)), task)
            self._mkeys[id(task)] = hit     # task ref keeps the id stable
        return hit[0]

    def supports_mask(self, task: Task) -> np.ndarray:
        key = self._model_key(task)
        mask = self._supports.get(key)
        if mask is None:
            g = self.graph
            mask = np.fromiter(
                ((n.model is not None and n.model.supports(task, n))
                 for n in (g.nodes[p] for p in self.comp.pu_names)),
                dtype=bool, count=len(self.comp.pu_names))
            self._supports[key] = mask
        return mask

    def standalone(self, task: Task) -> np.ndarray:
        key = self._model_key(task)
        sa = self._standalone.get(key)
        if sa is None:
            g = self.graph
            sup = self.supports_mask(task)
            sa = np.full(len(self.comp.pu_names), np.inf)
            for i, p in enumerate(self.comp.pu_names):
                if sup[i]:
                    sa[i] = g.nodes[p].predict(task)
            self._standalone[key] = sa
        return sa

    def comm(self, task: Task, dev: str) -> float:
        key = (dev, task.input_bytes, task.origin,
               tuple(task.attrs.get("src_devices") or ()))
        c = self._comm.get(key)
        if c is None:
            c = self.trav.comm_time_dev(task, dev, self.comp)
            self._comm[key] = c
        return c

    def core_sig(self, task: Task) -> tuple:
        """The origin-independent slice of :meth:`task_sig`: exactly the
        fields the eligibility and factor/constraint kernels read (kind,
        size, usage, compute attrs — plus origin for pinned tasks, whose
        candidate set it restricts).  Signatures sharing a core produce
        bit-identical core scan columns, so they share one tracked scan
        state; comm and deadline are layered back on per signature."""
        sig = self._cores.get(id(task))
        if sig is None:
            pinned = bool(task.attrs.get("pinned"))
            s = (task.kind, task.size, pinned,
                 task.origin if pinned else None,
                 tuple(sorted(task.usage.items())),
                 tuple((k, task.attrs[k]) for k in ("flops", "bytes",
                                                    "coll_bytes")
                       if k in task.attrs))
            sig = (s, task)
            self._cores[id(task)] = sig     # task ref keeps the id stable
        return sig[0]

    def pu_idx(self, pu_names: list[str]) -> np.ndarray:
        """Compiled PU ordinal (or -1) per name, cached per candidate
        list — walk plans re-scan the same lists for every signature.
        The cached entry holds the list itself so its id stays live."""
        key = id(pu_names)
        hit = self._puidx.get(key)
        if hit is None:
            idx = np.fromiter(
                (self.comp.pu_index.get(p, -1) for p in pu_names),
                dtype=np.int64, count=len(pu_names))
            hit = (idx, pu_names)
            self._puidx[key] = hit
        return hit[0]

    def view(self, dev: str) -> _LedgerView:
        led = self.ledger.shard_for(dev)
        epoch = led.dev_epoch
        ver = led.dev_version.get(dev, 0)
        hit = self._views.get(dev)
        if hit is not None and hit[0] == epoch and hit[1] == ver:
            return hit[2]
        v = None
        if hit is not None and hit[0] == epoch and hit[1] == ver - 1:
            # a device-version bump within one epoch whose row count grew
            # by one is exactly one ledger add: extend the previous view
            # by that row instead of re-gathering every column (any other
            # shape — a kill, a touch — re-gathers, which also re-reads
            # release times charged by the session between waves)
            v = self._extend_view(hit[2], dev)
        if v is None:
            v = led.device_view(self.comp, dev)
        self._views[dev] = (epoch, ver, v)
        return v

    def _extend_view(self, prev: _LedgerView,
                     dev: str) -> Optional[_LedgerView]:
        led = self.ledger.shard_for(dev)
        comp = self.comp
        rows = led._device_rows(comp).get(dev)
        if rows is None or len(rows) != len(prev.rows) + 1:
            return None
        led._fill_pu_idx(comp)
        i = rows[-1]
        pidx = int(led._pu_idx[i])
        if pidx < 0:
            return None
        v = _LedgerView()
        v.rows = np.append(prev.rows, i)
        v.pu_names = prev.pu_names + [led._pus[i]]
        v.P = np.append(prev.P, pidx)
        v.est = np.append(prev.est, led._est[i])
        v.fac = np.append(prev.fac, led._fac[i])
        v.dl = np.append(prev.dl, led._dl[i])
        v.upu = np.append(prev.upu, led._upu[i])
        umem = led._umem[i]
        v.umem = np.append(prev.umem, umem)
        v.Ma = np.append(prev.Ma, min(umem, comp.mem_cap[pidx]))
        v.uid = np.append(prev.uid, led._uid[i])
        t = led._tasks[i]
        v.tasks = prev.tasks + [t]
        v.rel = np.append(prev.rel, t.release_time)
        o = comp.dev_ord.get(dev)
        v.na = prev.na.copy()
        v.astart = prev.astart
        if o is not None:
            v.na[o] = len(rows)
            v.Da = np.full(len(rows), o, dtype=np.int64)
        else:
            v.Da = np.zeros(len(rows), dtype=np.int64)
        return v

    def task_sig(self, task: Task) -> tuple:
        sig = self._sigs.get(id(task))
        if sig is None:
            sig = (Orchestrator._task_signature(None, task), task)
            self._sigs[id(task)] = sig      # task ref keeps the id stable
        return sig[0]

    def static_score(self, orc: "Orchestrator", task: Task,
                     pu_names: list[str]) -> _StaticScore:
        """Ledger-independent scoring inputs, cached per (task signature,
        candidate list).  The cached value holds the candidate list itself
        so its id cannot be recycled while the entry lives."""
        key = (self.task_sig(task), id(pu_names))
        hit = self._static.get(key)
        if hit is None:
            hit = (orc._static_score(task, pu_names, self.comp, self),
                   pu_names)
            self._static[key] = hit
        return hit[0]

    def static_core(self, orc: "Orchestrator", task: Task,
                    pu_names: list[str]) -> _StaticScore:
        """Like :meth:`static_score` but keyed by the task *core* and
        without the (origin-dependent) comm column — the inputs of the
        shared core scan states, computed once per core instead of once
        per signature."""
        key = (self.core_sig(task), id(pu_names))
        hit = self._static_core.get(key)
        if hit is None:
            hit = (orc._static_score(task, pu_names, self.comp, self,
                                     skip_comm=True),
                   pu_names)
            self._static_core[key] = hit
        return hit[0]


class Orchestrator:
    def __init__(self, graph: HWGraph, group: str, traverser: Traverser,
                 ledger: ActiveLedger, config: Optional[OrcConfig] = None,
                 parent: Optional["Orchestrator"] = None) -> None:
        self.graph = graph
        self.group = group
        self.traverser = traverser
        self.ledger = ledger
        self.config = config or OrcConfig()
        self.parent = parent
        self.children: list["Orchestrator"] = []
        self.leaf_pus: list[str] = []
        self._device_orcs: Optional[dict[str, "Orchestrator"]] = None
        self._subtree_pus_cache: Optional[list[str]] = None
        self._hop_cache: Optional[tuple] = None
        self._plan_cache: Optional[tuple] = None   # (comp, _ScanPlan)
        self._child_cache: Optional[tuple] = None  # (comp, _ChildPlan)
        self._sharded_hw: Optional["ShardedHWGraph"] = None  # root only
        # session-resident batch context (the serving fast path): survives
        # map_batch calls so steady-state waves pay only dirty-device work
        self._resident_ctx: Optional["_BatchContext"] = None

    # -- hierarchy ----------------------------------------------------------
    def add_child(self, child: "Orchestrator") -> "Orchestrator":
        child.parent = self
        self.children.append(child)
        node: Optional["Orchestrator"] = self
        while node is not None:
            node._device_orcs = None
            node._subtree_pus_cache = None
            node._plan_cache = None
            node._child_cache = None
            node._resident_ctx = None
            node = node.parent
        return child

    def _subtree_pus(self) -> list[str]:
        """Every leaf PU managed below (and at) this ORC, in tree order —
        the candidate universe one fused constraint check covers."""
        if self._subtree_pus_cache is None:
            out: list[str] = []
            for orc in self.iter_tree():
                out.extend(orc.leaf_pus)
            self._subtree_pus_cache = out
        return self._subtree_pus_cache

    def is_device_orc(self) -> bool:
        return bool(self.leaf_pus)

    def prepare(self, comp=None) -> "Orchestrator":
        """Prebuild the compiled scan/child plans of the whole ORC tree
        against ``comp`` (default: the graph's current snapshot).

        Pure one-time lowering work — the plans are cached per snapshot
        either way — so callers that construct the tree ahead of time
        (sessions, benchmarks) keep it out of the first mapping wave."""
        if comp is None:
            comp = self.graph.compiled()
        for orc in self.iter_tree():
            orc._scan_plan(comp)
            if orc.children:
                orc._child_plan(comp)
        if self._sharding_enabled():
            self._install_sharding(comp)
        return self

    # -- group sharding ------------------------------------------------------
    def _sharding_enabled(self) -> bool:
        """Group sharding applies at a root ORC with >=2 group subtrees
        and is oracle-gated: ``REPRO_SHARDED_WALK=0`` keeps the fused
        single-shard walk (and the monolithic ledger) as the bit-identical
        parity baseline."""
        return (self.parent is None and len(self.children) > 1
                and os.environ.get("REPRO_SHARDED_WALK", "1") != "0")

    def _install_sharding(self, comp) -> None:
        """Shard the snapshot and ledger per root-child ORC group.

        Builds the :class:`ShardedHWGraph` partition (one shard per root
        child, owning that subtree's device groups), validates the
        block-diagonal NCR invariant, and swaps the whole tree's (empty)
        ledger for a :class:`ShardedLedger` over that partition.  A
        non-empty or already-sharded ledger, or a partition that fails
        validation, leaves the monolithic setup untouched."""
        if type(self.ledger) is not ActiveLedger or len(self.ledger):
            return
        sharded = getattr(comp, "sharded", None)
        if sharded is None:
            return
        groups = {c.group: [o.group for o in c.iter_tree()
                            if o.is_device_orc()]
                  for c in self.children}
        try:
            shg = sharded(groups)
        except ValueError:
            return                    # not block-diagonal: stay monolithic
        led = ShardedLedger(comp, shg)
        for orc in self.iter_tree():
            orc.ledger = led
        self._sharded_hw = shg

    # -- canonical factor-cache visibility (bench JSON / CI smoke) ----------
    @property
    def factor_cache_hits(self) -> int:
        return int(getattr(self.traverser.slowdown, "factor_cache_hits", 0))

    @property
    def factor_cache_misses(self) -> int:
        return int(getattr(self.traverser.slowdown, "factor_cache_misses", 0))

    def __repr__(self) -> str:
        return f"ORC({self.group})"

    # -- Alg. 1, batch-first -------------------------------------------------
    def map_batch(self, tasks: Iterable[Task], now: float = 0.0,
                  commit: bool = True,
                  route: bool = False) -> list[Optional[MapResult]]:
        """Map a frontier of ready tasks in one call (Alg. 1 per task).

        Semantics are identical to running Alg. 1 once per task in
        order (the parity suite pins this at 1e-9): tasks are scored
        optimistically against the ledger as of batch start, committed in
        order, and re-scored only when an earlier commit touched a device
        their search scored.  With ``route=True`` each task enters at the
        device ORC of its origin (the session/policy entry path) instead
        of at ``self``.
        """
        tasks = list(tasks)
        if not tasks:
            return []
        self.ledger.prune(now)
        # release_time of resident tasks may have been charged with overhead
        # since the last batch (a mutation the ledger version cannot see):
        # drop the cross-batch global view so l.15 reads the charged values
        self.ledger._live_view = None
        comp = self.graph.compiled()
        sd = self.traverser.slowdown
        noisy = bool(getattr(sd, "_noisy", lambda: False)())
        # fused wave-batched walk: lowers Alg. 1's recursion to scan plans
        # + one closed-form reduce per scan, wave-batching the constraint
        # checks of each escalation depth into one multi-newcomer kernel
        # call.  Gated to the deterministic batch path: noisy models need
        # the scalar rng stream order and first_fit the early-return walk.
        fusable = (not noisy and self.config.objective != "first_fit"
                   and hasattr(sd, "factors_same_device_multi")
                   and os.environ.get("REPRO_FUSED_WALK", "1") != "0")
        if fusable and os.environ.get("REPRO_SERVE_FASTPATH", "1") != "0":
            # serving fast path: a session-resident context keeps the
            # prepared walk state across waves, and single-task waves run
            # the fused walk too (only dirty devices are re-checked).
            # REPRO_SERVE_FASTPATH=0 restores the per-batch cold context
            # (and the object walk for single-task waves) as the parity
            # oracle.
            ctx = self._session_context(comp)
        else:
            ctx = (_BatchContext(self.graph, comp, self.traverser,
                                 self.ledger)
                   if len(tasks) > 1 else None)
        fast = fusable and ctx is not None
        # phase 1: optimistic walks against the frozen ledger, deduped by
        # task signature (identical tasks walk once; commits are replayed
        # per task in phase 2)
        tentative: list[tuple["Orchestrator", Optional[MapResult], set]] = []
        if fast:
            if self._sharding_enabled():
                walks = self._walk_wave_sharded(tasks, now, ctx, route)
            else:
                walks = self._walk_wave(tasks, now, ctx, route)
            for t in tasks:
                orc = self._entry_orc(t) if route else self
                w = walks[self._task_signature(orc, t)]
                res = (dataclasses.replace(w.res)
                       if w.res is not None else None)
                tentative.append((orc, res, w.scored))
        else:
            phase1: dict = {}
            for t in tasks:
                orc = self._entry_orc(t) if route else self
                key = None if noisy else self._task_signature(orc, t)
                hit = phase1.get(key) if key is not None else None
                if hit is not None:
                    res0, scored = hit
                    res = (dataclasses.replace(res0)
                           if res0 is not None else None)
                else:
                    scored = set()
                    res = orc._map_once(t, now, ctx, scored)
                    if key is not None:
                        phase1[key] = (res, scored)
                tentative.append((orc, res, scored))
        # phase 2: ordered commit; re-walk when the optimistic result is
        # stale (an earlier commit landed on a device this walk scored).
        # Fast re-walks splice only the committed devices' segments back
        # into the tracked scans (the commit log tells each scan exactly
        # which suffix of commits it has not seen yet).
        dirty: set[str] = set()
        out: list[Optional[MapResult]] = []
        warmed = not fast
        for i, (t, (orc, res, scored)) in enumerate(zip(tasks, tentative)):
            if dirty and not dirty.isdisjoint(scored):
                if not warmed:
                    # first re-walk of the batch: warm the comm-LUT route
                    # rows of every task still to commit in one batched
                    # Dijkstra instead of one lazy row build per re-walk
                    er = getattr(comp, "ensure_routes", None)
                    if er is not None:
                        warm: set = set()
                        for t2 in tasks[i:]:
                            if t2.origin is not None:
                                warm.add(t2.origin)
                            warm.update(t2.attrs.get("src_devices") or ())
                        er(warm)
                    warmed = True
                res = (orc._map_once_fast(t, now, ctx, None) if fast
                       else orc._map_once(t, now, ctx, set()))
            if res is not None and commit:
                # ledger.add journals the commit's device into mut_log —
                # the log every batch context aliases as its commit_log
                self.ledger.add(t, res.pu, res.prediction, now)
                t.assigned_pu = res.pu
                dirty.add(comp.device_name(res.pu))
            out.append(res)
        return out

    def _session_context(self, comp) -> _BatchContext:
        """The session-resident :class:`_BatchContext` for ``comp``,
        reused across ``map_batch`` calls (the serving fast path).

        Reuse rules: same graph and ledger, and either the same snapshot
        or a bandwidth-only successor (``pu_alive``, route topology, PU
        index and the NCR/memory arrays all identity-equal — then the
        core scan states, canonical splices and ledger views stay valid
        and only the comm-bearing caches are rebuilt).  Anything else —
        device death/revival, NCR refresh, a swapped ledger — drops the
        context and the next wave pays one cold build."""
        ctx = self._resident_ctx
        led = self.ledger
        if ctx is not None and (ctx.ledger is not led
                                or ctx.graph is not self.graph
                                or len(led.mut_log) > 50_000):
            ctx = None
        if ctx is not None and ctx.comp is not comp:
            old = ctx.comp
            if (comp.pu_alive is old.pu_alive
                    and getattr(comp, "_rt", None) is not None
                    and getattr(old, "_rt", None) is not None
                    and comp._rt.topo is old._rt.topo
                    and comp.pu_index is old.pu_index
                    and comp.ncr_rclass is old.ncr_rclass
                    and comp.mem_cap is old.mem_cap):
                ctx.rebase(comp)
            else:
                ctx = None
        if ctx is None:
            if len(led.mut_log) > 50_000 and self._resident_ctx is not None:
                # no live context references the journal any more; reset
                # it in place (shards alias the same list)
                del led.mut_log[:]
            ctx = _BatchContext(self.graph, comp, self.traverser, led)
            self._resident_ctx = ctx
        elif len(ctx._sigs) > 8192:
            # id(task)-keyed memo caches accrete one entry per request
            # over a serving session; they are pure memos, safe to drop
            ctx._sigs = {}
            ctx._cores = {}
            ctx._mkeys = {}
        return ctx

    # ``map_task`` was deprecated in PR 3 and removed in PR 8: map
    # one-element frontiers with ``map_batch([task], now)[0]`` or drive
    # whole TaskGraphs through ``core.session.SchedulerSession``.

    # -- fused wave-batched walk (the array lowering of Alg. 1) --------------
    def _scan_plan(self, comp) -> _ScanPlan:
        """This ORC's subtree lowered to scan arrays (cached per snapshot)."""
        cache = self._plan_cache
        if cache is not None and cache[0] is comp:
            return cache[1]
        p = _ScanPlan()
        p.pus = self._subtree_pus()
        pu_lo: list[int] = []
        pu_hi: list[int] = []
        leafcnt: list[int] = []
        nchild: list[int] = []
        hopsum: list[float] = []
        depth: list[int] = []
        p.leaf_groups = []
        p.devs = []
        p.dev_ranges = {}
        p.dev_sublists = {}
        cursor = 0

        def build(orc: "Orchestrator", d: int) -> None:
            nonlocal cursor
            i = len(pu_lo)
            pu_lo.append(cursor)
            pu_hi.append(0)          # patched after the subtree is laid out
            n_leaf = len(orc.leaf_pus)
            leafcnt.append(n_leaf)
            nchild.append(len(orc.children))
            depth.append(d)
            h = 0.0
            for c in orc.children:
                h += orc._hop_cost(c)
            hopsum.append(h)
            if n_leaf:
                p.leaf_groups.append(orc.group)
                p.devs.append(orc.group)
                p.dev_ranges[orc.group] = (cursor, cursor + n_leaf)
                p.dev_sublists[orc.group] = orc.leaf_pus
            cursor += n_leaf
            for c in orc.children:
                build(c, d + 1)
            pu_hi[i] = cursor

        build(self, 0)
        p.pu_lo = np.asarray(pu_lo, dtype=np.int64)
        p.pu_hi = np.asarray(pu_hi, dtype=np.int64)
        p.leafcnt = np.asarray(leafcnt, dtype=np.int64)
        p.nchild = np.asarray(nchild, dtype=np.int64)
        p.hopsum = np.asarray(hopsum)
        p.depth = np.asarray(depth, dtype=np.float64)
        self._plan_cache = (comp, p)
        return p

    def _child_plan(self, comp) -> _ChildPlan:
        """Every child subtree concatenated into one AskParent candidate
        list (cached per snapshot).  All asking children share this one
        plan — and therefore one tracked scan state per task signature —
        with the asker's own slice masked out at selection time."""
        cache = self._child_cache
        if cache is not None and cache[0] is comp:
            return cache[1]
        cp = _ChildPlan()
        cp.children = list(self.children)
        cp.child_pos = {id(c): i for i, c in enumerate(cp.children)}
        cp.pus = []
        cp.devs = []
        cp.dev_ranges = {}
        cp.dev_sublists = {}
        cp.leaf_groups = []
        bounds = [0]
        hc = []
        prefix = []
        running = 0.0
        for c in cp.children:
            plan = c._scan_plan(comp)
            lo = len(cp.pus)
            cp.pus.extend(plan.pus)
            bounds.append(lo + len(plan.pus))
            h = self._hop_cost(c)
            hc.append(h)
            running += h
            prefix.append(running)
            for dev, (a, b) in plan.dev_ranges.items():
                cp.dev_ranges[dev] = (lo + a, lo + b)
                cp.dev_sublists[dev] = plan.dev_sublists[dev]
            cp.devs.extend(plan.devs)
            cp.leaf_groups.extend(plan.leaf_groups)
        cp.bounds = np.asarray(bounds, dtype=np.int64)
        cp.hc = np.asarray(hc)
        cp.hop_prefix = prefix
        # persistent scan states key on id(plan.pus): when a snapshot swap
        # rebuilds this plan with the same candidate list (the common case
        # — bandwidth churn, ledger-only waves), keep the previous list
        # object so those states and the per-list memo caches survive
        if cache is not None and cache[1].pus == cp.pus:
            cp.pus = cache[1].pus
        self._child_cache = (comp, cp)
        return cp

    def _check_arrays(self, task: Task, pu_names: list[str], now: float,
                      ctx: "_BatchContext") -> tuple:
        """Fused core check returning dense (ok, sa, f, wait) columns over
        ``pu_names`` (ineligible rows keep the infeasible defaults) —
        origin-independent, see :class:`_ScanState`.

        Single-device checks — the shape of every commit splice — are
        additionally cached by the device's *canonical* occupancy pattern
        (the slowdown kernel's structural key extended with everything
        else the constraint blocks read: active finish/factor/deadline/
        release columns, the candidates' standalone/tenancy inputs and
        the check instant).  Replicated fleets then pay one real check
        per occupancy stage instead of one per device."""
        n = len(pu_names)
        static = ctx.static_core(self, task, pu_names)
        cols = static.cols
        ck = None
        fused = None
        fkey = None
        view = None
        if len(cols) and static.single_dev is not None:
            sd = self.traverser.slowdown
            view = ctx.view(static.single_dev)
            fkey = (ctx.core_sig(task), static.single_dev)
            fent = ctx.factor_cache.get(fkey)
            if fent is not None and fent[0] is view and fent[1] is static:
                # identity hit: the device view object survives exactly
                # while (epoch, version) are unchanged, so the factors —
                # which never read the clock — are still exact.  Skip the
                # kernel *and* both canonical-key constructions; only the
                # constraint block below re-reads ``now``
                fused = (fent[2], view)
            else:
                canon = getattr(sd, "_canon_key", None)
                if canon is not None:
                    key, _ = canon(ctx.comp, task, static.cand_idx,
                                   static.cand_dev, view.P, view.upu,
                                   view.Ma, view.uid, view.astart, view.na)
                    if key is not None:
                        ck = (ctx.core_sig(task), key, n, now,
                              cols.tobytes(), static.sa.tobytes(),
                              static.maxten.tobytes(), view.est.tobytes(),
                              view.fac.tobytes(), view.dl.tobytes(),
                              view.rel.tobytes())
                        hit = ctx.splice_cache.get(ck)
                        if hit is not None:
                            return (hit[0].copy(), hit[1].copy(),
                                    hit[2].copy(), hit[3].copy(), hit[4])
        ok = np.zeros(n, dtype=bool)
        sa = np.full(n, np.inf)
        f = np.ones(n)
        wait = np.zeros(n)
        expiry = np.inf
        if len(cols):
            if fused is None and fkey is not None:
                sd = self.traverser.slowdown
                fac = sd.factors_same_device(
                    ctx.comp, task, static.cand_idx, static.cand_dev,
                    view.P, view.upu, view.Ma, view.uid, view.Da,
                    view.astart, view.na)
                fcache = ctx.factor_cache
                fcache[fkey] = (view, static, fac)
                if len(fcache) > 4096:
                    fcache.pop(next(iter(fcache)), None)
                fused = (fac, view)
            o, s_, f_, w_, expiry = self._score_fused_arrays(
                task, static, now, with_constraints=True, ctx=ctx,
                split_comm=True, fused=fused)
            ok[cols] = o
            sa[cols] = s_
            f[cols] = f_
            wait[cols] = w_
        if ck is not None:
            cache = ctx.splice_cache
            cache[ck] = (ok.copy(), sa.copy(), f.copy(), wait.copy(), expiry)
            if len(cache) > 512:
                # keys embed the check instant, so a persistent serving
                # context would otherwise accrete one generation of
                # entries per wave — FIFO like eff_cache
                cache.pop(next(iter(cache)), None)
        return ok, sa, f, wait, expiry

    def _tracked_checks(self, task: Task, plan, now: float,
                        ctx: "_BatchContext") -> _ScanState:
        """Core constraint checks over ``plan.pus`` with commit-aware
        reuse.

        The first walk of a (task core, candidate list) pair pays one
        fused check; every later walk — same task or any task sharing its
        core — splices fresh single-device checks over exactly the devices
        committed since.  The block-diagonal kernel scores devices
        independently, so the untouched segments are bit-identical to a
        full rescan (pinned by the parity suite)."""
        led = self.ledger
        key = (ctx.core_sig(task), id(plan.pus))
        st = ctx.scan_states.get(key)
        if st is not None and (st.epoch != led.dev_epoch
                               or len(st.refresh_log) > 65536):
            st = None
        if st is None:
            st = _ScanState(len(plan.pus))
            st.ok, st.sa, st.f, st.wait, _ = self._check_arrays(
                task, plan.pus, now, ctx)
            st.epoch = led.dev_epoch
            st.stamps = {d: led.dev_version.get(d, 0) for d in plan.devs}
            st.log_pos = len(ctx.commit_log)
            st.now = now
            ctx.scan_states[key] = st
            return st
        log = ctx.commit_log
        refresh: set = set()
        if st.log_pos < len(log):
            for dev in set(log[st.log_pos:]):
                if dev in plan.dev_ranges \
                        and st.stamps.get(dev) != led.dev_version.get(dev, 0):
                    refresh.add(dev)
            st.log_pos = len(log)
        if st.now != now:
            # the clock moved since the columns were checked: occupied
            # devices' tenancy-wait and l.15 terms read ``now``, so their
            # segments must be re-spliced even with unchanged versions —
            # unless the last splice proved its outputs constant until a
            # known flip instant (``st.expiry``) that is still ahead
            # (empty devices score now-independently and keep)
            for dev in led.occupied_devices(ctx.comp):
                if dev in plan.dev_ranges and dev not in refresh:
                    e = st.expiry.get(dev)
                    if e is None or e <= now:
                        refresh.add(dev)
            st.now = now
        for dev in refresh:
            lo, hi = plan.dev_ranges[dev]
            o, s_, f_, w_, e = self._check_arrays(
                task, plan.dev_sublists[dev], now, ctx)
            st.ok[lo:hi] = o
            st.sa[lo:hi] = s_
            st.f[lo:hi] = f_
            st.wait[lo:hi] = w_
            st.stamps[dev] = led.dev_version.get(dev, 0)
            st.expiry[dev] = e
        if refresh:
            st.refresh_log.extend(refresh)
        return st

    def _effective(self, task: Task, st: _ScanState, plan, now: float,
                   ctx: "_BatchContext") -> tuple:
        """Layer the per-signature pieces over a shared core state: the
        comm column (origin / provenance / return leg, plus the tenancy
        wait) gathered onto the plan, the selection key ``cm + sa*f``,
        and the deadline mask — the only parts of a constraint check that
        depend on where the task came from and when it must finish.

        Cached per (task signature, plan) and patched per committed
        device, mirroring the tracked scan states: consecutive re-walks
        of equal-signature tasks (the common wave shape — replicated
        sensors) refresh only the few plan positions the ledger touched
        instead of re-deriving three fleet-length columns."""
        static = ctx.static_score(self, task, plan.pus)
        cols = static.cols
        dl = task.deadline
        log = ctx.commit_log
        rlog = st.refresh_log
        ck = (ctx.task_sig(task), id(plan.pus))
        ent = ctx.eff_cache.get(ck)
        if ent is not None and ent[0] is st:
            pos, rpos, ok, cm, key = ent[1], ent[2], ent[3], ent[4], ent[5]
            if pos < len(log) or rpos < len(rlog):
                # union of the commit suffix and the scan state's own
                # re-splice suffix (clock-moved occupied devices) — both
                # change the wait/sa/f inputs this layer is derived from
                for dev in set(log[pos:]).union(rlog[rpos:]):
                    rng = plan.dev_ranges.get(dev)
                    if rng is None:
                        continue
                    lo, hi = rng
                    jlo = int(np.searchsorted(cols, lo))
                    jhi = int(np.searchsorted(cols, hi))
                    cm[lo:hi] = 0.0
                    cseg = cols[jlo:jhi]
                    cm[cseg] = static.comm[jlo:jhi] + st.wait[cseg]
                    key[lo:hi] = cm[lo:hi] + st.sa[lo:hi] * st.f[lo:hi]
                    o = st.ok[lo:hi]
                    if dl is not None:
                        o = o & ~(key[lo:hi] > dl)
                    ok[lo:hi] = o
                ent[1] = len(log)
                ent[2] = len(rlog)
            return ok, cm, key
        cm = np.zeros(len(plan.pus))
        if len(cols):
            cm[cols] = static.comm + st.wait[cols]
        key = cm + st.sa * st.f
        if dl is not None:
            ok = st.ok & ~(key > dl)
        else:
            ok = st.ok.copy()          # the cache owns a mutable copy
        cache = ctx.eff_cache
        cache[ck] = [st, len(log), len(rlog), ok, cm, key]
        if len(cache) > 24:
            # pop-with-default: group threads of the sharded walk may race
            # on evicting the same oldest entry
            cache.pop(next(iter(cache)), None)
        return ok, cm, key

    def _scan_reduce(self, ok_d: np.ndarray, cm_d: np.ndarray,
                     st: _ScanState, plan: _ScanPlan,
                     offset: int = 0,
                     key_d: Optional[np.ndarray] = None,
                     ) -> Optional[MapResult]:
        """Replay TraverseChildren's accounting over one scan in closed
        form (see ``kernels.walk_kernel``) and return its winner.
        ``ok_d``/``cm_d`` (and the precomputed ``cm + sa*f`` selection
        column ``key_d``) are the per-signature effective columns over the
        plan that ``st`` (plus ``offset``) is sliced against."""
        n = len(plan.pus)
        sl = slice(offset, offset + n)
        ok = ok_d[sl]
        if not ok.any():
            return None
        sa = st.sa[sl]
        f = st.f[sl]
        cm = cm_d[sl]
        if self.config.objective == "min_load":
            cnt = self.ledger.count
            key = np.full(n, np.inf)
            for i in np.flatnonzero(ok).tolist():
                key[i] = cnt(plan.pus[i])
        elif key_d is not None:
            key = key_d[sl]
        else:
            key = cm + sa * f
        w, queries, hops, overhead = _scan_reduce_kernel()(
            ok, key, plan.pu_lo, plan.pu_hi, plan.leafcnt, plan.nchild,
            plan.hopsum, plan.depth, self.config.local_query_cost)
        if w < 0:
            return None
        pred = TaskPrediction(float(sa[w]), float(f[w]), float(cm[w]))
        return MapResult(pu=plan.pus[w], prediction=pred,
                         overhead=overhead, queries=queries, hops=hops)

    def _traverse_fast(self, task: Task, now: float, ctx: "_BatchContext",
                       scored: Optional[set]) -> Optional[MapResult]:
        """TraverseChildren over this ORC's subtree as one tracked scan."""
        plan = self._scan_plan(ctx.comp)
        if scored is not None:
            scored.update(plan.leaf_groups)
        if not plan.pus:
            return None
        st = self._tracked_checks(task, plan, now, ctx)
        ok, cm, key = self._effective(task, st, plan, now, ctx)
        return self._scan_reduce(ok, cm, st, plan, key_d=key)

    def _ask_level_fast(self, task: Task, now: float, ctx: "_BatchContext",
                        scored: Optional[set]) -> Optional[MapResult]:
        """One AskParent level as a flat selection over every sibling
        subtree at once.

        Alg. 1 picks each sibling's winner, then ``_select``s among them —
        and neither selection key (prediction total / ledger load) depends
        on the escalation hops charged along the way, so the overall
        winner is the flat first-wins argmin over all sibling candidates.
        Only the winning sibling's subtree replays its accounting (the
        other winners' accounting is discarded by ``_select`` anyway);
        the hop/overhead running charges come from the plan's prefix.

        The scan runs over the parent's shared child plan — the asker's
        own slice is part of the state (so every child escalating through
        this parent reuses one set of checks) but is masked out of the
        selection, exactly as Alg. 1 skips the asking child."""
        parent = self.parent
        comp = ctx.comp
        cp = parent._child_plan(comp)
        if scored is not None:
            scored.update(cp.leaf_groups)
        ci = cp.child_pos[id(self)]
        lo_c = int(cp.bounds[ci])
        hi_c = int(cp.bounds[ci + 1])
        if len(cp.pus) == hi_c - lo_c:
            return None                       # no siblings at this level
        er = getattr(comp, "ensure_routes", None)
        if er is not None:
            names = [self.group, parent.group]
            if task.origin is not None:
                names.append(task.origin)
            names.extend(task.attrs.get("src_devices") or ())
            er(names)
        st = self._tracked_checks(task, cp, now, ctx)
        ok_d, cm_d, key_d = self._effective(task, st, cp, now, ctx)
        ok_idx = np.flatnonzero(ok_d)
        ok_idx = ok_idx[(ok_idx < lo_c) | (ok_idx >= hi_c)]
        if not len(ok_idx):
            return None
        if self.config.objective == "min_load":
            cnt = self.ledger.count
            keys = np.fromiter((cnt(cp.pus[i]) for i in ok_idx.tolist()),
                               dtype=np.float64, count=len(ok_idx))
        else:
            keys = key_d[ok_idx]
        w = int(ok_idx[np.argmin(keys)])
        k = int(np.searchsorted(cp.bounds, w, side="right")) - 1
        sibling = cp.children[k]
        sub = sibling._scan_reduce(ok_d, cm_d, st, sibling._scan_plan(comp),
                                   offset=int(cp.bounds[k]), key_d=key_d)
        # the running Alg. 1 charges at the winning sibling's position:
        # one hop up to the parent plus one per *sibling* asked so far
        # (the asker itself is skipped in the iteration order)
        k_sib = k - (1 if ci < k else 0)
        sub.hops += 1 + (k_sib + 1)
        ov = cp.hop_prefix[k] - (cp.hc[ci] if ci < k else 0.0)
        sub.overhead += self._hop_cost(parent) + ov
        return sub

    def _map_once_fast(self, task: Task, now: float, ctx: "_BatchContext",
                       scored: Optional[set]) -> Optional[MapResult]:
        """The fused equivalent of ``_map_once`` (phase-2 re-walks)."""
        res = self._traverse_fast(task, now, ctx, scored)
        cur = self
        while res is None and cur.parent is not None:
            res = cur._ask_level_fast(task, now, ctx, scored)
            cur = cur.parent
        if res is None and self.config.allow_best_effort:
            res = self._best_effort(task, now, ctx, scored)
        return res

    def _batch_checks(self, ctx: "_BatchContext", reqs: list,
                      now: float) -> None:
        """Seed the tracked scan states of ``reqs`` — (orc, task, plan)
        triples sharing one wave depth — with a single
        ``factors_same_device_multi`` kernel call.  The results are built
        by the same ``_score_fused`` logic from the same static inputs and
        ledger views as per-scan checks, so they are bit-identical."""
        sd = self.traverser.slowdown
        led = self.ledger
        comp = ctx.comp
        items = []
        metas = []
        for orc, task, plan in reqs:
            if not plan.pus:
                continue
            key = (ctx.core_sig(task), id(plan.pus))
            if key in ctx.scan_states:
                continue
            static = ctx.static_core(orc, task, plan.pus)
            st = _ScanState(len(plan.pus))
            st.epoch = led.dev_epoch
            st.stamps = {d: led.dev_version.get(d, 0) for d in plan.devs}
            st.log_pos = len(ctx.commit_log)
            st.now = now
            ctx.scan_states[key] = st
            if not len(static.cols):
                continue
            if static.single_dev is not None:
                view = ctx.view(static.single_dev)
            else:
                view = led.live_view(comp)
            items.append((task, static.cand_idx, static.cand_dev, view.P,
                          view.upu, view.Ma, view.uid, view.Da,
                          view.astart, view.na))
            metas.append((orc, task, static, view, st))
        if not items:
            return
        outs = sd.factors_same_device_multi(comp, items)
        for (orc, task, static, view, st), fused in zip(metas, outs):
            o, s_, f_, w_, e = orc._score_fused_arrays(
                task, static, now, with_constraints=True, ctx=ctx,
                fused=(fused, view), split_comm=True)
            cols = static.cols
            st.ok[cols] = o
            st.sa[cols] = s_
            st.f[cols] = f_
            st.wait[cols] = w_
            if static.single_dev is not None:
                st.expiry[static.single_dev] = e

    def _dedup_walks(self, tasks: list, route: bool,
                     ) -> tuple[dict, list["_Walk"]]:
        """Dedup a frontier by task signature: identical tasks walk once
        in phase 1 (commits are replayed per task in phase 2)."""
        walks: dict = {}
        order: list[_Walk] = []
        for t in tasks:
            orc = self._entry_orc(t) if route else self
            key = self._task_signature(orc, t)
            if key not in walks:
                w = walks[key] = _Walk(orc, t)
                order.append(w)
        return walks, order

    def _escalate_walks(self, active: list["_Walk"], now: float,
                        ctx: "_BatchContext",
                        stop_root: bool = False) -> None:
        """Advance unresolved walks through AskParent levels in lockstep,
        batching each escalation depth's constraint checks into one
        kernel call and each depth's route rows into one batched
        Dijkstra.  With ``stop_root=True`` walks park *below* the root
        level (``cur.parent.parent is None``) instead of asking it — the
        group-sharded driver escalates intra-group levels on group
        threads and reserves the root scan (the only cross-group one)
        for serial boundary reconciliation."""
        comp = ctx.comp
        while active:
            er = getattr(comp, "ensure_routes", None)
            if er is not None:
                warm: set = set()
                for w in active:
                    warm.add(w.cur.group)
                    warm.add(w.cur.parent.group)
                    if w.task.origin is not None:
                        warm.add(w.task.origin)
                    warm.update(w.task.attrs.get("src_devices") or ())
                er(warm)
            self._batch_checks(
                ctx, [(w.orc, w.task, w.cur.parent._child_plan(comp))
                      for w in active], now)
            nxt: list[_Walk] = []
            for w in active:
                w.res = w.cur._ask_level_fast(w.task, now, ctx, w.scored)
                if w.res is None:
                    w.cur = w.cur.parent
                    if w.cur.parent is not None and not (
                            stop_root and w.cur.parent.parent is None):
                        nxt.append(w)
            active = nxt

    def _drive_wave(self, order: list["_Walk"], now: float,
                    ctx: "_BatchContext", stop_root: bool = False) -> None:
        """Resolve a set of deduped walks: batched entry checks, one
        tracked entry scan per walk, then lockstep escalation."""
        comp = ctx.comp
        self._batch_checks(
            ctx, [(w.orc, w.task, w.orc._scan_plan(comp)) for w in order],
            now)
        self._entry_reduce_batch(order, now, ctx)
        active = [w for w in order
                  if w.res is None and w.cur.parent is not None and not (
                      stop_root and w.cur.parent.parent is None)]
        self._escalate_walks(active, now, ctx, stop_root=stop_root)

    def _walk_wave(self, tasks: list, now: float, ctx: "_BatchContext",
                   route: bool) -> dict:
        """Phase 1: walk every distinct task signature against the frozen
        ledger, advancing all walks in lockstep so each escalation depth's
        constraint checks batch into one kernel call and each depth's
        route rows warm in one batched Dijkstra."""
        walks, order = self._dedup_walks(tasks, route)
        self._drive_wave(order, now, ctx)
        if self.config.allow_best_effort:
            for w in order:
                if w.res is None:
                    w.res = w.orc._best_effort(w.task, now, ctx, w.scored)
        return walks

    def _entry_reduce_batch(self, ws: list["_Walk"], now: float,
                            ctx: "_BatchContext") -> None:
        """Resolve every walk's entry TraverseChildren scan, stacking
        same-shape scan-plan reduces into one ``scan_reduce_batch`` call
        (jax path vmaps the stack; numpy path is a bit-identical row
        loop).  ``min_load`` walks fall back to the per-walk reduce —
        their selection key reads live ledger counts."""
        comp = ctx.comp
        buckets: dict = {}
        for w in ws:
            orc = w.orc
            plan = orc._scan_plan(comp)
            w.scored.update(plan.leaf_groups)
            if not plan.pus:
                w.res = None
                continue
            st = orc._tracked_checks(w.task, plan, now, ctx)
            ok, cm, key = orc._effective(w.task, st, plan, now, ctx)
            if (orc.config.objective == "min_load" or key is None
                    or not ok.any()):
                w.res = orc._scan_reduce(ok, cm, st, plan, key_d=key)
                continue
            shape = (len(plan.pus), len(plan.pu_lo),
                     orc.config.local_query_cost)
            buckets.setdefault(shape, []).append((w, plan, st, ok, cm, key))
        for (n_pus, n_nodes, lqc), rows in buckets.items():
            if len(rows) == 1:
                w, plan, st, ok, cm, key = rows[0]
                w.res = w.orc._scan_reduce(ok, cm, st, plan, key_d=key)
                continue
            ok_s = np.stack([r[3] for r in rows])
            key_s = np.stack([r[5] for r in rows])
            lo_s = np.stack([r[1].pu_lo for r in rows])
            hi_s = np.stack([r[1].pu_hi for r in rows])
            leaf_s = np.stack([r[1].leafcnt for r in rows])
            nch_s = np.stack([r[1].nchild for r in rows])
            hop_s = np.stack([r[1].hopsum for r in rows])
            dep_s = np.stack([r[1].depth for r in rows])
            wv, qv, hv, ov = _scan_reduce_batch_kernel()(
                ok_s, key_s, lo_s, hi_s, leaf_s, nch_s, hop_s, dep_s, lqc)
            for i, (w, plan, st, ok, cm, key) in enumerate(rows):
                wi = int(wv[i])
                if wi < 0:
                    w.res = None
                    continue
                pred = TaskPrediction(float(st.sa[wi]), float(st.f[wi]),
                                      float(cm[wi]))
                w.res = MapResult(pu=plan.pus[wi], prediction=pred,
                                  overhead=float(ov[i]),
                                  queries=int(qv[i]), hops=int(hv[i]))

    def _shard_root_of(self, orc: "Orchestrator",
                       ) -> Optional["Orchestrator"]:
        """The root-child subtree (= group shard) an ORC belongs to, or
        None for the root itself (serial bucket)."""
        while orc.parent is not None and orc.parent.parent is not None:
            orc = orc.parent
        return orc if orc.parent is not None else None

    def _walk_wave_sharded(self, tasks: list, now: float,
                           ctx: "_BatchContext", route: bool) -> dict:
        """Group-sharded phase 1: partition the deduped walks by root
        child (= ORC device group), drive each group's walks on its own
        host thread up to (but excluding) the root escalation level, then
        reconcile at the group boundary — the root's child-plan scan, the
        only one whose NCR rows cross groups — serially.

        Bit-identity to :meth:`_walk_wave` holds because phase 1 is pure
        against the frozen ledger and every scan an intra-group walk
        touches (entry subtree, intra-group child plans) reads only its
        own group's PU columns: the partition of walks is a partition of
        all reads, so per-group batched checks see exactly the inputs the
        global batch would."""
        comp = ctx.comp
        walks, order = self._dedup_walks(tasks, route)
        buckets: dict = {}
        serial: list[_Walk] = []
        for w in order:
            root = self._shard_root_of(w.orc)
            if root is None:
                serial.append(w)
            else:
                buckets.setdefault(id(root), []).append(w)
        groups = list(buckets.values())
        if len(groups) < 2:
            self._drive_wave(order, now, ctx)
        else:
            # host-thread fan-out only where it can win: >=2 cores and a
            # wave big enough to amortize pool spawn + route pre-warm
            # (small waves and 1-vCPU hosts drive the same group buckets
            # serially — identical results, no thread overhead)
            nthreads = min(len(groups), os.cpu_count() or 1)
            if nthreads < 2 or len(order) < 64 * len(groups):
                for ws in groups:
                    self._drive_wave(ws, now, ctx, stop_root=True)
            else:
                # warm every route row any group thread could need up
                # front: one batched Dijkstra instead of contended lazy
                # builds
                er = getattr(comp, "ensure_routes", None)
                if er is not None:
                    warm: set = set()
                    for w in order:
                        if w.task.origin is not None:
                            warm.add(w.task.origin)
                        warm.update(w.task.attrs.get("src_devices") or ())
                        cur = w.orc
                        while cur is not None:
                            warm.add(cur.group)
                            cur = cur.parent
                    er(warm)
                with ThreadPoolExecutor(max_workers=nthreads) as ex:
                    list(ex.map(
                        lambda ws: self._drive_wave(ws, now, ctx,
                                                    stop_root=True),
                        groups))
            if serial:
                self._drive_wave(serial, now, ctx)
            # boundary reconciliation: walks that exhausted their group
            # escalate through the root's cross-group scan, serially
            pend = [w for w in order
                    if w.res is None and w.cur.parent is not None]
            self._escalate_walks(pend, now, ctx)
        if self.config.allow_best_effort:
            for w in order:
                if w.res is None:
                    w.res = w.orc._best_effort(w.task, now, ctx, w.scored)
        return walks

    @staticmethod
    def _task_signature(orc: "Orchestrator", t: Task) -> tuple:
        """Signature of everything a walk reads off the task: tasks with
        equal signatures produce identical phase-1 walks."""
        return (id(orc), t.kind, t.size, t.deadline, t.origin, t.input_bytes,
                bool(t.attrs.get("pinned")),
                t.attrs.get("succ_pinned_bytes", 0.0),
                tuple(t.attrs.get("src_devices") or ()),
                tuple(sorted(t.usage.items())),
                tuple((k, t.attrs[k]) for k in ("flops", "bytes", "coll_bytes")
                      if k in t.attrs))

    def _entry_orc(self, task: Task) -> "Orchestrator":
        if self._device_orcs is None:
            self._device_orcs = {o.group: o for o in self.iter_tree()
                                 if o.is_device_orc()}
        orc = (self._device_orcs.get(task.origin)
               if task.origin is not None else None)
        if orc is None:
            orc = next(iter(self._device_orcs.values()), self)
        return orc

    def _map_once(self, task: Task, now: float, ctx: Optional[_BatchContext],
                  scored: set) -> Optional[MapResult]:
        res = self._traverse_children(task, now, ctx, scored)
        if res is None:
            res = self._ask_parent(task, now, origin=self, ctx=ctx,
                                   scored=scored)
        if res is None and self.config.allow_best_effort:
            res = self._best_effort(task, now, ctx, scored)
        return res

    # TraverseChildren (Alg. 1 line 20)
    def _traverse_children(self, task: Task, now: float,
                           ctx: Optional[_BatchContext] = None,
                           scored: Optional[set] = None,
                           pre: Optional[dict] = None,
                           ) -> Optional[MapResult]:
        candidates: list[MapResult] = []
        queries = 0
        hops = 0
        overhead = 0.0
        if pre is None and self.children:
            # fuse the whole subtree's constraint check into one call;
            # the recursion below only replays Alg. 1's accounting
            pus = self._subtree_pus()
            pre = dict(zip(pus, self._check_candidates(task, pus, now,
                                                       ctx=ctx)))
        if scored is not None and self.leaf_pus:
            scored.add(self.group)
        if pre is not None and self.leaf_pus:
            checks = [pre[p] for p in self.leaf_pus]
        else:
            checks = self._check_candidates(task, self.leaf_pus, now, ctx=ctx)
        for pu_name, (ok, pred) in zip(self.leaf_pus, checks):
            queries += 1
            if ok:
                r = MapResult(pu=pu_name, prediction=pred)
                if self.config.objective == "first_fit":
                    r.queries = queries
                    r.overhead = overhead + queries * self.config.local_query_cost
                    r.hops = hops
                    return r
                candidates.append(r)
        for child in self.children:
            hops += 1
            overhead += self._hop_cost(child)
            sub = child._traverse_children(task, now, ctx, scored, pre)
            if sub is not None:
                queries += sub.queries
                hops += sub.hops
                overhead += sub.overhead
                if self.config.objective == "first_fit":
                    sub.queries = queries
                    sub.hops = hops
                    sub.overhead = overhead + queries * self.config.local_query_cost
                    return sub
                candidates.append(sub)
        if not candidates:
            return None
        best = self._select(candidates)
        best.queries = queries
        best.hops = hops
        best.overhead = overhead + queries * self.config.local_query_cost
        return best

    # AskParent (Alg. 1 line 30)
    def _ask_parent(self, task: Task, now: float,
                    origin: "Orchestrator",
                    ctx: Optional[_BatchContext] = None,
                    scored: Optional[set] = None) -> Optional[MapResult]:
        if self.parent is None:
            return None
        parent = self.parent
        results: list[MapResult] = []
        hops = 1                       # message up to the parent
        overhead = self._hop_cost(parent)
        queries = 0
        siblings = [s for s in parent.children if s is not self]
        # fuse the sibling scan's constraint checks into one call
        sib_pus = [p for s in siblings for p in s._subtree_pus()]
        pre = (dict(zip(sib_pus, self._check_candidates(task, sib_pus, now,
                                                        ctx=ctx)))
               if sib_pus else None)
        for sibling in siblings:
            hops += 1
            overhead += parent._hop_cost(sibling)
            sub = sibling._traverse_children(task, now, ctx, scored, pre)
            if sub is not None:
                sub.hops += hops
                sub.overhead += overhead
                if parent.config.objective == "first_fit":
                    return sub
                results.append(sub)
                queries += sub.queries
        if results:
            best = self._select(results)
            return best
        # no sibling satisfies: propagate the search further up (DFS)
        return parent._ask_parent(task, now, origin=origin, ctx=ctx,
                                  scored=scored)

    # CheckTaskConstraints (Alg. 1 line 11)
    def _check_constraints(self, task: Task, pu_name: str,
                           now: float) -> tuple[bool, TaskPrediction]:
        return self._check_candidates(task, [pu_name], now)[0]

    def _check_candidates(self, task: Task, pu_names: list[str],
                          now: float, ctx: Optional[_BatchContext] = None,
                          ) -> list[tuple[bool, TaskPrediction]]:
        """CheckTaskConstraints over every candidate PU in one shot."""
        return self._score_candidates(task, pu_names, now,
                                      with_constraints=True, ctx=ctx)

    # -- helpers --------------------------------------------------------------
    def _score_candidates(self, task: Task, pu_names: list[str], now: float,
                          *, with_constraints: bool,
                          ctx: Optional[_BatchContext] = None,
                          ) -> list[tuple[bool, TaskPrediction]]:
        """Vectorized candidate scoring against the compiled HW-GRAPH.

        Per candidate: standalone prediction, inbound communication, the
        newcomer's slowdown factor amid the device's active tasks, and —
        when ``with_constraints`` — the tenancy queueing wait, the deadline
        check, and Alg. 1 line 15 (existing tasks keep their constraints).
        Eligibility (alive / supports / pinned), the ledger lookups and the
        l.15 re-check are all array ops over the compiled snapshot and the
        struct-of-arrays ledger; the factor work for all candidates of a
        device comes from a single ``factors_with_candidates_idx`` call.

        Predictions are *pipeline-aware*: if this task's output must
        return to a pinned consumer on the origin device, that transfer is
        charged here — otherwise a remote placement looks cheap while the
        return leg destroys the downstream task's budget (cf. §5.4.1
        CloudVR comparison: balance computation AND communication)."""
        graph = self.graph
        comp = ctx.comp if ctx is not None else graph.compiled()
        n = len(pu_names)
        infeasible = (False, TaskPrediction(float("inf"), 1.0, 0.0))
        results: list[tuple[bool, TaskPrediction]] = [infeasible] * n
        if not n:
            return results
        sd = self.traverser.slowdown
        noisy = bool(getattr(sd, "_noisy", lambda: False)())
        if (not noisy) and hasattr(sd, "factors_same_device"):
            static = (ctx.static_score(self, task, pu_names)
                      if ctx is not None
                      else self._static_score(task, pu_names, comp, None))
            if len(static.cols):
                self._score_fused(task, static, now, results,
                                  with_constraints=with_constraints, ctx=ctx)
        else:
            idx, elig = self._eligibility(task, pu_names, comp, ctx)
            if elig.any():
                self._score_grouped(task, pu_names, idx, elig, now, results,
                                    with_constraints=with_constraints,
                                    ctx=ctx)
        return results

    def _eligibility(self, task: Task, pu_names: list[str], comp,
                     ctx: Optional[_BatchContext]) -> tuple:
        graph = self.graph
        n = len(pu_names)
        if ctx is not None:
            idx = ctx.pu_idx(pu_names)
        else:
            idx = np.fromiter((comp.pu_index.get(p, -1) for p in pu_names),
                              dtype=np.int64, count=n)
        known = idx >= 0
        elig = known.copy()
        if known.any():
            ki = idx[known]
            alive = comp.pu_alive[ki]
            if ctx is not None:
                sup = ctx.supports_mask(task)[ki]
            else:
                sup = np.fromiter(
                    ((graph.nodes[p].model is not None
                      and graph.nodes[p].model.supports(task, graph.nodes[p]))
                     for p, k in zip(pu_names, known) if k),
                    dtype=bool, count=int(known.sum()))
            ok = alive & sup
            if task.attrs.get("pinned"):
                # device-local peripherals pin a task to its origin
                ok &= comp.pu_device[ki] == task.origin
            elig[known] = ok
        return idx, elig

    def _static_score(self, task: Task, pu_names: list[str], comp,
                      ctx: Optional[_BatchContext],
                      skip_comm: bool = False) -> "_StaticScore":
        """The ledger-independent half of fused scoring: eligibility,
        candidate index/device arrays, standalone predictions, inbound
        communication (with the pinned-return leg), tenancy limits.
        Cached per (task signature, candidate list) by the batch context;
        ``skip_comm`` leaves ``comm = None`` for the core-keyed variant
        whose consumers never read it."""
        idx, elig = self._eligibility(task, pu_names, comp, ctx)
        s = _StaticScore()
        s.pu_names = pu_names
        s.cols = np.nonzero(elig)[0]
        s.single_dev = None
        if not len(s.cols):
            s.cand_idx = s.cand_dev = s.cols
            s.sa = s.comm = s.maxten = np.zeros(0)
            return s
        s.cand_idx = idx[s.cols]
        s.cand_dev = comp.pu_dev_ord[s.cand_idx]
        if bool((s.cand_dev == s.cand_dev[0]).all()):
            s.single_dev = comp.dev_ord_names[int(s.cand_dev[0])]
        if ctx is not None:
            s.sa = ctx.standalone(task)[s.cand_idx]
        else:
            g = self.graph
            s.sa = np.array([g.nodes[pu_names[c]].predict(task)
                             for c in s.cols])
        if skip_comm:
            s.comm = None
            s.maxten = comp.max_tenancy[s.cand_idx]
            return s
        # communication per distinct destination device (+ return leg)
        ret_bytes = task.attrs.get("succ_pinned_bytes", 0.0)
        comm_lut = np.zeros(len(comp.dev_ord_names))
        uniq = (s.cand_dev[:1] if s.single_dev is not None
                else np.unique(s.cand_dev))
        if not self._comm_lut_fast(task, comp, uniq, ret_bytes, comm_lut):
            if ret_bytes > 0 and task.origin is not None and len(uniq) > 1:
                # the return leg routes *from* each candidate device: warm
                # all those rows in one batched Dijkstra instead of one
                # heapq walk per device inside the loop
                er = getattr(comp, "ensure_routes", None)
                if er is not None:
                    er([comp.dev_ord_names[int(o)] for o in uniq])
            for o in uniq:
                dev = comp.dev_ord_names[o]
                c = (ctx.comm(task, dev) if ctx is not None
                     else self.traverser.comm_time_dev(task, dev, comp))
                if (ret_bytes > 0 and task.origin is not None
                        and dev != task.origin):
                    c += comp.transfer_time(dev, task.origin, ret_bytes)
                comm_lut[o] = c
        s.comm = comm_lut[s.cand_dev]
        s.maxten = comp.max_tenancy[s.cand_idx]
        return s

    def _comm_lut_fast(self, task: Task, comp, uniq: np.ndarray,
                       ret_bytes: float, comm_lut: np.ndarray) -> bool:
        """Fill ``comm_lut`` for the ``uniq`` destination devices straight
        off the compiled route table — elementwise the same
        ``lat + nbytes * ibw`` doubles ``transfer_time`` computes, so the
        values are bit-identical to the scalar loop.  Returns False (LUT
        untouched) when any endpoint falls outside the routable space or a
        route is missing; the caller's scalar loop then reproduces the
        oracle semantics, including its KeyError."""
        rt = getattr(comp, "_rt", None)
        ri = getattr(comp, "routable_index", None)
        if rt is None or ri is None or len(uniq) < 2:
            return False
        srcs = task.attrs.get("src_devices")
        if not srcs and task.origin is not None:
            srcs = [task.origin]
        srcs = list(srcs or ())
        ib = task.input_bytes
        dev2r = comp.__dict__.get("_dev_routable")
        if dev2r is None:
            dev2r = comp._dev_routable = np.fromiter(
                (ri.get(d, -1) for d in comp.dev_ord_names),
                dtype=np.int64, count=len(comp.dev_ord_names))
        j_arr = dev2r[uniq]
        i_src = [ri.get(d, -1) for d in srcs]
        ret = ret_bytes > 0 and task.origin is not None
        j_org = ri.get(task.origin, -1) if ret else -1
        if not (j_arr >= 0).all() or any(i < 0 for i in i_src) \
                or (ret and j_org < 0):
            return False
        need = set(i_src)
        if ret:
            need.update(int(j) for j in j_arr)
        comp.ensure_routes(need)
        vals = np.zeros(len(uniq))
        # effective inverse bandwidth reads the layered route table's
        # per-snapshot overlay (ibw_row/ibw_col), not the shared base —
        # a bandwidth-churned snapshot prices links post-churn while the
        # topology layer stays shared (docs/timeline.md)
        if ib > 0:
            for i in i_src:
                leg = rt.lat[i, j_arr] + ib * rt.ibw_row(i)[j_arr]
                leg = np.where(j_arr == i, 0.0, leg)
                if not np.isfinite(leg).all():
                    return False
                np.maximum(vals, leg, out=vals)
        if ret:
            leg = rt.lat[j_arr, j_org] + ret_bytes * rt.ibw_col(j_arr, j_org)
            leg = np.where(j_arr == j_org, 0.0, leg)
            if not np.isfinite(leg).all():
                return False
            vals = vals + leg
        comm_lut[uniq] = vals
        return True

    def _score_fused(self, task: Task, static: "_StaticScore", now: float,
                     results: list, *, with_constraints: bool,
                     ctx: Optional[_BatchContext],
                     fused: Optional[tuple] = None) -> None:
        """One-shot scoring of an arbitrary mixed-device candidate set: a
        single block-diagonal kernel call replaces one slowdown/constraint
        evaluation per device (the escalation scan's former hot loop).

        ``fused``: optional ``((new_f, ci, ai, act_pf), view)`` computed
        by the wave-level multi-newcomer prescore; when given, the kernel
        call is skipped and the constraint logic runs on the precomputed
        factors."""
        arrs = self._score_fused_arrays(task, static, now,
                                        with_constraints=with_constraints,
                                        ctx=ctx, fused=fused)
        ok_a, sa_a, f_a, cm_a = arrs
        for c, ok, sa, f, cm in zip(static.cols.tolist(), ok_a.tolist(),
                                    sa_a.tolist(), f_a.tolist(),
                                    cm_a.tolist()):
            results[c] = (ok, TaskPrediction(sa, f, cm))

    def _score_fused_arrays(self, task: Task, static: "_StaticScore",
                            now: float, *, with_constraints: bool,
                            ctx: Optional[_BatchContext],
                            fused: Optional[tuple] = None,
                            split_comm: bool = False) -> tuple:
        """The array core of :meth:`_score_fused`: per eligible candidate
        (``static.cols`` order) the feasibility, standalone, factor and
        comm columns — the fast walk consumes these directly and never
        materializes per-candidate prediction objects.

        With ``split_comm`` the comm column is withheld: the last column
        is the additive tenancy wait and ``ok`` excludes the deadline
        mask — the origin-independent core the tracked scan states share
        across task signatures."""
        comp = ctx.comp if ctx is not None else self.graph.compiled()
        sd = self.traverser.slowdown
        cols = static.cols
        cand_idx = static.cand_idx
        if fused is not None:
            (new_f, ci, ai, act_pf), view = fused
        else:
            # single-device candidate sets (the common local check) read
            # the per-device segment view, which commits on *other*
            # devices never invalidate; mixed-device sets read the
            # global view
            if ctx is not None and static.single_dev is not None:
                view = ctx.view(static.single_dev)
            else:
                view = self.ledger.live_view(comp)
            new_f, ci, ai, act_pf = sd.factors_same_device(
                comp, task, cand_idx, static.cand_dev, view.P, view.upu,
                view.Ma, view.uid, view.Da, view.astart, view.na)
        A = len(view)
        wait = None
        ok = np.ones(len(cols), dtype=bool)
        C = len(cand_idx)
        expiry = np.inf
        if with_constraints and A and C:
            # tenancy cap: queueing wait behind the earliest finisher.
            # Count actives per *candidate position* (not per fleet PU):
            # the candidate sets here are device- or subtree-local, so two
            # fleet-length scatter arrays per check would dwarf the math
            order = np.argsort(cand_idx, kind="stable")
            sci = cand_idx[order]
            pp = np.minimum(np.searchsorted(sci, view.P), C - 1)
            on_cand = sci[pp] == view.P
            cpos = order[pp[on_cand]]
            cnt = np.bincount(cpos, minlength=C)
            waits = cnt >= static.maxten
            if waits.any():
                minest = np.full(C, np.inf)
                np.minimum.at(minest, cpos, view.est[on_cand])
                wait = np.where(
                    waits, np.maximum(0.0, minest - now), 0.0)
                if split_comm and bool((minest[waits] > now).any()):
                    # a positive queueing wait decays with every clock
                    # tick: this check is stale the instant ``now`` moves
                    expiry = now
            # Alg. 1 l.15 over the same-device (candidate, active) pairs
            if len(ci):
                est_a = view.est[ai]
                fac_a = np.maximum(view.fac[ai], 1e-12)
                rem = np.maximum(0.0, est_a - now) / fac_a
                fin = now + rem * act_pf
                dlp = view.dl[ai] * (1 + 1e-9)
                viol = np.isfinite(dlp) & (fin - view.rel[ai] > dlp)
                ok[ci[viol]] = False
                if split_comm:
                    # earliest future instant any pair's verdict can flip.
                    # fin(t) is piecewise linear and continuous in t
                    # (slope 1-r before est, slope 1 after, r = pf/fac),
                    # so each pair's violation state changes only at a
                    # root of fin(t) - rel - dl': t1 inside [now, est) or
                    # t2 = rel + dl' inside [max(now, est), inf)
                    fine = np.isfinite(dlp)
                    r = act_pf / fac_a
                    rel_a = view.rel[ai]
                    with np.errstate(divide="ignore", invalid="ignore"):
                        t1 = (rel_a + dlp - est_a * r) / (1.0 - r)
                    flips = np.where(
                        fine & (r != 1.0) & (t1 >= now) & (t1 < est_a),
                        t1, np.inf)
                    t2 = rel_a + dlp
                    flips = np.minimum(flips, np.where(
                        fine & (t2 >= now) & (t2 >= est_a), t2, np.inf))
                    tmin = float(flips.min()) if len(flips) else np.inf
                    if tmin < expiry:
                        # pull a hair early: the analytic root and the
                        # float-evaluated predicate may disagree by ulps,
                        # and an early re-splice is merely redundant
                        expiry = tmin - max(abs(tmin), 1.0) * 1e-9
        new_f = np.asarray(new_f, dtype=np.float64)
        if split_comm:
            # origin-independent core: the comm column is replaced by the
            # additive tenancy wait and the (comm-dependent) deadline mask
            # is left to the per-signature layer (``_effective``); the
            # fifth column is the valid-until instant — outputs are exact
            # for any check time in [now, expiry)
            return ok, static.sa, new_f, (wait if wait is not None
                                          else np.zeros(len(cols))), expiry
        comm = static.comm if wait is None else static.comm + wait
        comm = (np.asarray(comm, dtype=np.float64)
                if np.ndim(comm) else np.full(len(cols), float(comm)))
        if with_constraints and task.deadline is not None:
            totals = comm + static.sa * new_f
            ok &= ~(totals > task.deadline)
        elif not with_constraints:
            ok = np.ones(len(cols), dtype=bool)
        return ok, static.sa, new_f, comm

    def _score_grouped(self, task: Task, pu_names: list[str], idx: np.ndarray,
                       elig: np.ndarray, now: float, results: list, *,
                       with_constraints: bool,
                       ctx: Optional[_BatchContext]) -> None:
        """Per-device scoring via the tuple-based slowdown surface: the
        path for noisy models (rng stream order must match the scalar
        reference) and for custom slowdown objects without the
        block-diagonal kernel."""
        graph = self.graph
        comp = ctx.comp if ctx is not None else graph.compiled()
        sd = self.traverser.slowdown
        batch = getattr(sd, "factors_with_candidates", None)
        by_dev: dict[str, list[int]] = {}
        for c in np.nonzero(elig)[0]:
            by_dev.setdefault(comp.pu_device[idx[c]], []).append(int(c))
        sa_vec = ctx.standalone(task) if ctx is not None else None
        ret_bytes = task.attrs.get("succ_pinned_bytes", 0.0)
        P = len(comp.pu_names)
        for dev, cols in by_dev.items():
            names = [pu_names[c] for c in cols]
            cand_idx = idx[cols]
            view = (ctx.view(dev) if ctx is not None
                    else self.ledger.device_view(comp, dev))
            A = len(view)
            act_f = None
            if batch is not None:
                new_f, act_f = batch(task, names, view.pairs())
            else:
                pairs = view.pairs()
                new_f = [sd.factor(task, p, pairs) for p in names]
            if ctx is not None:
                comm = ctx.comm(task, dev)
            else:
                comm = self.traverser.comm_time_dev(task, dev, comp)
            if ret_bytes > 0 and task.origin is not None and dev != task.origin:
                comm += comp.transfer_time(dev, task.origin, ret_bytes)
            # tenancy occupancy per candidate PU (live rows only)
            if with_constraints and A:
                cnt = np.bincount(view.P, minlength=P)[cand_idx]
                minest = np.full(P, np.inf)
                np.minimum.at(minest, view.P, view.est)
                minest = minest[cand_idx]
            else:
                cnt = np.zeros(len(cols), dtype=np.int64)
                minest = np.full(len(cols), np.inf)
            # Alg. 1 l.15: existing tasks keep their constraints
            ok15 = np.ones(len(cols), dtype=bool)
            if with_constraints and A:
                if act_f is not None:
                    rem = (np.maximum(0.0, view.est - now)
                           / np.maximum(view.fac, 1e-12))
                    fin = now + rem[None, :] * np.asarray(act_f)
                    viol = (fin - view.rel[None, :]
                            > view.dl[None, :] * (1 + 1e-9))
                    ok15 = ~viol.any(axis=1)
                else:
                    pairs = view.pairs()
                    for c_pos, name in enumerate(names):
                        new_factors = self.traverser.predict_active_with(
                            task, name, pairs)
                        for a in range(A):
                            if not np.isfinite(view.dl[a]):
                                continue
                            rem = (max(0.0, view.est[a] - now)
                                   / max(view.fac[a], 1e-12))
                            fin = now + rem * new_factors[int(view.uid[a])]
                            if fin - view.rel[a] > view.dl[a] * (1 + 1e-9):
                                ok15[c_pos] = False
                                break
            for c_pos, c in enumerate(cols):
                name = names[c_pos]
                sa = (sa_vec[idx[c]] if sa_vec is not None
                      else graph.nodes[name].predict(task))
                pred = TaskPrediction(standalone=float(sa),
                                      factor=float(new_f[c_pos]), comm=comm)
                if not with_constraints:
                    results[c] = (True, pred)
                    continue
                # tenancy cap: queueing wait behind the earliest finisher
                if cnt[c_pos] >= comp.max_tenancy[idx[c]]:
                    wait = float(minest[c_pos]) - now
                    pred = TaskPrediction(standalone=pred.standalone,
                                          factor=pred.factor,
                                          comm=pred.comm + max(0.0, wait))
                if task.deadline is not None and pred.total > task.deadline:
                    results[c] = (False, pred)
                    continue
                results[c] = (bool(ok15[c_pos]), pred)

    def _select(self, candidates: list[MapResult]) -> MapResult:
        if self.config.objective == "min_load":
            return min(candidates, key=lambda r: self.ledger.count(r.pu))
        return min(candidates, key=lambda r: r.prediction.total)

    def _hop_cost(self, other: "Orchestrator") -> float:
        """Round-trip query cost between this ORC's group and another's
        (cached per compiled-snapshot version)."""
        comp = self.graph.compiled()
        cache = self._hop_cache
        if cache is None or cache[0] is not comp:
            cache = self._hop_cache = (comp, {})
        cost = cache[1].get(id(other))
        if cost is None:
            try:
                one_way = comp.transfer_time(self.group, other.group,
                                             QUERY_BYTES)
            except KeyError:
                one_way = 0.0
            cost = cache[1][id(other)] = 2.0 * one_way
        return cost

    def _best_effort(self, task: Task, now: float,
                     ctx: Optional[_BatchContext] = None,
                     scored: Optional[set] = None) -> Optional[MapResult]:
        """Nothing satisfies the deadline anywhere: pick the globally least-bad
        PU so the system degrades instead of dropping work (QoS failure is
        recorded by the evaluation layer)."""
        root = self
        while root.parent is not None:
            root = root.parent
        best: Optional[MapResult] = None
        all_pus = root._subtree_pus()
        scores = self._score_candidates(task, all_pus, now,
                                        with_constraints=False, ctx=ctx)
        pre = dict(zip(all_pus, scores))
        for orc in root.iter_tree():
            if not orc.leaf_pus:
                continue
            if scored is not None:
                scored.add(orc.group)
            for pu_name in orc.leaf_pus:
                ok, pred = pre[pu_name]
                if not ok:
                    continue
                if best is None or pred.total < best.prediction.total:
                    best = MapResult(pu=pu_name, prediction=pred)
        return best

    def iter_tree(self):
        yield self
        for c in self.children:
            yield from c.iter_tree()

    def find_device_orc(self, device: str) -> Optional["Orchestrator"]:
        for orc in self.iter_tree():
            if orc.group == device:
                return orc
        return None


def build_orchestrators(graph: HWGraph, traverser: Traverser,
                        ledger: Optional[ActiveLedger] = None,
                        config: Optional[OrcConfig] = None,
                        max_fanout: Optional[int] = None,
                        cls: type = None) -> Orchestrator:
    """Build the ORC tree from GROUP nodes tagged with attrs['orc_level'].

    Levels: 'root' (exactly one), 'cluster' (virtual groupings), 'device'
    (manages every PU in its subtree).  Matches Fig. 4b.

    ``max_fanout``: the paper's scalability device (§3.5) — "if a virtual
    cluster gets too large, the logarithmic complexity could be maintained
    by inserting virtual nodes and corresponding ORCs".  When a cluster ORC
    ends up with more than max_fanout children, intermediate virtual ORCs
    are inserted so every node's fanout stays bounded and a MapTask
    escalation touches O(log n) ORCs instead of O(n) siblings.

    ``cls``: Orchestrator subclass to instantiate (benchmark/compat
    harnesses replicate historical scoring paths this way).
    """
    cls = cls or Orchestrator
    ledger = ledger if ledger is not None else ActiveLedger()
    config = config or OrcConfig()
    roots = [n for n in graph.nodes.values()
             if n.attrs.get("orc_level") == "root"]
    if len(roots) != 1:
        raise ValueError(f"expected exactly one root group, got {len(roots)}")
    root = cls(graph, roots[0].name, traverser, ledger, config)

    def attach(parent_orc: Orchestrator, group_name: str) -> None:
        for child in graph.children_of(group_name):
            lvl = child.attrs.get("orc_level")
            if lvl == "cluster":
                orc = parent_orc.add_child(
                    cls(graph, child.name, traverser, ledger, config))
                attach(orc, child.name)
            elif lvl == "device":
                orc = parent_orc.add_child(
                    cls(graph, child.name, traverser, ledger, config))
                orc.leaf_pus = [p.name for p in graph.pus(under=child.name)]
            elif child.kind.name == "GROUP":
                attach(parent_orc, child.name)

    attach(root, roots[0].name)
    if max_fanout is not None and max_fanout >= 2:
        for orc in list(root.iter_tree()):
            _bound_fanout(orc, max_fanout)
    return root


def _bound_fanout(orc: Orchestrator, k: int) -> None:
    """Insert virtual intermediate ORCs under ``orc`` until every node in
    its subtree has at most k children (device ORCs are leaves)."""
    level = 0
    while len(orc.children) > k:
        groups: list[Orchestrator] = []
        kids = orc.children
        for i in range(0, len(kids), k):
            chunk = kids[i:i + k]
            if len(chunk) == 1:
                groups.append(chunk[0])
                continue
            virt = Orchestrator(orc.graph, f"{orc.group}.virt{level}_{i // k}",
                                orc.traverser, orc.ledger, orc.config)
            virt.parent = orc
            for c in chunk:
                c.parent = virt
                virt.children.append(c)
            groups.append(virt)
        orc.children = groups
        level += 1
