"""Traverser: predict performance of a CFG of tasks on mapped PUs, accounting
for shared-resource slowdown between concurrently running tasks (paper §3.4).

The Traverser walks the CFG in time order and splits execution into
**contention intervals** (Fig. 6): maximal time spans during which the set of
co-running tasks is constant.  Within an interval each task progresses at
``1 / slowdown_factor`` of its standalone speed; at interval boundaries the
factors are recomputed.  The simulation itself runs on the struct-of-arrays
``core.timeline.TimelineEngine`` (dense job/transfer tables, one array-op
settle per timestamp, one repricing call per flush across every dirty
device); the seed's per-job ``heapq`` event loop survives verbatim as
:meth:`Traverser.traverse_reference` — the parity oracle
(``tests/test_timeline.py`` pins 1e-9 agreement) and the ``bench-des``
baseline.  Transfer routes come from the compiled (lazily materialized)
route tables instead of per-query Dijkstra runs.

The same engine serves two roles:

* **Prediction** (H-EYE's Traverser proper): linear calibrated slowdown
  model, no noise — called by the Orchestrator for constraint checks.
* **Ground truth** (core/simulator.py): superlinear slowdown + per-task
  irregular-access noise — stands in for the paper's physical testbed.

Communication is first-class: data moving between devices becomes a
TransferJob that *shares link bandwidth* with concurrent transfers
(paper Fig. 12's dynamic-bandwidth experiments rely on this).
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from .hwgraph import EdgeAttr, HWGraph, ProcessingUnit
from .slowdown import DecoupledSlowdown
from .task import Task, TaskGraph
from .timeline import Timeline, TimelineEngine


@dataclass
class TaskPrediction:
    """Closed-form single-task prediction used by Orchestrator checks."""

    standalone: float
    factor: float
    comm: float

    @property
    def total(self) -> float:
        return self.comm + self.standalone * self.factor


class _ComputeJob:
    __slots__ = ("task", "pu", "device", "W", "rate", "t_last", "version", "start")

    def __init__(self, task: Task, pu: str, device: str, work: float, t: float):
        self.task = task
        self.pu = pu
        self.device = device
        self.W = work
        self.rate = 1.0
        self.t_last = t
        self.version = 0
        self.start = t


class _TransferJob:
    __slots__ = ("key", "consumer_uid", "edges", "W", "rate", "t_last",
                 "version", "latency")

    def __init__(self, key: int, consumer_uid: int, edges: list[EdgeAttr],
                 nbytes: float, latency: float, t: float):
        self.key = key
        self.consumer_uid = consumer_uid
        self.edges = edges
        self.W = max(nbytes, 0.0)
        self.rate = 1.0
        self.t_last = t
        self.version = 0
        self.latency = latency


class Traverser:
    """Predicts CFG performance on a given task->PU mapping (no scheduling)."""

    def __init__(self, graph: HWGraph, slowdown: Optional[DecoupledSlowdown] = None,
                 noise: float = 0.0, rng: Optional[np.random.Generator] = None):
        self.graph = graph
        self.slowdown = slowdown or DecoupledSlowdown(graph)
        self.noise = noise
        self.rng = rng or np.random.default_rng(0)

    # ------------------------------------------------------------------
    # Closed-form single-task prediction (Orchestrator constraint checks)
    # ------------------------------------------------------------------
    def predict_task(self, task: Task, pu_name: str,
                     active: list[tuple[Task, str]] = ()) -> TaskPrediction:
        pu = self.graph.nodes[pu_name]
        assert isinstance(pu, ProcessingUnit)
        comp = self.graph.compiled()
        standalone = pu.predict(task)
        factor = self.slowdown.factor(task, pu_name, list(active))
        comm = self.comm_time(task, pu_name, comp)
        return TaskPrediction(standalone=standalone, factor=factor, comm=comm)

    def comm_time(self, task: Task, pu_name: str, comp=None) -> float:
        """Inbound transfer time of ``task``'s input onto ``pu_name``'s device."""
        comp = comp or self.graph.compiled()
        return self.comm_time_dev(task, comp.device_name(pu_name), comp)

    def comm_time_dev(self, task: Task, dst_dev: str, comp=None) -> float:
        """Inbound transfer time of ``task``'s input onto device ``dst_dev``.

        Data comes from the producers' devices (set by the runtime once
        predecessors are placed), falling back to the task's origin."""
        if task.input_bytes <= 0:
            return 0.0
        comp = comp or self.graph.compiled()
        srcs = task.attrs.get("src_devices")
        if not srcs and task.origin is not None:
            srcs = [task.origin]
        comm = 0.0
        for src_dev in srcs or []:
            if src_dev != dst_dev:
                comm = max(comm, comp.transfer_time(
                    src_dev, dst_dev, task.input_bytes))
        return comm

    def predict_active_with(self, new_task: Task, new_pu: str,
                            active: list[tuple[Task, str]]) -> dict[int, float]:
        """Updated slowdown factor of each active task if new_task joins."""
        batch = getattr(self.slowdown, "factors_with_candidates", None)
        if batch is not None:
            _, act_f = batch(new_task, [new_pu], list(active))
            return {t.uid: float(f) for (t, _), f in zip(active, act_f[0])}
        out: dict[int, float] = {}
        pool = list(active) + [(new_task, new_pu)]
        for t, p in active:
            others = [(t2, p2) for t2, p2 in pool if t2.uid != t.uid]
            out[t.uid] = self.slowdown.factor(t, p, others)
        return out

    # ------------------------------------------------------------------
    # Full CFG traverse (contention-interval event simulation)
    # ------------------------------------------------------------------
    def traverse(self, cfg: TaskGraph, mapping: dict[int, str],
                 background: list[tuple[Task, str, float]] = (),
                 interventions: list[tuple[float, Any]] = (),
                 engine: str = "fused",
                 ) -> Timeline:
        """Simulate ``cfg`` under ``mapping`` (task.uid -> pu name).

        ``background``: (task, pu, remaining_standalone_seconds) triples of
        already-running tasks that contend but whose dependencies are done.
        ``interventions``: (t, fn) pairs applied at simulated time ``t``
        — ``fn`` a zero-arg callable or a :class:`~.hwgraph.Churn` delta
        batch; every active device pool and link set is repriced at that
        instant.

        ``engine`` selects the event loop — the single selector over the
        two DES implementations:

        * ``"fused"`` (default): the array-native
          :class:`core.timeline.TimelineEngine`.  A *noisy slowdown
          model* (rng-bearing) draws inside ``factor()`` in per-device
          pool order, which only the seed event loop reproduces
          byte-for-byte — those configurations fall back to the
          reference engine automatically (note: the ground-truth
          engine's per-task work noise is NOT this case; it is drawn at
          job start and the array engine preserves its stream).
        * ``"reference"``: the seed's per-job heapq event loop, kept
          verbatim — the 1e-9 parity oracle and the ``bench-des``
          object-path baseline.
        """
        if engine not in ("fused", "reference"):
            raise ValueError(
                f"engine must be 'fused' or 'reference', got {engine!r}")
        if (engine == "reference"
                or bool(getattr(self.slowdown, "_noisy", lambda: False)())):
            return self._traverse_seed(cfg, mapping, background,
                                       interventions)
        return TimelineEngine(self, cfg, mapping, background,
                              interventions).run()

    def traverse_reference(self, cfg: TaskGraph, mapping: dict[int, str],
                           background: list[tuple[Task, str, float]] = (),
                           interventions: list[tuple[float, Any]] = (),
                           ) -> Timeline:
        """Alias for ``traverse(..., engine="reference")`` (the historical
        oracle entrypoint; kept because benches and parity suites name
        it)."""
        return self.traverse(cfg, mapping, background, interventions,
                             engine="reference")

    def _traverse_seed(self, cfg: TaskGraph, mapping: dict[int, str],
                       background: list[tuple[Task, str, float]] = (),
                       interventions: list[tuple[float, Any]] = (),
                       ) -> Timeline:
        """The seed's per-job heapq event loop, kept verbatim: the parity
        oracle for ``TimelineEngine`` (1e-9) and the ``bench-des``
        object-path baseline."""
        tl = Timeline(mapping=dict(mapping))
        heap: list[tuple[float, int, str, Any]] = []
        seq = itertools.count()
        time = 0.0
        comp = self.graph.compiled()      # topology is frozen during a traverse
        from .timeline import warm_transfer_routes
        # freeze transfer routes against the pre-churn topology (route
        # rows are lazily materialized; both engines warm identically so
        # interventions cannot skew which graph version a route sees)
        warm_transfer_routes(comp, cfg, mapping)
        factor_batch = getattr(self.slowdown, "factor_batch", None)

        # --- state ---
        compute: dict[int, _ComputeJob] = {}               # task.uid -> job
        dev_members: dict[str, set[int]] = defaultdict(set)
        transfers: dict[int, _TransferJob] = {}
        xfer_seq = itertools.count()
        edge_members: dict[int, set[int]] = defaultdict(set)   # id(edge) -> xfer keys
        pu_running: dict[str, int] = defaultdict(int)
        pu_queue: dict[str, deque[Task]] = defaultdict(deque)
        waiting: dict[int, int] = {}                        # uid -> inbound count
        ready_at: dict[int, float] = {}                     # uid -> data-arrival time
        task_by_uid = {t.uid: t for t in cfg}
        finished: set[int] = set()

        def push(t: float, kind: str, payload: Any) -> None:
            heapq.heappush(heap, (t, next(seq), kind, payload))

        # --- rate maintenance -------------------------------------------
        # Repricing is *frontier-batched*: handlers only mark devices/edges
        # dirty, and one flush per distinct event timestamp reprices each
        # dirty device pool and the union of touched links once — a
        # producer fanning out K transfers (or a release wave starting K
        # tasks) costs one repricing call, not K.  Rates are piecewise
        # constant and settle() at an unchanged timestamp is a no-op, so
        # the deferred flush computes exactly the rates the per-change
        # repricing would have.
        dirty_devs: set[str] = set()
        dirty_edges: dict[int, EdgeAttr] = {}

        def settle(job) -> None:
            job.W = max(0.0, job.W - job.rate * (time - job.t_last))
            job.t_last = time

        def reprice_device(dev: str) -> None:
            """Contention-interval boundary: recompute every member's rate.

            The whole pool is evaluated in one vectorized shot against the
            compiled arrays instead of O(n^2) Python pair loops."""
            members = [compute[u] for u in sorted(dev_members[dev])]
            pool = [(j.task, j.pu) for j in members]
            if factor_batch is not None:
                factors = factor_batch(pool)
            else:
                factors = [self.slowdown.factor(j.task, j.pu, pool)
                           for j in members]
            for j, f in zip(members, factors):
                settle(j)
                j.rate = 1.0 / float(f)
                j.version += 1
                push(time + j.W / j.rate, "cdone", (j.task.uid, j.version))
            tl.n_intervals += 1

        def reprice_edges(edges: list[EdgeAttr]) -> None:
            affected: set[int] = set()
            for e in edges:
                affected |= edge_members[id(e)]
            # deterministic tie-break: transfers repriced (and hence their
            # completion events pushed) in key order, so simultaneous
            # completions settle in a pinned order — the array engine's
            # scan order, and stable across hash seeds
            for k in sorted(affected):
                x = transfers[k]
                settle(x)
                bw = min(e.bandwidth / max(1, len(edge_members[id(e)]))
                         for e in x.edges) if x.edges else float("inf")
                x.rate = bw
                x.version += 1
                eta = time + (x.W / x.rate if x.rate > 0 else float("inf"))
                push(eta, "xdone", (x.key, x.version))

        def flush() -> None:
            if dirty_devs:
                for dev in sorted(dirty_devs):   # deterministic tie-break
                    reprice_device(dev)
                dirty_devs.clear()
            if dirty_edges:
                reprice_edges(list(dirty_edges.values()))
                dirty_edges.clear()

        # --- job lifecycle ----------------------------------------------
        def start_compute(task: Task) -> None:
            pu_name = mapping[task.uid]
            pu = self.graph.nodes[pu_name]
            assert isinstance(pu, ProcessingUnit), pu_name
            if pu_running[pu_name] >= pu.max_tenancy:
                pu_queue[pu_name].append(task)
                return
            pu_running[pu_name] += 1
            sa = pu.predict(task)
            work = sa
            if self.noise > 0.0:
                irr = task.attrs.get("irregularity", 1.0)
                work = sa * float(np.exp(self.rng.normal(0.0, self.noise * irr)))
            dev = comp.device_name(pu_name)
            job = _ComputeJob(task, pu_name, dev, work, time)
            compute[task.uid] = job
            dev_members[dev].add(task.uid)
            tl.start[task.uid] = time
            tl.standalone[task.uid] = sa
            tl.queue_wait[task.uid] = time - ready_at.get(task.uid, task.release_time)
            dirty_devs.add(dev)

        def launch_transfer(consumer: Task, src_dev: str, dst_dev: str,
                            nbytes: float) -> bool:
            """Returns True if a transfer was started (False = local/no data)."""
            if src_dev == dst_dev or nbytes <= 0:
                return False
            edges = comp.route_edges(src_dev, dst_dev)
            lat = sum(e.latency for e in edges)
            key = next(xfer_seq)
            x = _TransferJob(key, consumer.uid, edges, nbytes, lat, time)
            transfers[key] = x
            for e in edges:
                edge_members[id(e)].add(key)
                dirty_edges[id(e)] = e
            return True

        def data_arrived(uid: int) -> None:
            waiting[uid] -= 1
            if waiting[uid] == 0:
                ready_at[uid] = time
                dep_done = max(task_by_uid[uid].release_time, _dep_finish(uid))
                tl.ready[uid] = dep_done
                tl.comm[uid] = time - dep_done
                start_compute(task_by_uid[uid])

        def _dep_finish(uid: int) -> float:
            preds = cfg.preds(task_by_uid[uid])
            return max((tl.finish[p.uid] for p in preds if p.uid in tl.finish),
                       default=task_by_uid[uid].release_time)

        def finish_compute(uid: int) -> None:
            job = compute.pop(uid)
            dev_members[job.device].discard(uid)
            pu_running[job.pu] -= 1
            tl.finish[uid] = time
            finished.add(uid)
            # successors: dependency bookkeeping + inter-device transfers
            t = task_by_uid.get(uid)
            if t is not None:
                for s in cfg.succs(t):
                    dst_dev = comp.device_name(mapping[s.uid])
                    if launch_transfer(s, job.device, dst_dev, t.output_bytes):
                        pass  # data_arrived fires on xdone
                    else:
                        data_arrived(s.uid)
            # wake queued tasks on this PU
            q = pu_queue[job.pu]
            if q:
                start_compute(q.popleft())
            dirty_devs.add(job.device)

        # --- initialization ----------------------------------------------
        for t in cfg:
            if t.uid not in mapping:
                raise KeyError(f"{t} has no mapping")
            waiting[t.uid] = len(cfg.preds(t)) + 1     # +1 for the release event
        for it, ifn in interventions:
            push(it, "intervene", ifn)
        for bt, bpu, brem in background:
            dev = comp.device_name(bpu)
            job = _ComputeJob(bt, bpu, dev, brem, 0.0)
            compute[bt.uid] = job
            dev_members[dev].add(bt.uid)
            pu_running[bpu] += 1
            tl.start[bt.uid] = 0.0
            tl.standalone[bt.uid] = brem
            dirty_devs.add(dev)
        flush()
        for t in cfg:
            if not cfg.preds(t):
                push(t.release_time, "release", t.uid)
            else:
                push(t.release_time, "release", t.uid)

        # --- event loop ---------------------------------------------------
        # all events sharing one timestamp drain before a single flush
        # reprices the devices/links they touched (frontier batching)
        while heap:
            time = max(time, heap[0][0])
            while heap and heap[0][0] <= time:
                _, _, kind, payload = heapq.heappop(heap)
                tl.n_events += 1
                if kind == "cdone":
                    uid, ver = payload
                    job = compute.get(uid)
                    if job is None or job.version != ver:
                        continue
                    settle(job)
                    if job.W > 1e-15:   # stale estimate; a fresh one is queued
                        continue
                    finish_compute(uid)
                elif kind == "xdone":
                    key, ver = payload
                    x = transfers.get(key)
                    if x is None or x.version != ver:
                        continue
                    settle(x)
                    if x.W > 1e-6:
                        continue
                    # latency tail: propagate arrival after fixed route latency
                    transfers.pop(key)
                    for e in x.edges:
                        edge_members[id(e)].discard(key)
                        dirty_edges[id(e)] = e
                    if x.latency > 0:
                        push(time + x.latency, "arrive", x.consumer_uid)
                    else:
                        data_arrived(x.consumer_uid)
                elif kind == "arrive":
                    data_arrived(payload)
                elif kind == "release":
                    uid = payload
                    t = task_by_uid[uid]
                    # initial input payload from the origin device
                    pu_dev = comp.device_name(mapping[uid])
                    if (t.origin is not None and t.input_bytes > 0
                            and not cfg.preds(t)):
                        if launch_transfer(t, t.origin, pu_dev, t.input_bytes):
                            continue
                    data_arrived(uid)
                elif kind == "intervene":
                    # churn boundary: apply the mutation, then reprice
                    # every occupied device pool and active link set.
                    # A Churn batch coalesces its bandwidth entries into
                    # one snapshot delta (layered route table); the
                    # repricing below reads live EdgeAttr bandwidths, so
                    # the oracle loop and TimelineEngine see identical
                    # post-churn link rates either way.
                    from .hwgraph import Churn
                    if isinstance(payload, Churn):
                        self.graph.apply_churn(payload)
                    else:
                        payload()
                    for dev, members in dev_members.items():
                        if members:
                            dirty_devs.add(dev)
                    for x in transfers.values():
                        for e in x.edges:
                            dirty_edges[id(e)] = e
                else:  # pragma: no cover
                    raise AssertionError(kind)
            flush()

        missing = [u for u in task_by_uid if u not in tl.finish]
        if missing:
            raise RuntimeError(f"traverse deadlock: unfinished {missing[:5]}")
        # background tasks may legitimately still be running; report their
        # projected finish assuming the final interval persists.
        for bt, bpu, _ in background:
            if bt.uid not in tl.finish and bt.uid in compute:
                job = compute[bt.uid]
                tl.finish[bt.uid] = time + job.W / job.rate
        return tl
