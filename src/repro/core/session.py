"""Batch-first scheduling sessions: the public mapping surface.

``SchedulerSession`` owns the mapping loop the seed's ``Runtime.run``
hand-rolled per task: callers ``submit()`` whole ``TaskGraph``s (or
streaming batches of independent tasks) and the session drives
**dependency-frontier batches** through the policy — every ready task in
a frontier is scored in one ``Orchestrator.map_batch`` call against the
compiled snapshot, replacing N independent ``map_task`` walks whose
Python dispatch dominated exactly where the compiled HW-GRAPH engine
made the math cheap.

Two wave disciplines:

* ``frontier=True`` (default) — tasks are grouped into waves of
  dependency-ready tasks sharing a release instant, in (release, uid)
  order.  Producers are always placed before consumers, so inter-device
  ``src_devices`` provenance is exact, and a wave maps in one batched
  call.
* ``frontier=False`` — one task per wave in strict (release, uid) order
  regardless of readiness: byte-for-byte the seed's ``Runtime.run``
  semantics (``Runtime`` delegates here).

Scheduling overhead accounting matches the paper (Fig. 14): each task's
overhead delays its own release before the ground-truth execution.

Topology churn during a session (``mark_dead`` / ``mark_alive`` /
``set_bandwidth``) is absorbed by ``CompiledHWGraph.apply_delta`` — the
session keeps mapping against incrementally patched snapshots instead of
triggering full recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Union

import numpy as np

from .hwgraph import Churn, HWGraph
from .orchestrator import MapResult, Orchestrator
from .task import Task, TaskGraph
from .timeline import TimelineEngine
from .traverser import TaskPrediction, Timeline, Traverser


def percentiles(values: Iterable[float],
                qs: Iterable[float] = (50.0, 99.0, 99.9)) -> dict[float, float]:
    """Tail percentiles (numpy linear interpolation) keyed by q; nan on an
    empty sample.  Shared by offline ``RunStats`` and online ``ServeStats``
    so p50/p99/p999 mean the same thing in both reports."""
    arr = np.asarray([v for v in values], dtype=np.float64)
    if arr.size == 0:
        return {float(q): float("nan") for q in qs}
    qlist = [float(q) for q in qs]
    vals = np.percentile(arr, qlist)
    return dict(zip(qlist, (float(v) for v in vals)))


def _tenant_of(task: Task) -> str:
    return str(task.attrs.get("tenant", "default"))


@dataclass
class RunStats:
    timeline: Timeline
    mapping: dict[int, str]
    overhead: dict[int, float] = field(default_factory=dict)   # uid -> seconds
    queries: dict[int, int] = field(default_factory=dict)
    hops: dict[int, int] = field(default_factory=dict)
    unmapped: list[int] = field(default_factory=list)

    def qos_failures(self, cfg: TaskGraph) -> int:
        return sum(0 if self.timeline.deadline_met(t) else 1 for t in cfg)

    def qos_failure_rate(self, cfg: TaskGraph) -> float:
        dl = [t for t in cfg if t.deadline is not None]
        if not dl:
            return 0.0
        return sum(0 if self.timeline.deadline_met(t) else 1
                   for t in dl) / len(dl)

    def mean_overhead_ratio(self, cfg: TaskGraph) -> float:
        """Fig. 14 metric: scheduling overhead / task execution time."""
        ratios = []
        for t in cfg:
            exec_t = (self.timeline.finish[t.uid] - self.timeline.start[t.uid])
            if exec_t > 0 and t.uid in self.overhead:
                ratios.append(self.overhead[t.uid] / exec_t)
        return float(np.mean(ratios)) if ratios else 0.0

    # -- tail metrics (same definitions as serving.ServeStats) -------------
    def latencies(self, cfg: TaskGraph) -> list[float]:
        """Per-task ready-to-finish latencies over ``cfg``, in cfg order
        (tasks that never finished — partial timelines — are skipped)."""
        return [self.timeline.latency(t) for t in cfg
                if t.uid in self.timeline.finish]

    def latency_percentiles(self, cfg: TaskGraph,
                            qs: Iterable[float] = (50.0, 99.0, 99.9),
                            ) -> dict[float, float]:
        """p50/p99/p999 task latency — the offline counterpart of the
        serving report's request tails."""
        return percentiles(self.latencies(cfg), qs)

    def latencies_by_tenant(self, cfg: TaskGraph) -> dict[str, list[float]]:
        """Latencies grouped by each task's ``attrs["tenant"]`` (tasks
        without one land in the "default" group)."""
        out: dict[str, list[float]] = {}
        for t in cfg:
            if t.uid in self.timeline.finish:
                out.setdefault(_tenant_of(t), []).append(
                    self.timeline.latency(t))
        return out

    def latency_percentiles_by_tenant(
            self, cfg: TaskGraph,
            qs: Iterable[float] = (50.0, 99.0, 99.9),
            ) -> dict[str, dict[float, float]]:
        return {ten: percentiles(vals, qs)
                for ten, vals in self.latencies_by_tenant(cfg).items()}

    def sla_attainment(self, cfg: TaskGraph) -> dict[str, float]:
        """Per-tenant fraction of deadline-carrying tasks that met their
        deadline (tenants with no deadlines are omitted)."""
        tot: dict[str, int] = {}
        ok: dict[str, int] = {}
        for t in cfg:
            if t.deadline is None or t.uid not in self.timeline.finish:
                continue
            ten = _tenant_of(t)
            tot[ten] = tot.get(ten, 0) + 1
            ok[ten] = ok.get(ten, 0) + (1 if self.timeline.deadline_met(t)
                                        else 0)
        return {ten: ok[ten] / tot[ten] for ten in tot}


def _any_supporting(graph: HWGraph, task: Task) -> Optional[MapResult]:
    """Degraded fallback when the policy declines a task: any PU that can
    run it at all, so execution remains defined."""
    for pu in graph.pus():
        if pu.model is None or not pu.model.supports(task, pu):
            continue
        if (task.attrs.get("pinned") and
                graph.device_of(pu.name).name != task.origin):
            continue
        return MapResult(pu=pu.name,
                         prediction=TaskPrediction(pu.predict(task), 1.0, 0.0))
    return None


Policy = Union[Callable[[Task, float], Optional[MapResult]], Orchestrator]


class SchedulerSession:
    """Batch-first scheduling over one graph: submit, map, execute.

    ``policy`` may be

    * an :class:`Orchestrator` (typically the root): waves go through
      ``map_batch(..., route=True)``, entering at each task's origin
      device ORC;
    * any object with a ``map_batch(tasks, now)`` method (e.g. the
      simulator policies);
    * a plain ``assign(task, now) -> MapResult`` callable: waves fall
      back to per-task calls in order (sequential-compatible).

    Typical use::

        session = SchedulerSession(graph, root, truth=truth)
        session.submit(cfg)                  # a TaskGraph, or more later
        stats = session.run()                # map frontiers + execute
    """

    def __init__(self, graph: HWGraph, policy: Policy,
                 truth: Optional[Traverser] = None,
                 charge_overhead: bool = True,
                 frontier: bool = True) -> None:
        self.graph = graph
        self.policy = policy
        self.truth = truth
        self.charge_overhead = charge_overhead
        self.frontier = frontier
        if isinstance(policy, Orchestrator):
            # lower the ORC tree to its compiled scan plans up front so
            # the first mapping wave doesn't pay the one-time build
            policy.prepare(graph.compiled())
        self._cfg = TaskGraph("session")
        self._mapped: set[int] = set()
        # submitted-but-unmapped tasks: the wave loop scans this instead
        # of the whole (ever-growing) session CFG, so a serving session's
        # per-wave mapping cost tracks the wave size, not the history
        self._pending: list[Task] = []
        self.results: dict[int, Optional[MapResult]] = {}
        self.mapping: dict[int, str] = {}
        self.unmapped: list[int] = []
        # session-resident timeline (serving mode); opens count full engine
        # builds — a healthy serving run opens exactly once
        self.engine: Optional[TimelineEngine] = None
        self.engine_opens = 0

    # -- submission ---------------------------------------------------------
    def submit(self, work: Union[TaskGraph, Iterable[Task]]) -> "SchedulerSession":
        """Enqueue a whole TaskGraph, or a streaming batch of independent
        tasks.  May be called repeatedly (uids are globally unique)."""
        if isinstance(work, TaskGraph):
            for t in work.tasks:
                self._cfg.tasks.append(t)
                self._cfg._succ.setdefault(t.uid, []).extend(work.succs(t))
                self._cfg._pred.setdefault(t.uid, []).extend(work.preds(t))
                self._pending.append(t)
        else:
            for t in work:
                self._cfg.add(t)
                self._pending.append(t)
        return self

    @property
    def cfg(self) -> TaskGraph:
        return self._cfg

    # -- frontier construction ---------------------------------------------
    def _waves(self) -> Iterable[tuple[float, list[Task]]]:
        """Yield (now, tasks) mapping waves over the pending tasks.

        Frontier mode: dependency-ready tasks sharing the earliest pending
        release instant.  Sequential mode: singleton waves in strict
        (release, uid) order with no readiness gating (seed semantics).
        Release times are read before any overhead is charged."""
        still = [t for t in self._pending if t.uid not in self._mapped]
        self._pending = still
        pending = sorted(still, key=lambda t: (t.release_time, t.uid))
        if not self.frontier:
            for t in pending:
                yield t.release_time, [t]
            return
        done = set(self._mapped)
        remaining = pending
        while remaining:
            ready = [t for t in remaining
                     if all(p.uid in done for p in self._cfg.preds(t))]
            if not ready:
                raise ValueError("dependency cycle or missing producer in "
                                 f"submitted tasks: {remaining[:3]}")
            r0 = ready[0].release_time
            wave = [t for t in ready if t.release_time == r0]
            yield r0, wave
            for t in wave:
                done.add(t.uid)
            remaining = [t for t in remaining if t.uid not in done]

    # -- mapping ------------------------------------------------------------
    def _assign_wave(self, wave: list[Task],
                     now: float) -> list[Optional[MapResult]]:
        pol = self.policy
        if isinstance(pol, Orchestrator):
            return pol.map_batch(wave, now, route=True)
        batch = getattr(pol, "map_batch", None)
        if batch is not None and (self.frontier or len(wave) > 1):
            return batch(wave, now)
        return [pol(t, now) for t in wave]

    def map_pending(self, fallback: bool = True,
                    ) -> dict[int, Optional[MapResult]]:
        """Drive the wave loop over everything submitted but not yet
        mapped; commits assignments and charges overhead.  Returns the
        results of this call only.

        ``fallback=False`` records a declined task as ``None`` instead of
        degrading to any supporting PU — the admission-control path, where
        infeasibility must surface as a reject/defer signal rather than a
        desperate placement (withdraw the task afterwards)."""
        out: dict[int, Optional[MapResult]] = {}
        comp = self.graph.compiled()
        for now, wave in self._waves():
            for t in wave:
                preds = self._cfg.preds(t)
                placed = [p.assigned_pu for p in preds if p.assigned_pu]
                if placed:
                    t.attrs["src_devices"] = sorted(
                        {comp.device_name(pu) for pu in placed})
            results = self._assign_wave(wave, now)
            for t, res in zip(wave, results):
                self._mapped.add(t.uid)
                if res is None:
                    self.unmapped.append(t.uid)
                    if not fallback:
                        out[t.uid] = None
                        self.results[t.uid] = None
                        continue
                    # fall back to any supporting PU so execution remains
                    # defined
                    res = _any_supporting(self.graph, t)
                    if res is None:
                        raise RuntimeError(f"no PU supports {t}")
                self.mapping[t.uid] = res.pu
                out[t.uid] = res
                self.results[t.uid] = res
                if self.charge_overhead and res.overhead:
                    # a release-time change on a ledger-resident row: tell
                    # the ledger so persistent walk state re-reads it
                    t.release_time += res.overhead
                    pol = self.policy
                    if isinstance(pol, Orchestrator):
                        touch = getattr(pol.ledger, "touch", None)
                        if touch is not None:
                            touch(comp.device_name(res.pu))
        return out

    def withdraw(self, task: Task) -> None:
        """Undo a mapping commit and drop ``task`` from the session — the
        admission-rejection path.  Reverts the overhead charge, clears the
        ledger belief and ``assigned_pu``, and removes the task from the
        session CFG.  Tasks already injected into a resident timeline
        cannot be withdrawn (their intervals are settled history)."""
        if self.engine is not None and task.uid in self.engine.slot_of:
            raise ValueError(
                f"{task} is already injected into the resident timeline")
        res = self.results.pop(task.uid, None)
        self.mapping.pop(task.uid, None)
        self._mapped.discard(task.uid)
        self._pending = [t for t in self._pending if t.uid != task.uid]
        if task.uid in self.unmapped:
            self.unmapped.remove(task.uid)
        if res is not None:
            if self.charge_overhead:
                task.release_time -= res.overhead
            task.assigned_pu = None
            if isinstance(self.policy, Orchestrator):
                self.policy.ledger.remove(task)
        self._cfg.remove(task)

    # -- resident timeline (online serving) ---------------------------------
    def open_timeline(self, interventions=()) -> TimelineEngine:
        """Open the session-resident DES timeline: built once, advanced to
        each admission instant, fed by ``inject``.  The engine shares this
        session's CFG and mapping dict, so later ``map_pending`` commits
        are visible without copying.  Anything already submitted must be
        mapped first (its releases enter the event heap at open)."""
        if self.engine is not None:
            raise RuntimeError("resident timeline already open")
        if self.truth is None:
            from .simulator import ground_truth_traverser
            self.truth = ground_truth_traverser(self.graph)
        self.engine = TimelineEngine.open(
            self.truth, cfg=self._cfg, mapping=self.mapping,
            interventions=interventions)
        self.engine_opens += 1
        return self.engine

    def inject(self, tasks: Iterable[Task]) -> None:
        """Push freshly mapped tasks into the resident timeline."""
        if self.engine is None:
            raise RuntimeError("open_timeline() first")
        self.engine.inject(list(tasks))

    def churn(self, delta: "Churn", at: Optional[float] = None) -> None:
        """Apply (or schedule) one :class:`~.hwgraph.Churn` delta batch —
        the consolidated churn entrypoint.

        * ``at`` set: queued on the resident timeline at simulated time
          ``at`` (requires an open engine), replacing the old
          ``interventions=[(t, fn)]`` plumbing.
        * engine open, ``at`` omitted: applied at the current engine
          clock through the one-flush reprice path (the serve-loop
          mid-run delta case).
        * no engine: applied to the graph immediately; the compiled
          snapshot absorbs it via ``apply_delta`` and the next
          ``map_pending`` sees the new topology.
        """
        if at is not None:
            if self.engine is None:
                raise RuntimeError(
                    "churn(at=...) schedules on the resident timeline — "
                    "open_timeline() first (or omit `at`)")
            self.engine.schedule(at, delta)
        elif self.engine is not None:
            self.engine.apply_churn(delta)
        else:
            self.graph.apply_churn(delta)

    def finalize_online(self, drain: bool = True) -> RunStats:
        """Collect RunStats from the resident timeline.  ``drain=True``
        advances to quiescence first (every injected task finishes);
        ``drain=False`` snapshots mid-flight (partial timeline)."""
        if self.engine is None:
            raise RuntimeError("open_timeline() first")
        if drain:
            self.engine.advance()
        return self._stats(self.engine.timeline(partial=not drain))

    # -- execution ----------------------------------------------------------
    def _stats(self, tl: Timeline) -> RunStats:
        stats = RunStats(timeline=tl, mapping=dict(self.mapping),
                         unmapped=list(self.unmapped))
        for uid, res in self.results.items():
            if res is not None:
                stats.overhead[uid] = res.overhead
                stats.queries[uid] = res.queries
                stats.hops[uid] = res.hops
        return stats

    def execute(self) -> RunStats:
        """Run everything mapped so far through the ground-truth engine
        (a fresh one-shot traverse — the offline path)."""
        if self.truth is None:
            from .simulator import ground_truth_traverser
            self.truth = ground_truth_traverser(self.graph)
        tl = self.truth.traverse(self._cfg, self.mapping)
        return self._stats(tl)

    def run(self, work: Optional[Union[TaskGraph, Iterable[Task]]] = None,
            ) -> RunStats:
        """submit (optional) + map every pending frontier + execute."""
        if work is not None:
            self.submit(work)
        self.map_pending()
        return self.execute()
