"""HW-GRAPH: multi-layer graph-based hardware representation (paper §3.3).

A ``HWGraph`` holds nodes (compute units, storage, controllers, abstract
components, and GROUP sub-graphs) connected by interconnect edges.  Layers of
abstraction are expressed two ways, both from the paper's Fig. 4:

* GROUP nodes contain children (a CPU with cores+caches inside; a pod with
  hosts inside).  The parent/child relation is the Orchestrator hierarchy.
* ``abstraction links`` (the red dashed edges in Fig. 4) tie an ABSTRACT
  placeholder in a coarse layer to its detailed realization in a finer layer.

Every component a ``Task`` can be mapped to is a ``ProcessingUnit`` which
implements the ``Predictable`` interface: ``predict(task, unit)`` and
``get_compute_path()`` (single-source shortest path from the PU to the
storage/controller resources it relies on — the mechanism by which shared
resources between concurrently-running PUs are discovered algorithmically).

Two-layer architecture: this module is the mutable **authoring layer** —
topology builders construct it, and ``mark_dead`` / ``mark_alive`` /
``set_bandwidth`` mutate it at runtime.  Hot-path consumers (the slowdown
model, the Traverser's contention repricing, the Orchestrator's candidate
checks) evaluate against the dense **compiled layer** instead: a
``core.compiled.CompiledHWGraph`` snapshot obtained via :meth:`HWGraph.compiled`,
rebuilt lazily whenever ``_invalidate_paths()`` fires on mutation.  Object
queries here remain the reference semantics the compiled arrays must match
(parity is tested to 1e-9).
"""
from __future__ import annotations

import heapq
import itertools
import warnings
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Iterable, Optional, Sequence


class NodeKind(Enum):
    COMPUTE = "compute"        # a PU: CPU core cluster, GPU, DLA, TPU chip, ...
    STORAGE = "storage"        # cache, DRAM, HBM, SRAM
    CONTROLLER = "controller"  # memory controller, network switch, router
    ABSTRACT = "abstract"      # internals unknown (e.g. WAN fabric, DCN)
    GROUP = "group"            # sub-graph: SoC, server, rack, pod, cluster


class Unit(Enum):
    """What ``predict`` should return (paper: the UNIT parameter)."""

    SECONDS = "seconds"
    JOULES = "joules"
    FLOPS = "flops"
    BYTES = "bytes"


@dataclass
class Node:
    """A vertex of the HW-GRAPH."""

    name: str
    kind: NodeKind
    attrs: dict[str, Any] = field(default_factory=dict)
    parent: Optional[str] = None          # enclosing GROUP node name
    alive: bool = True                    # dynamic adaptability: dead nodes are skipped

    def __hash__(self) -> int:  # nodes are identified by name
        return hash(self.name)


@dataclass
class EdgeAttr:
    """An interconnect. ``bandwidth`` in bytes/s, ``latency`` in seconds."""

    bandwidth: float = float("inf")
    latency: float = 0.0
    name: str = ""
    attrs: dict[str, Any] = field(default_factory=dict)

    def transfer_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return self.latency
        return self.latency + nbytes / self.bandwidth


class Predictable(ABC):
    """Interface every mappable HW component must implement (paper §3.3)."""

    @abstractmethod
    def predict(self, task: "Task", unit: Unit = Unit.SECONDS) -> float:  # noqa: F821
        """Standalone cost of ``task`` on this component (no co-runners)."""

    @abstractmethod
    def get_compute_path(self) -> list[str]:
        """Names of storage/controller nodes this PU relies on (via SSSP)."""


class ProcessingUnit(Node, Predictable):
    """A COMPUTE node with an attached performance model.

    ``model`` is any object with ``predict(task, pu, unit) -> float`` —
    the modular performance-model interface (profiled tables, roofline,
    analytic, learned; see core/predict.py).
    """

    def __init__(self, name: str, model: Any = None, max_tenancy: int = 8,
                 attrs: Optional[dict[str, Any]] = None, parent: Optional[str] = None):
        super().__init__(name=name, kind=NodeKind.COMPUTE, attrs=dict(attrs or {}),
                         parent=parent)
        self.model = model
        self.max_tenancy = max_tenancy      # concurrent tasks beyond this queue up
        self._graph: Optional["HWGraph"] = None
        self._compute_path: Optional[list[str]] = None

    # -- Predictable ------------------------------------------------------
    def predict(self, task, unit: Unit = Unit.SECONDS) -> float:
        if self.model is None:
            raise ValueError(f"PU {self.name} has no performance model attached")
        return self.model.predict(task, self, unit)

    def get_compute_path(self) -> list[str]:
        """SSSP from this PU to every reachable STORAGE/CONTROLLER node.

        The result is cached: it is topology-dependent, not task-dependent.
        Only intra-device resources are considered (the search does not cross
        GROUP boundaries upward past this PU's device), matching the paper:
        the path list is "obtained during profiling and stored in the TASK".
        """
        if self._compute_path is None:
            if self._graph is None:
                raise ValueError(f"PU {self.name} is not part of a graph")
            self._compute_path = self._graph.resource_path(self.name)
        return self._compute_path

    def invalidate(self) -> None:
        self._compute_path = None


@dataclass(frozen=True)
class Churn:
    """One batch of topology churn — the consolidated delta surface.

    Replaces the three per-call entrypoints (``mark_dead`` /
    ``mark_alive`` / ``set_bandwidth``): a single ``Churn`` value can be
    applied immediately (``HWGraph.apply_churn``), scheduled on a running
    timeline (``TimelineEngine.schedule(t, churn)``), injected mid-run
    through the one-flush reprice path (``TimelineEngine.apply_churn``),
    or routed through all three by ``SchedulerSession.churn``.

    Application order within a batch is deaths, then revivals, then
    bandwidth changes.  Deaths/revivals delta-patch the compiled
    snapshot via ``CompiledHWGraph.apply_delta`` exactly as the old
    sequential calls did; bandwidth entries are coalesced
    last-writer-wins per link into **one** multi-edge delta, so a batch
    of N link changes pays a single bandwidth-overlay copy and the
    resulting snapshot is identical to applying them one by one."""

    dead: Sequence[str] = ()
    alive: Sequence[str] = ()
    bandwidth: Sequence[tuple[str, float]] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "dead", tuple(self.dead))
        object.__setattr__(self, "alive", tuple(self.alive))
        object.__setattr__(self, "bandwidth",
                           tuple((e, float(b)) for e, b in self.bandwidth))

    def __bool__(self) -> bool:
        return bool(self.dead or self.alive or self.bandwidth)

    def __len__(self) -> int:
        return len(self.dead) + len(self.alive) + len(self.bandwidth)


class HWGraph:
    """Connected multi-layer graph topology of a DECS (or a TPU fleet)."""

    def __init__(self) -> None:
        self.nodes: dict[str, Node] = {}
        self._adj: dict[str, list[tuple[str, EdgeAttr]]] = {}
        self._children: dict[str, list[str]] = {}
        # red dashed links in Fig. 4: detailed-node -> abstract-node (and back)
        self.abstraction: dict[str, str] = {}
        self.refinement: dict[str, str] = {}
        self._compiled = None        # lazy CompiledHWGraph snapshot
        self.recompile_count = 0     # full snapshot builds
        self.delta_count = 0         # incremental apply_delta patches
        self.route_row_builds = 0    # lazily materialized route rows (Dijkstras)
        # layered route-table copy counters (see docs/timeline.md,
        # "Route-table layering"): holder = O(D^2) topology-layer copies
        # (death/revival churn only), overlay = O(changed rows) bandwidth
        # overlay copies (one per coalesced bandwidth delta batch)
        self.route_holder_copies = 0
        self.route_overlay_copies = 0
        # overlay folds into a solely-owned topology layer (bounds the
        # overlay dict on long bandwidth-volatile serving runs)
        self.route_overlay_compactions = 0

    # -- construction ------------------------------------------------------
    def add_node(self, node: Node) -> Node:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name!r}")
        self.nodes[node.name] = node
        self._adj.setdefault(node.name, [])
        self._children.setdefault(node.name, [])
        if node.parent is not None:
            self._children.setdefault(node.parent, []).append(node.name)
        if isinstance(node, ProcessingUnit):
            node._graph = self
        self._compiled = None
        return node

    def add_edge(self, u: str, v: str, bandwidth: float = float("inf"),
                 latency: float = 0.0, name: str = "",
                 attrs: Optional[dict[str, Any]] = None) -> EdgeAttr:
        for n in (u, v):
            if n not in self.nodes:
                raise KeyError(f"unknown node {n!r}")
        e = EdgeAttr(bandwidth=bandwidth, latency=latency,
                     name=name or f"{u}--{v}", attrs=dict(attrs or {}))
        self._adj[u].append((v, e))
        self._adj[v].append((u, e))
        self._compiled = None
        return e

    def add_abstraction_link(self, detailed: str, abstract: str) -> None:
        """Tie a detailed node to its coarse placeholder (Fig. 4 red dashes)."""
        self.abstraction[detailed] = abstract
        self.refinement[abstract] = detailed

    # -- queries -----------------------------------------------------------
    def node(self, name: str) -> Node:
        return self.nodes[name]

    def children_of(self, name: str) -> list[Node]:
        return [self.nodes[c] for c in self._children.get(name, [])]

    def parent_of(self, name: str) -> Optional[Node]:
        p = self.nodes[name].parent
        return self.nodes[p] if p is not None else None

    def neighbors(self, name: str) -> list[tuple[Node, EdgeAttr]]:
        return [(self.nodes[v], e) for v, e in self._adj[name]]

    def pus(self, under: Optional[str] = None) -> list[ProcessingUnit]:
        """All (alive) ProcessingUnits, optionally restricted to a GROUP subtree."""
        if under is None:
            return [n for n in self.nodes.values()
                    if isinstance(n, ProcessingUnit) and n.alive]
        out: list[ProcessingUnit] = []
        stack = [under]
        while stack:
            cur = stack.pop()
            n = self.nodes[cur]
            if isinstance(n, ProcessingUnit) and n.alive:
                out.append(n)
            stack.extend(self._children.get(cur, []))
        return out

    def device_of(self, name: str) -> Node:
        """The physical-device GROUP containing ``name``.

        A device group is tagged ``attrs['orc_level'] == 'device'`` by the
        topology builders (SoCs, servers, TPU hosts).  Falls back to the
        top-most group below the root for untagged graphs.
        """
        node: Optional[Node] = self.nodes[name]
        tagged: Optional[Node] = None
        while node is not None:
            if node.attrs.get("orc_level") == "device":
                tagged = node
            node = self.nodes[node.parent] if node.parent is not None else None
        if tagged is not None:
            return tagged
        cur = self.nodes[name]
        while cur.parent is not None and self.nodes[cur.parent].parent is not None:
            cur = self.nodes[cur.parent]
        return cur

    # -- shortest paths ----------------------------------------------------
    def sssp(self, src: str, weight: Callable[[EdgeAttr], float] | None = None,
             within_device: bool = False) -> tuple[dict[str, float], dict[str, str]]:
        """Dijkstra from ``src``. Returns (dist, predecessor).

        ``within_device`` restricts exploration to nodes sharing ``src``'s
        enclosing device group (used by get_compute_path so a PU's resource
        list does not leak across the network).
        """
        if weight is None:
            weight = lambda e: e.latency if e.latency > 0 else 1e-9
        home = self.device_of(src).name if within_device else None
        dist: dict[str, float] = {src: 0.0}
        pred: dict[str, str] = {}
        pq: list[tuple[float, str]] = [(0.0, src)]
        while pq:
            d, u = heapq.heappop(pq)
            if d > dist.get(u, float("inf")):
                continue
            for v, e in self._adj[u]:
                if not self.nodes[v].alive:
                    continue
                if home is not None and self.device_of(v).name != home:
                    continue
                nd = d + weight(e)
                if nd < dist.get(v, float("inf")):
                    dist[v] = nd
                    pred[v] = u
                    heapq.heappush(pq, (nd, v))
        return dist, pred

    def path(self, src: str, dst: str) -> list[tuple[str, Optional[EdgeAttr]]]:
        """Node/edge sequence of the shortest path src -> dst (global graph)."""
        dist, pred = self.sssp(src)
        if dst not in dist:
            raise KeyError(f"no path {src} -> {dst}")
        seq: list[str] = [dst]
        while seq[-1] != src:
            seq.append(pred[seq[-1]])
        seq.reverse()
        out: list[tuple[str, Optional[EdgeAttr]]] = [(seq[0], None)]
        for a, b in itertools.pairwise(seq):
            edge = min((e for v, e in self._adj[a] if v == b),
                       key=lambda e: e.latency)
            out.append((b, edge))
        return out

    def transfer_time(self, src: str, dst: str, nbytes: float) -> float:
        """End-to-end transfer cost along the shortest path (store-and-forward
        latency sum; bandwidth bottleneck = min along path)."""
        if src == dst:
            return 0.0
        hops = self.path(src, dst)
        lat = sum(e.latency for _, e in hops if e is not None)
        bw = min((e.bandwidth for _, e in hops if e is not None),
                 default=float("inf"))
        return lat + (nbytes / bw if bw != float("inf") else 0.0)

    def route_edges(self, src: str, dst: str) -> list[EdgeAttr]:
        return [e for _, e in self.path(src, dst) if e is not None]

    def resource_path(self, pu: str) -> list[str]:
        """The memory-hierarchy chain the PU relies on (paper: SSSP between
        the PU and the memory/control sources it uses).

        Returns the STORAGE/CONTROLLER nodes on the shortest path from the PU
        to its device's main memory (nearest dram/hbm node), ordered
        PU-outward — e.g. cpu core -> [L2, L3, LLC, DRAM].  Two PUs' chains
        intersect exactly at the resources they genuinely contend on, and the
        first intersection is the nearest contention point.
        """
        dist, pred = self.sssp(pu, within_device=True)
        sinks = [n for n in dist
                 if self.nodes[n].attrs.get("rclass") in ("dram", "hbm")]
        if sinks:
            sink = min(sinks, key=lambda n: dist[n])
            seq = [sink]
            while seq[-1] != pu:
                seq.append(pred[seq[-1]])
            seq.reverse()
            return [n for n in seq if self.nodes[n].kind in
                    (NodeKind.STORAGE, NodeKind.CONTROLLER)]
        out = [n for n in dist
               if self.nodes[n].kind in (NodeKind.STORAGE, NodeKind.CONTROLLER)]
        out.sort(key=lambda n: dist[n])
        return out

    def shared_resources(self, pu_a: str, pu_b: str) -> list[str]:
        """Resources two PUs contend on = intersection of compute paths.

        This is the paper's Fig. 4 example: DLA and PVA both reach SRAM and
        LPDDR4x, so concurrent execution contends on those.
        """
        a = self.nodes[pu_a]
        b = self.nodes[pu_b]
        pa = a.get_compute_path() if isinstance(a, ProcessingUnit) else self.resource_path(pu_a)
        pb = b.get_compute_path() if isinstance(b, ProcessingUnit) else self.resource_path(pu_b)
        shared = set(pa) & set(pb)
        return sorted(shared)

    # -- dynamic adaptability ------------------------------------------------
    def _subtree(self, name: str) -> list[str]:
        out: list[str] = []
        stack = [name]
        while stack:
            cur = stack.pop()
            out.append(cur)
            stack.extend(self._children.get(cur, []))
        return out

    def apply_churn(self, churn: "Churn") -> None:
        """Apply one :class:`Churn` delta batch — the single topology-churn
        entrypoint (deaths, then revivals, then bandwidth changes).
        Deaths and revivals route through ``_after_mutation`` exactly
        like the old per-call surface.  Bandwidth entries are coalesced
        **last-writer-wins per link** and applied as one multi-edge
        ``set_bandwidth`` delta, so N bandwidth changes in one batch pay
        a single overlay copy; the final snapshot is identical to the
        sequential per-entry patches (each patch reprices a route from
        its edges' live bandwidths, and only the last write to a link
        survives either way)."""
        for name in churn.dead:
            self._mark_dead(name)
        for name in churn.alive:
            self._mark_alive(name)
        if churn.bandwidth:
            final: dict[str, float] = {}
            for edge_name, bandwidth in churn.bandwidth:
                final[edge_name] = bandwidth
            self._set_bandwidths(final)

    def _mark_dead(self, name: str) -> None:
        """Node failure: the node (and its subtree) stops being schedulable."""
        names = self._subtree(name)
        for cur in names:
            self.nodes[cur].alive = False
        self._after_mutation("mark_dead", names=names)

    def _mark_alive(self, name: str) -> None:
        names = self._subtree(name)
        for cur in names:
            self.nodes[cur].alive = True
        self._after_mutation("mark_alive", names=names)

    def _set_bandwidths(self, updates: dict[str, float]) -> None:
        """Dynamic network conditions (paper §5.4.1): re-provision many
        links in one delta.  Validates every name before mutating (the
        authoring layer is never left half-applied on a bad batch)."""
        hit: set[str] = set()
        edges: list[EdgeAttr] = []
        for adj in self._adj.values():
            for _, e in adj:
                if e.name in updates:
                    edges.append(e)
                    hit.add(e.name)
        missing = set(updates) - hit
        if missing:
            raise KeyError(f"no edge named {sorted(missing)[0]!r}")
        for e in edges:
            e.bandwidth = updates[e.name]
        self._after_mutation("set_bandwidth", edge_names=tuple(updates))

    # -- deprecated per-call churn shims ------------------------------------
    # (each is a one-entry Churn: the batch surface is the only delta
    # plumbing left, so the shims cannot drift from apply_churn)
    def mark_dead(self, name: str) -> None:
        """.. deprecated:: batch churn through :meth:`apply_churn` (or
        ``SchedulerSession.churn``)."""
        warnings.warn(
            "HWGraph.mark_dead is deprecated: apply churn as a delta batch "
            "via HWGraph.apply_churn(Churn(dead=[...])) or "
            "SchedulerSession.churn(...)", DeprecationWarning, stacklevel=2)
        self.apply_churn(Churn(dead=(name,)))

    def mark_alive(self, name: str) -> None:
        """.. deprecated:: batch churn through :meth:`apply_churn` (or
        ``SchedulerSession.churn``)."""
        warnings.warn(
            "HWGraph.mark_alive is deprecated: apply churn as a delta batch "
            "via HWGraph.apply_churn(Churn(alive=[...])) or "
            "SchedulerSession.churn(...)", DeprecationWarning, stacklevel=2)
        self.apply_churn(Churn(alive=(name,)))

    def set_bandwidth(self, edge_name: str, bandwidth: float) -> None:
        """.. deprecated:: batch churn through :meth:`apply_churn` (or
        ``SchedulerSession.churn``)."""
        warnings.warn(
            "HWGraph.set_bandwidth is deprecated: apply churn as a delta "
            "batch via HWGraph.apply_churn(Churn(bandwidth=[(edge, bw)])) "
            "or SchedulerSession.churn(...)", DeprecationWarning,
            stacklevel=2)
        self.apply_churn(Churn(bandwidth=((edge_name, bandwidth),)))

    def _after_mutation(self, kind: str, names=(), edge_names=()) -> None:
        """Invalidate object-layer caches, then delta-patch the compiled
        snapshot instead of dropping it (full rebuild only when the delta
        engine declines — see ``CompiledHWGraph.apply_delta``)."""
        for n in self.nodes.values():
            if isinstance(n, ProcessingUnit):
                n.invalidate()
        if self._compiled is not None:
            try:
                patched = self._compiled.apply_delta(kind, names=names,
                                                     edge_names=edge_names)
            except Exception:
                patched = None
            self._compiled = patched
            if patched is not None:
                self.delta_count += 1

    def _invalidate_paths(self) -> None:
        for n in self.nodes.values():
            if isinstance(n, ProcessingUnit):
                n.invalidate()
        self._compiled = None

    def compiled(self):
        """The array-native snapshot of the current topology version.

        Built lazily on first use.  Construction-time mutations drop the
        snapshot entirely; runtime mutations (mark_dead / mark_alive /
        set_bandwidth) patch it incrementally via ``apply_delta`` so
        callers may simply re-fetch it per decision.  ``recompile_count``
        / ``delta_count`` record which path each topology version took."""
        if self._compiled is None:
            from .compiled import CompiledHWGraph
            self._compiled = CompiledHWGraph(self)
            self.recompile_count += 1
        return self._compiled

    # -- convenience ---------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self.nodes

    def __len__(self) -> int:
        return len(self.nodes)

    def summary(self) -> str:
        kinds: dict[str, int] = {}
        for n in self.nodes.values():
            kinds[n.kind.value] = kinds.get(n.kind.value, 0) + 1
        edges = sum(len(v) for v in self._adj.values()) // 2
        parts = ", ".join(f"{k}={v}" for k, v in sorted(kinds.items()))
        return f"HWGraph({len(self.nodes)} nodes [{parts}], {edges} edges)"
