"""Decoupled shared-resource slowdown models (paper §3.4).

The paper's accuracy insight: *decouple* standalone performance from the
slowdown caused by shared-resource use.  Once per system, each shareable
resource is characterized for the slowdown it induces per amount of
concurrent use; each task is characterized by its generalized usage of each
resource; at runtime ``slowdown()`` combines the two.

Two contention mechanisms (paper §2.2, Fig. 2):

* **Shared-memory contention across PUs** — discovered via the HW-GRAPH:
  the *nearest common resource* on the two PUs' compute paths is the
  contention point (e.g. two cores in one cluster meet at L2; cores in
  different clusters meet at L3; GPU and DLA meet at DRAM).  Using the
  nearest common point (rather than every shared node) reflects that an
  upstream shared cache merges/filters traffic before it reaches deeper
  levels, and is what reproduces the paper's Fig. 2 ordering
  (L2 0.91x > L3 0.87x).

* **Multi-tenancy on one PU** — co-tenant tasks on the same PU slow each
  other down by a PU-class-specific factor (GPU 0.66x for 2 DNNs, etc.).

Calibration below reproduces the paper's Orin AGX measurements:
  same-cluster CPU MMs (L2)          -> 0.91x   => beta_l2  = 0.099
  cross-cluster CPU MMs (L3)         -> 0.87x   => beta_l3  = 0.149
  2 DNNs on one GPU (multi-tenancy)  -> 0.66x   => mt_gpu   = 0.515
  GPU + DLA via shared DRAM          -> 0.68x   => beta_dram= 0.47
  CPU + GPU via shared 4MB LLC       -> 0.89x   => beta_llc = 0.124

The ground-truth simulator uses the same structure with a superlinear term
and task-kind-specific irregular-access noise (``truth_params``), so that the
H-EYE predictor (linear, noise-free) exhibits a small but honest error while
contention-blind baselines (ACE-like) err by the full contention amount.

Batched evaluation: the per-pair helpers (``nearest_shared``, ``factor``)
now read the ``CompiledHWGraph`` snapshot (nearest-common-resource matrix,
per-PU caps/classes), and three vectorized entry points evaluate whole
pools at once over the same arrays — ``factor_batch`` (joint factors of a
co-running pool, used by the Traverser at contention-interval boundaries),
``slowdown_matrix`` (all pairwise co-run factors in one shot) and
``factors_with_candidates`` (the Orchestrator's one-shot constraint check
over every candidate PU).  The factor-aggregation inner loop dispatches to
a Pallas kernel on TPU (kernels/slowdown_kernel.py) and to the equivalent
numpy reference elsewhere.  The numpy path matches the scalar path to
1e-9; the TPU kernel computes in fp32 (~1e-6 relative) — set
``REPRO_SLOWDOWN_KERNEL=ref`` to force strict float64 parity on any
backend, or ``=pallas`` to force the kernel.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .hwgraph import HWGraph
from .task import Task

# resource classes a STORAGE/CONTROLLER node may declare in attrs["rclass"]
RCLASSES = ("l2", "l3", "llc", "sram", "dram", "hbm", "vmem", "nic")

# beta for rclasses absent from SlowdownParams.beta (matches the scalar
# path's ``p.beta.get(rclass, 0.3)``)
_DEFAULT_BETA = 0.3

# pools at or below this size route through the exact scalar loop in
# ``factor_batch_idx``: below ~n=10 the array path's fixed call overhead
# (bincount/nonzero/broadcast setup) dominates the actual math, and up
# to 7 co-runner rows the sequential scalar sums match BLAS's dot
# reductions bit-for-bit (8-wide rows start SIMD-reordering the adds).
# tests/test_slowdown assert both the dispatch boundary and bit-equality.
_SMALL_POOL_MAX = 7


@dataclass
class SlowdownParams:
    # sensitivity of each resource class to one unit of co-runner pressure,
    # normalized so that beta * 1.12 reproduces Fig. 2 at x=1 co-runner
    # (the 1.12 = 1 + superlinear accounts for the profiled curvature)
    beta: dict[str, float] = field(default_factory=lambda: {
        "l2": 0.0884, "l3": 0.1330, "llc": 0.1107, "sram": 0.1786,
        "dram": 0.4196, "hbm": 0.2679, "vmem": 0.0, "nic": 0.0893,
    })
    # multi-tenancy sensitivity per PU class
    mt_beta: dict[str, float] = field(default_factory=lambda: {
        "cpu": 0.3125, "gpu": 0.4598, "dla": 0.3571, "vic": 0.2232,
        "pva": 0.2679, "tpu": 0.4018, "default": 0.3571,
    })
    superlinear: float = 0.12   # kappa: factor term beta*x*(1+kappa*x)
    noise: float = 0.0          # rel. sigma of task-irregularity noise (truth only)

    def mt(self, pu_class: str) -> float:
        return self.mt_beta.get(pu_class, self.mt_beta["default"])


def heye_params() -> SlowdownParams:
    """The calibrated model H-EYE's Traverser uses for prediction.

    The paper's step (1) profiles each shared resource "for the slowdown
    they will experience per the amount of concurrent use" — i.e. the
    calibration covers every concurrency level, so the predictor carries
    the same superlinear shape as the system it was profiled on.  What it
    can NOT know is the per-execution irregular-access noise (§5.2 names
    exactly this as the source of H-EYE's residual 3.2% error)."""
    return SlowdownParams(superlinear=0.12)


def truth_params(noise: float = 0.035, superlinear: float = 0.12) -> SlowdownParams:
    """Ground-truth behaviour: profiled contention + irregular-access noise.

    These produce the paper-reported gap: H-EYE predicts within a few
    percent (missing only the noise) while a contention-blind model misses
    the entire slowdown (tens of percent under heavy sharing)."""
    return SlowdownParams(superlinear=superlinear, noise=noise)


# ---------------------------------------------------------------------------
# batched factor aggregation: numpy fast path + Pallas kernel on TPU
# ---------------------------------------------------------------------------
def _pterm_arr(beta: np.ndarray, x: np.ndarray, kappa: float) -> np.ndarray:
    """Vectorized ``_pressure_term``: beta*x*(1+kappa*x), 0 where inactive."""
    return np.where((x > 0.0) & (beta > 0.0),
                    beta * x * (1.0 + kappa * x), 0.0)


def _aggregate_np(x: np.ndarray, beta: np.ndarray, mem: np.ndarray,
                  mt_term: np.ndarray, kappa: float) -> np.ndarray:
    """factors[i] = (1+mt_term[i]) * prod_r(1 + pterm(beta[r], x[i,r])*mem[i]).

    Same formula as ``kernels.ref.slowdown_factors_ref`` (the Pallas
    oracle); kept inline so pure-DES workflows never import jax."""
    term = _pterm_arr(beta[None, :], x, kappa)
    return np.maximum(1.0, (1.0 + mt_term)
                      * np.prod(1.0 + term * mem[:, None], axis=-1))


_AGGREGATE = None


def _aggregate(x, beta, mem, mt_term, kappa):
    """Batched factor-aggregation inner loop.

    Selected once: the Pallas kernel when jax is loaded and reports a TPU
    backend (the same ``on_tpu`` switch the other kernels use), else the
    numpy reference.  jax is never imported just to make this choice, so
    CPU-only DES runs stay jax-free.  ``REPRO_SLOWDOWN_KERNEL`` overrides
    the choice (``ref`` | ``pallas`` | ``auto``): the kernel runs in fp32,
    so deployments that need bit-stable scheduling across backends pin
    ``ref``."""
    global _AGGREGATE
    if _AGGREGATE is None:
        _AGGREGATE = _select_aggregate()
    return _AGGREGATE(x, beta, mem, mt_term, kappa)


def _select_aggregate():
    import os
    import sys
    mode = os.environ.get("REPRO_SLOWDOWN_KERNEL", "auto").lower()
    if mode == "ref":
        return _aggregate_np
    if mode == "pallas":
        from ..kernels.slowdown_kernel import slowdown_factors_pallas

        def _pallas_forced(x, beta, mem, mt_term, kappa):
            return np.asarray(slowdown_factors_pallas(x, beta, mem, mt_term,
                                                      kappa))
        return _pallas_forced
    if "jax" in sys.modules:
        try:
            import jax
            if jax.default_backend() == "tpu":
                from ..kernels.slowdown_kernel import slowdown_factors

                def _pallas(x, beta, mem, mt_term, kappa):
                    return np.asarray(slowdown_factors(x, beta, mem, mt_term,
                                                       kappa))
                return _pallas
        except Exception:       # pragma: no cover - jax probe best-effort
            pass
    return _aggregate_np


class DecoupledSlowdown:
    """slowdown(task on pu | co-running tasks) -> multiplicative factor >= 1."""

    def __init__(self, graph: HWGraph, params: Optional[SlowdownParams] = None,
                 rng: Optional[np.random.Generator] = None) -> None:
        self.graph = graph
        self.params = params or heye_params()
        self.rng = rng
        # (snapshot, (beta_vec, mt_vec)) — rebuilt when the graph compiles
        # a new snapshot; holding the snapshot itself makes the identity
        # check safe (it cannot be freed and its id reused while cached)
        self._tables_cache: Optional[tuple] = None
        # canonical-pattern result cache for single-device constraint
        # checks (see _canon_key); keyed per snapshot identity like the
        # tables, plus hit/miss counters surfaced in the benchmarks
        self._canon_cache: Optional[tuple] = None
        self.factor_cache_hits = 0
        self.factor_cache_misses = 0
        # the sharded walk drives group threads through the canon cache
        # concurrently; the counter read-modify-writes are the only
        # non-atomic mutations (cache fills are idempotent equal values)
        self._counter_lock = threading.Lock()

    # -- helpers -----------------------------------------------------------
    def nearest_shared(self, pu_a: str, pu_b: str) -> Optional[str]:
        """Nearest common resource on the compute paths of two PUs (or None
        if the PUs share nothing, e.g. they sit in different devices).

        Reads the compiled nearest-common-resource matrix, which tracks
        topology mutations automatically (no manual cache invalidation)."""
        return self.graph.compiled().nearest_common_resource(pu_a, pu_b)

    def invalidate(self) -> None:
        """Kept for API compatibility: the compiled snapshot invalidates
        itself on topology mutation, so there is no cache to clear."""
        self._tables_cache = None
        self._canon_cache = None

    def _pressure_term(self, beta: float, x: float) -> float:
        if x <= 0.0 or beta <= 0.0:
            return 0.0
        return beta * x * (1.0 + self.params.superlinear * x)

    def _mem_usage(self, task: Task, pu_name: str) -> float:
        """Effective shared-memory pressure of ``task`` when run on ``pu``.
        PUs with private data storage (e.g. VIC, §5.3.1) cap it."""
        u = task.usage.get("mem", 1.0)
        cap = self.graph.nodes[pu_name].attrs.get("mem_usage_cap")
        return min(u, cap) if cap is not None else u

    # -- per-snapshot model tables ----------------------------------------
    @staticmethod
    def _factor_state(comp) -> tuple:
        """The snapshot columns the factor model actually reads.  Two
        snapshots whose columns are the *same objects* (a bandwidth-only
        ``apply_delta`` clone shares everything but the route table) are
        kin: cached tables and canonical factors carry over verbatim."""
        return (comp.rclass_names, comp.pu_class_kind,
                getattr(comp, "ncr_rclass", None),
                getattr(comp, "mem_cap", None),
                getattr(comp, "pu_index", None))

    @classmethod
    def _factor_kin(cls, a, b) -> bool:
        return all(x is y for x, y in
                   zip(cls._factor_state(a), cls._factor_state(b)))

    def _tables(self, comp) -> tuple[np.ndarray, np.ndarray]:
        """(beta per compiled rclass, mt-beta per compiled PU); cached per
        snapshot identity, so a topology mutation (new snapshot) rebuilds
        them and stale coefficients can never leak across versions.
        Bandwidth-only delta clones are rebased, not rebuilt."""
        cached = self._tables_cache
        if cached is not None and cached[0] is not comp \
                and self._factor_kin(cached[0], comp):
            cached = (comp, cached[1])
            self._tables_cache = cached
        if cached is None or cached[0] is not comp:
            p = self.params
            beta_vec = np.array([p.beta.get(rc, _DEFAULT_BETA)
                                 for rc in comp.rclass_names])
            mt_vec = np.array([p.mt_beta.get(cls, p.mt_beta["default"])
                               for cls in comp.pu_class_kind])
            cached = (comp, (beta_vec, mt_vec))
            self._tables_cache = cached
        return cached[1]

    def _pool_arrays(self, comp, pool: Sequence[tuple[Task, str]]):
        n = len(pool)
        P = np.fromiter((comp.pu_index[p] for _, p in pool),
                        dtype=np.int64, count=n)
        U = np.fromiter((t.usage.get("pu", 1.0) for t, _ in pool),
                        dtype=np.float64, count=n)
        mem = np.fromiter((t.usage.get("mem", 1.0) for t, _ in pool),
                          dtype=np.float64, count=n)
        M = np.minimum(mem, comp.mem_cap[P])
        uid = np.fromiter((t.uid for t, _ in pool), dtype=np.int64, count=n)
        return P, U, M, uid

    def _noisy(self) -> bool:
        return self.params.noise > 0.0 and self.rng is not None

    def _apply_noise(self, task: Task, f: float) -> float:
        irregularity = task.attrs.get("irregularity", 1.0)
        return f * float(np.exp(self.rng.normal(
            0.0, self.params.noise * irregularity)))

    # -- the model (scalar reference path) ---------------------------------
    def factor(self, task: Task, pu_name: str,
               coruns: list[tuple[Task, str]]) -> float:
        """Multiplicative slowdown of ``task`` running on ``pu_name`` while
        each (other_task, other_pu) in ``coruns`` runs concurrently."""
        p = self.params
        f = 1.0
        pu = self.graph.nodes[pu_name]
        pu_class = pu.attrs.get("pu_class_kind", pu.attrs.get("pu_class", "default"))
        # split co-runners: same-PU tenants vs other-PU resource sharers
        mt_pressure = 0.0
        res_pressure: dict[str, float] = {}
        for other, other_pu in coruns:
            if other.uid == task.uid:
                continue
            if other_pu == pu_name:
                mt_pressure += other.usage.get("pu", 1.0)
            else:
                shared = self.nearest_shared(pu_name, other_pu)
                if shared is None:
                    continue
                rclass = self.graph.nodes[shared].attrs.get("rclass", "dram")
                res_pressure[rclass] = (res_pressure.get(rclass, 0.0)
                                        + self._mem_usage(other, other_pu))
        if mt_pressure > 0.0:
            f *= 1.0 + self._pressure_term(p.mt(pu_class), mt_pressure
                                           ) * task.usage.get("pu", 1.0)
        for rclass, x in res_pressure.items():
            f *= 1.0 + self._pressure_term(p.beta.get(rclass, _DEFAULT_BETA), x
                                           ) * self._mem_usage(task, pu_name)
        if p.noise > 0.0 and self.rng is not None and f > 1.0:
            f = self._apply_noise(task, f)
        return max(1.0, f)

    # -- vectorized entry points -------------------------------------------
    def factor_batch(self, pool: Sequence[tuple[Task, str]]) -> np.ndarray:
        """Joint slowdown factor of every (task, pu) in ``pool`` given all
        the others — the quantity the Traverser recomputes at each
        contention-interval boundary, in one shot instead of O(n^2) Python
        pair loops.  Matches ``factor(t, p, pool)`` per entry to 1e-9."""
        n = len(pool)
        if n == 0:
            return np.ones(0)
        if self._noisy():
            # the scalar path draws rng noise per factor call in pool
            # order; preserve the exact stream
            return np.array([self.factor(t, p, list(pool)) for t, p in pool])
        comp = self.graph.compiled()
        P, U, M, uid = self._pool_arrays(comp, pool)
        return self._factor_batch_arrays(comp, P, U, M, uid)

    def factor_batch_idx(self, P: np.ndarray, U: np.ndarray,
                         mem: np.ndarray, uid: np.ndarray) -> np.ndarray:
        """Array-native :meth:`factor_batch` over ledger-style columns
        (compiled PU index, pu-usage, raw mem-usage, uid) — the DES
        timeline engine reprices every dirty device pool in one call
        through this entry, with no tuple building.  Because compute
        paths never cross device boundaries, a pool spanning several
        devices factors exactly as the per-device pools would
        (cross-device pairs share nothing by construction).  Noise-free
        path only (the engine routes noisy models to the tuple surface)."""
        n = len(P)
        if n == 0:
            return np.ones(0)
        comp = self.graph.compiled()
        if n == 1:
            return np.ones(1)          # a lone job has no co-runners
        M = np.minimum(mem, comp.mem_cap[P])
        if n == 2:
            # scalar pair path: light-load DES pools are mostly pairs, and
            # the float ops replicate the array path bit-for-bit (a row's
            # product over inactive rclasses multiplies exact 1.0s)
            return self._factor_pair(comp, P, U, M)
        if n <= _SMALL_POOL_MAX:
            # light-load pools floor on array-path call overhead (bincount,
            # nonzero, broadcasting all cost more than the math below this
            # size); the scalar loop replicates the array path bit-for-bit
            return self._factor_small(comp, P, U, M)
        # DES pools hold one job per task, so uids are pairwise distinct:
        # self-interaction reduces to the diagonal and the uid mask work
        # is skipped entirely
        return self._factor_batch_arrays(comp, P, U, M, uid, distinct=True)

    def _factor_pair(self, comp, P, U, M) -> np.ndarray:
        beta_vec, mt_vec = self._tables(comp)
        kappa = self.params.superlinear
        out = np.empty(2)
        p0, p1 = int(P[0]), int(P[1])
        for i, (pi, pj, j) in enumerate(((p0, p1, 1), (p1, p0, 0))):
            mt_term = 0.0
            res = 0.0
            if pi == pj:
                x = float(U[j])
                mtb = float(mt_vec[pi])
                if x > 0.0 and mtb > 0.0:
                    mt_term = mtb * x * (1.0 + kappa * x) * float(U[i])
            else:
                r = int(comp.ncr_rclass[pi, pj])
                if r >= 0:
                    x = float(M[j])
                    b = float(beta_vec[r])
                    if x > 0.0 and b > 0.0:
                        res = b * x * (1.0 + kappa * x)
            f = (1.0 + mt_term) * (1.0 + res * float(M[i]))
            out[i] = f if f > 1.0 else 1.0
        return out

    def _factor_small(self, comp, P, U, M) -> np.ndarray:
        """Exact scalar path for distinct-uid pools of a few members.

        Pressure accumulation runs in ascending co-runner order and the
        per-rclass product in ascending rclass order — the same orders the
        bincount / prod reductions of ``_factor_batch_arrays`` use — so
        the result is bit-identical to the array path (inactive rclasses
        multiply exact 1.0s there and are simply skipped here)."""
        beta_vec, mt_vec = self._tables(comp)
        kappa = self.params.superlinear
        n = len(P)
        Pi = [int(p) for p in P]
        Uf = [float(u) for u in U]
        Mf = [float(m) for m in M]
        out = np.empty(n)
        for i in range(n):
            pi = Pi[i]
            mt_p = 0.0
            res: dict[int, float] = {}
            for j in range(n):
                if j == i:
                    continue
                if Pi[j] == pi:
                    mt_p += Uf[j]
                else:
                    r = int(comp.ncr_rclass[pi, Pi[j]])
                    if r >= 0:
                        res[r] = res.get(r, 0.0) + Mf[j]
            mt_term = 0.0
            mtb = float(mt_vec[pi])
            if mt_p > 0.0 and mtb > 0.0:
                mt_term = mtb * mt_p * (1.0 + kappa * mt_p) * Uf[i]
            prod = 1.0
            for r in sorted(res):
                x = res[r]
                b = float(beta_vec[r])
                if x > 0.0 and b > 0.0:
                    prod *= 1.0 + b * x * (1.0 + kappa * x) * Mf[i]
            f = (1.0 + mt_term) * prod
            out[i] = f if f > 1.0 else 1.0
        return out

    def _factor_batch_arrays(self, comp, P, U, M, uid,
                             distinct: bool = False) -> np.ndarray:
        n = len(P)
        beta_vec, mt_vec = self._tables(comp)
        kappa = self.params.superlinear
        same_pu = P[:, None] == P[None, :]
        r = comp.ncr_rclass[P[:, None], P[None, :]]
        valid = ~same_pu & (r >= 0)
        if distinct:
            np.fill_diagonal(same_pu, False)
        else:
            diff_uid = uid[:, None] != uid[None, :]
            same_pu &= diff_uid
            valid &= diff_uid
        mtp = same_pu.astype(np.float64) @ U
        R = len(comp.rclass_names)
        ii, jj = np.nonzero(valid)
        if len(ii):
            # bincount over flattened (row, rclass) bins accumulates in
            # input order, exactly like the add.at it replaces
            X = np.bincount(ii * R + r[ii, jj], weights=M[jj],
                            minlength=n * R).reshape(n, R)
        else:
            X = np.zeros((n, R))
        mt_term = _pterm_arr(mt_vec[P], mtp, kappa) * U
        return _aggregate(X, beta_vec, M, mt_term, kappa)

    def slowdown_matrix(self, pool: Sequence[tuple[Task, str]]) -> np.ndarray:
        """All pairwise co-run factors in one shot: entry [i, j] is the
        factor of pool[i] when co-running with pool[j] alone (1.0 on the
        diagonal / for non-interfering pairs)."""
        n = len(pool)
        if n == 0:
            return np.ones((0, 0))
        if self._noisy():
            return np.array([[self.factor(ti, pi, [(tj, pj)])
                              for tj, pj in pool] for ti, pi in pool])
        comp = self.graph.compiled()
        beta_vec, mt_vec = self._tables(comp)
        kappa = self.params.superlinear
        P, U, M, uid = self._pool_arrays(comp, pool)
        diff_uid = uid[:, None] != uid[None, :]
        same_pu = (P[:, None] == P[None, :]) & diff_uid
        r = comp.ncr_rclass[P[:, None], P[None, :]]
        cross = diff_uid & (P[:, None] != P[None, :]) & (r >= 0)
        mt_f = 1.0 + _pterm_arr(mt_vec[P][:, None],
                                np.where(same_pu, U[None, :], 0.0),
                                kappa) * U[:, None]
        res_term = np.where(cross,
                            _pterm_arr(beta_vec[r.clip(0)],
                                       np.broadcast_to(M[None, :], (n, n)),
                                       kappa),
                            0.0)
        return np.maximum(1.0, mt_f * (1.0 + res_term * M[:, None]))

    def factors_with_candidates(
            self, task: Task, candidate_pus: Sequence[str],
            active: Sequence[tuple[Task, str]],
    ) -> tuple[np.ndarray, np.ndarray]:
        """One-shot Orchestrator constraint check over candidate PUs.

        Returns ``(new_f, act_f)`` where ``new_f[c]`` is the factor of
        ``task`` placed on ``candidate_pus[c]`` amid ``active``, and
        ``act_f[c, a]`` is the updated factor of ``active[a]`` if the task
        joins on candidate ``c`` (Alg. 1 line 15's "existing tasks keep
        their constraints" re-check, for every candidate at once)."""
        C = len(candidate_pus)
        A = len(active)
        comp = self.graph.compiled()
        if self._noisy() or C == 0:
            new_f = np.array([self.factor(task, p, list(active))
                              for p in candidate_pus])
            act_f = np.empty((C, A))
            for c, p in enumerate(candidate_pus):
                pool = list(active) + [(task, p)]
                for a, (t, q) in enumerate(active):
                    act_f[c, a] = self.factor(t, q, pool)
            return new_f, act_f
        Pc = np.fromiter((comp.pu_index[p] for p in candidate_pus),
                         dtype=np.int64, count=C)
        Pa, Ua, Ma, uid_a = self._pool_arrays(comp, active)
        return self.factors_with_candidates_idx(comp, task, Pc,
                                                Pa, Ua, Ma, uid_a)

    def factors_with_candidates_idx(
            self, comp, task: Task, Pc: np.ndarray, Pa: np.ndarray,
            Ua: np.ndarray, Ma: np.ndarray, uid_a: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Array-native core of :meth:`factors_with_candidates`.

        Candidates arrive as compiled PU indices and the active set as
        struct-of-arrays ledger columns (PU index, pu-usage, capped
        mem-usage, uid), so the Orchestrator's batched constraint checks
        feed the ledger straight in without building object tuples.
        Noise-free path only — callers with a noisy model use the tuple
        entry point, which preserves the scalar rng stream."""
        C = len(Pc)
        A = len(Pa)
        beta_vec, mt_vec = self._tables(comp)
        kappa = self.params.superlinear
        R = len(comp.rclass_names)
        u_new = task.usage.get("pu", 1.0)
        mem_new = task.usage.get("mem", 1.0)
        Mc = np.minimum(mem_new, comp.mem_cap[Pc])
        if A == 0:
            return np.ones(C), np.ones((C, 0))
        # co-runners sharing the placed task's uid never interact with it
        # (the scalar path skips them); mask them out of its pressures and
        # never add its contribution to theirs
        live = uid_a != task.uid

        # --- the new task's factor under each candidate -------------------
        same_ca = (Pc[:, None] == Pa[None, :]) & live[None, :]     # (C, A)
        mt_c = same_ca.astype(np.float64) @ Ua
        r_ca = comp.ncr_rclass[Pc[:, None], Pa[None, :]]
        valid_ca = live[None, :] & (Pc[:, None] != Pa[None, :]) & (r_ca >= 0)
        Xc = np.zeros((C, R))
        ci, ai = np.nonzero(valid_ca)
        np.add.at(Xc, (ci, r_ca[ci, ai]), Ma[ai])
        mt_term_c = _pterm_arr(mt_vec[Pc], mt_c, kappa) * u_new
        new_f = _aggregate(Xc, beta_vec, Mc, mt_term_c, kappa)

        # --- each active's factor if the task joins on candidate c --------
        diff_aa = uid_a[:, None] != uid_a[None, :]
        same_aa = (Pa[:, None] == Pa[None, :]) & diff_aa
        mt_base = same_aa.astype(np.float64) @ Ua                  # (A,)
        r_aa = comp.ncr_rclass[Pa[:, None], Pa[None, :]]
        valid_aa = diff_aa & (Pa[:, None] != Pa[None, :]) & (r_aa >= 0)
        Xa = np.zeros((A, R))
        i2, j2 = np.nonzero(valid_aa)
        np.add.at(Xa, (i2, r_aa[i2, j2]), Ma[j2])
        join_same = (Pa[None, :] == Pc[:, None]) & live[None, :]   # (C, A)
        mt_ca = mt_base[None, :] + np.where(join_same, u_new, 0.0)
        r_ac = comp.ncr_rclass[Pa[None, :], Pc[:, None]]           # (C, A)
        join_cross = live[None, :] & (Pa[None, :] != Pc[:, None]) & (r_ac >= 0)
        X_full = np.repeat(Xa[None, :, :], C, axis=0)              # (C, A, R)
        c3, a3 = np.nonzero(join_cross)
        X_full[c3, a3, r_ac[c3, a3]] += Mc[c3]
        mt_term_a = _pterm_arr(np.broadcast_to(mt_vec[Pa][None, :], (C, A)),
                               mt_ca, kappa) * Ua[None, :]
        act_f = _aggregate(X_full.reshape(C * A, R), beta_vec,
                           np.tile(Ma, C), mt_term_a.reshape(C * A),
                           kappa).reshape(C, A)
        return new_f, act_f

    def factors_same_device(
            self, comp, task: Task, Pc: np.ndarray, Dc: np.ndarray,
            Pa: np.ndarray, Ua: np.ndarray, Ma: np.ndarray,
            uid_a: np.ndarray, Da: np.ndarray, astart: np.ndarray,
            na: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Block-diagonal constraint-check kernel over *many devices* at once.

        Compute paths never cross device boundaries, so PUs on different
        devices share no resources and a candidate only interacts with the
        actives of its own device.  One call scores every candidate of an
        arbitrary mixed-device set against a device-sorted active ledger
        (``Da`` ascending, ``astart``/``na`` the per-device-ordinal segment
        offsets/lengths), materializing only the same-device
        (candidate, active) pairs instead of a dense C x A block.

        Returns ``(new_f, ci, ai, act_pf)``: the newcomer's factor per
        candidate, and flat same-device pair arrays where ``act_pf[k]`` is
        the updated factor of active ``ai[k]`` if the task joins candidate
        ``ci[k]`` (the Alg. 1 l.15 inputs).  Noise-free path only.

        Structured as a pure row builder (:meth:`_same_device_rows`) plus
        one aggregation, so :meth:`factors_same_device_multi` can stack
        the rows of every distinct task signature in a mapping wave and
        aggregate the whole frontier in a single kernel call.
        """
        empty = np.zeros(0, dtype=np.int64)
        if len(Pc) == 0 or len(Pa) == 0:
            return np.ones(len(Pc)), empty, empty, np.ones(0)
        key, base = self._canon_key(comp, task, Pc, Dc, Pa, Ua, Ma, uid_a,
                                    astart, na)
        if key is not None:
            hit = self._canon_lookup(comp, key, base)
            if hit is not None:
                return hit
        rows = self._same_device_rows(comp, task, Pc, Dc, Pa, Ua, Ma,
                                      uid_a, Da, astart, na)
        if rows is None:
            # no active shares a device with any candidate: all factors 1
            out = (np.ones(len(Pc)), empty, empty, np.ones(0))
        else:
            X, mem, mt_term, ci, ai = rows
            beta_vec, _ = self._tables(comp)
            C = len(Pc)
            f = _aggregate(X, beta_vec, mem, mt_term,
                           self.params.superlinear)
            out = (f[:C], ci, ai, f[C:])
        if key is not None:
            self._canon_store(key, base, out)
        return out

    def factors_same_device_multi(self, comp, items: Sequence[tuple]):
        """Score many newcomers (one per distinct wave signature) in one
        aggregation call.  ``items`` holds the positional argument tuples
        of :meth:`factors_same_device`; the result list holds that
        method's return tuple per item, bit-for-bit identical to calling
        it per item (the kernel is elementwise per row, so stacking and
        splitting is exact)."""
        empty = np.zeros(0, dtype=np.int64)
        built: list = []
        blocks: list = []
        keys: list = []
        for it in items:
            if len(it[1]) == 0 or len(it[3]) == 0:
                built.append(None)
                keys.append(None)
                continue
            key, base = self._canon_key(comp, it[0], it[1], it[2], it[3],
                                        it[4], it[5], it[6], it[8], it[9])
            if key is not None:
                hit = self._canon_lookup(comp, key, base)
                if hit is not None:
                    built.append(hit)
                    keys.append(None)       # already cached
                    continue
            keys.append((key, base))
            rows = self._same_device_rows(comp, *it)
            built.append(rows)
            if rows is not None:
                blocks.append(rows)
        if blocks:
            beta_vec, _ = self._tables(comp)
            f = _aggregate(np.concatenate([b[0] for b in blocks]),
                           beta_vec,
                           np.concatenate([b[1] for b in blocks]),
                           np.concatenate([b[2] for b in blocks]),
                           self.params.superlinear)
        pos = 0
        out = []
        for it, rows, kb in zip(items, built, keys):
            C = len(it[1])
            if isinstance(rows, tuple) and len(rows) == 4:
                out.append(rows)            # cache hit, already final
                continue
            if rows is None:
                res = (np.ones(C), empty, empty, np.ones(0))
            else:
                k = len(rows[1])
                fi = f[pos:pos + k]
                pos += k
                res = (fi[:C], rows[3], rows[4], fi[C:])
            if kb is not None and kb[0] is not None:
                self._canon_store(kb[0], kb[1], res)
            out.append(res)
        return out

    # -- canonical-pattern cache (single-device constraint checks) ---------
    def _canon_key(self, comp, task: Task, Pc, Dc, Pa, Ua, Ma, uid_a,
                   astart, na):
        """Structural cache key of one single-device constraint check.

        Two checks share a key iff every input the kernel math reads is
        identical *up to PU identity*: the candidate/active PU-equality
        pattern, the nearest-common-resource classes over all pairs, the
        per-PU model coefficients and caps, the active usage columns (in
        ledger order — order matters because the pressure reductions
        accumulate in it), the alive-pair mask against the newcomer's uid,
        and the newcomer's own usages.  Replicated mult=N fleets then
        share one kernel evaluation per structural pattern instead of one
        per device.  Returns ``(key, active_base)`` — pair indices are
        cached relative to the device's ledger segment and rebased on hit
        — or ``(None, 0)`` when the candidates span devices (the rare
        mixed case keeps the direct path)."""
        d0 = int(Dc[0])
        if not bool((Dc == d0).all()):
            return None, 0
        s = int(astart[d0])
        n_dev = int(na[d0])
        sel = slice(s, s + n_dev)
        L = np.concatenate([Pc, Pa[sel]])
        # equality pattern of L (np.unique(return_inverse) without its
        # dispatch overhead: these are ~a-device's-worth of ints)
        su = np.sort(L)
        uniq = su[np.concatenate(([True], su[1:] != su[:-1]))]
        inv = np.searchsorted(uniq, L)
        live = uid_a[sel] != task.uid
        _, mt_vec = self._tables(comp)
        key = (len(Pc), n_dev,
               task.usage.get("pu", 1.0), task.usage.get("mem", 1.0),
               inv.tobytes(),
               comp.ncr_rclass[L[:, None], L[None, :]].tobytes(),
               mt_vec[L].tobytes(), comp.mem_cap[L].tobytes(),
               Ua[sel].tobytes(), Ma[sel].tobytes(), live.tobytes())
        return key, s

    def _canon_cache_dict(self, comp) -> dict:
        cached = self._canon_cache
        if cached is not None and cached[0] is not comp \
                and self._factor_kin(cached[0], comp):
            # bandwidth-only delta clone: the canonical keys hash every
            # value the kernel math reads, none of which changed — keep
            # the warm factors instead of recomputing the whole fleet
            cached = (comp, cached[1])
            self._canon_cache = cached
        if cached is None or cached[0] is not comp:
            cached = (comp, {})
            self._canon_cache = cached
        return cached[1]

    def _canon_lookup(self, comp, key, base):
        hit = self._canon_cache_dict(comp).get(key)
        if hit is None:
            return None
        with self._counter_lock:
            self.factor_cache_hits += 1
        new_f, ci, rel_ai, act_pf = hit
        return new_f, ci, rel_ai + base, act_pf

    def _canon_store(self, key, base, result) -> None:
        # _canon_lookup always ran first, so the per-snapshot dict exists
        cache = self._canon_cache[1]
        with self._counter_lock:
            self.factor_cache_misses += 1
        if len(cache) > 100_000:            # runaway-key backstop
            cache.clear()
        new_f, ci, ai, act_pf = result
        cache[key] = (new_f, ci, ai - base, act_pf)

    def _same_device_rows(self, comp, task: Task, Pc, Dc, Pa, Ua, Ma,
                          uid_a, Da, astart, na):
        """Aggregation inputs of one newcomer's same-device constraint
        check: ``(X, mem, mt_term, ci, ai)`` with the candidate rows
        first and the (candidate, active) pair rows after, or ``None``
        when no active shares a device with any candidate."""
        C = len(Pc)
        A = len(Pa)
        _, mt_vec = self._tables(comp)
        kappa = self.params.superlinear
        R = len(comp.rclass_names)
        u_new = task.usage.get("pu", 1.0)
        mem_new = task.usage.get("mem", 1.0)
        Mc = np.minimum(mem_new, comp.mem_cap[Pc])
        empty = np.zeros(0, dtype=np.int64)

        def segment_pairs(left_ids, left_dev):
            """(li, ri): cross product of each left element with the active
            rows of its device (actives contiguous per device ordinal)."""
            rep = na[left_dev]
            K = int(rep.sum())
            if K == 0:
                return empty, empty
            li = np.repeat(left_ids, rep)
            within = np.arange(K) - np.repeat(np.cumsum(rep) - rep, rep)
            ri = np.repeat(astart[left_dev], rep) + within
            return li, ri

        # --- the new task's factor per candidate --------------------------
        ci, ai = segment_pairs(np.arange(C), Dc)
        if not len(ci):
            return None
        live = uid_a[ai] != task.uid
        Pci, Pai = Pc[ci], Pa[ai]
        same = (Pci == Pai) & live
        r_ca = np.asarray(comp.ncr_rclass[Pci, Pai], dtype=np.int64)
        validc = live & (Pci != Pai) & (r_ca >= 0)
        Xc = np.zeros((C, R))
        np.add.at(Xc, (ci[validc], r_ca[validc]), Ma[ai[validc]])
        mt_c = np.zeros(C)
        np.add.at(mt_c, ci[same], Ua[ai[same]])
        mt_term_c = _pterm_arr(mt_vec[Pc], mt_c, kappa) * u_new

        # --- each same-device active's factor if the task joins -----------
        # base pressures only for actives on candidate devices: the rest
        # never appear in a (candidate, active) pair
        d0 = int(Dc[0])
        if bool((Dc == d0).all()):           # single-device candidate set
            act_sel = np.arange(astart[d0], astart[d0] + na[d0])
        else:
            act_sel = np.nonzero(np.isin(Da, np.unique(Dc)))[0]
        a1, a2 = segment_pairs(act_sel, Da[act_sel])
        diff = uid_a[a1] != uid_a[a2]
        sameP = (Pa[a1] == Pa[a2]) & diff
        r_aa = np.asarray(comp.ncr_rclass[Pa[a1], Pa[a2]], dtype=np.int64)
        valida = diff & (Pa[a1] != Pa[a2]) & (r_aa >= 0)
        Xa = np.zeros((A, R))
        np.add.at(Xa, (a1[valida], r_aa[valida]), Ma[a2[valida]])
        mt_base = np.zeros(A)
        np.add.at(mt_base, a1[sameP], Ua[a2[sameP]])
        Xp = Xa[ai]                            # (K, R): base + join term
        r_ac = np.asarray(comp.ncr_rclass[Pai, Pci], dtype=np.int64)
        jc = live & (Pai != Pci) & (r_ac >= 0)
        kk = np.nonzero(jc)[0]
        Xp[kk, r_ac[kk]] += Mc[ci[kk]]
        mt_p = mt_base[ai] + np.where(same, u_new, 0.0)
        mt_term_p = _pterm_arr(mt_vec[Pai], mt_p, kappa) * Ua[ai]
        # stacked (candidate; pair) rows — the aggregation kernel is
        # elementwise per row, so callers split the result back exactly
        return (np.concatenate([Xc, Xp]),
                np.concatenate([Mc, Ma[ai]]),
                np.concatenate([mt_term_c, mt_term_p]), ci, ai)


class NoSlowdown:
    """Contention-blind model (what ACE-like baselines assume)."""

    factor_cache_hits = 0
    factor_cache_misses = 0

    def __init__(self, graph: HWGraph, *a, **k) -> None:
        self.graph = graph

    def factor(self, task: Task, pu_name: str,
               coruns: list[tuple[Task, str]]) -> float:
        return 1.0

    def factor_batch(self, pool) -> np.ndarray:
        return np.ones(len(pool))

    def factor_batch_idx(self, P, U, mem, uid) -> np.ndarray:
        return np.ones(len(P))

    def slowdown_matrix(self, pool) -> np.ndarray:
        return np.ones((len(pool), len(pool)))

    def factors_with_candidates(self, task, candidate_pus, active):
        return np.ones(len(candidate_pus)), np.ones((len(candidate_pus),
                                                     len(active)))

    def factors_with_candidates_idx(self, comp, task, Pc, Pa, Ua, Ma, uid_a):
        return np.ones(len(Pc)), np.ones((len(Pc), len(Pa)))

    def factors_same_device(self, comp, task, Pc, Dc, Pa, Ua, Ma, uid_a,
                            Da, astart, na):
        e = np.zeros(0, dtype=np.int64)
        return np.ones(len(Pc)), e, e, np.ones(0)

    def factors_same_device_multi(self, comp, items):
        return [self.factors_same_device(comp, *it) for it in items]

    def invalidate(self) -> None:
        pass
