"""Decoupled shared-resource slowdown models (paper §3.4).

The paper's accuracy insight: *decouple* standalone performance from the
slowdown caused by shared-resource use.  Once per system, each shareable
resource is characterized for the slowdown it induces per amount of
concurrent use; each task is characterized by its generalized usage of each
resource; at runtime ``slowdown()`` combines the two.

Two contention mechanisms (paper §2.2, Fig. 2):

* **Shared-memory contention across PUs** — discovered via the HW-GRAPH:
  the *nearest common resource* on the two PUs' compute paths is the
  contention point (e.g. two cores in one cluster meet at L2; cores in
  different clusters meet at L3; GPU and DLA meet at DRAM).  Using the
  nearest common point (rather than every shared node) reflects that an
  upstream shared cache merges/filters traffic before it reaches deeper
  levels, and is what reproduces the paper's Fig. 2 ordering
  (L2 0.91x > L3 0.87x).

* **Multi-tenancy on one PU** — co-tenant tasks on the same PU slow each
  other down by a PU-class-specific factor (GPU 0.66x for 2 DNNs, etc.).

Calibration below reproduces the paper's Orin AGX measurements:
  same-cluster CPU MMs (L2)          -> 0.91x   => beta_l2  = 0.099
  cross-cluster CPU MMs (L3)         -> 0.87x   => beta_l3  = 0.149
  2 DNNs on one GPU (multi-tenancy)  -> 0.66x   => mt_gpu   = 0.515
  GPU + DLA via shared DRAM          -> 0.68x   => beta_dram= 0.47
  CPU + GPU via shared 4MB LLC       -> 0.89x   => beta_llc = 0.124

The ground-truth simulator uses the same structure with a superlinear term
and task-kind-specific irregular-access noise (``truth_params``), so that the
H-EYE predictor (linear, noise-free) exhibits a small but honest error while
contention-blind baselines (ACE-like) err by the full contention amount.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .hwgraph import HWGraph, ProcessingUnit
from .task import Task

# resource classes a STORAGE/CONTROLLER node may declare in attrs["rclass"]
RCLASSES = ("l2", "l3", "llc", "sram", "dram", "hbm", "vmem", "nic")


@dataclass
class SlowdownParams:
    # sensitivity of each resource class to one unit of co-runner pressure,
    # normalized so that beta * 1.12 reproduces Fig. 2 at x=1 co-runner
    # (the 1.12 = 1 + superlinear accounts for the profiled curvature)
    beta: dict[str, float] = field(default_factory=lambda: {
        "l2": 0.0884, "l3": 0.1330, "llc": 0.1107, "sram": 0.1786,
        "dram": 0.4196, "hbm": 0.2679, "vmem": 0.0, "nic": 0.0893,
    })
    # multi-tenancy sensitivity per PU class
    mt_beta: dict[str, float] = field(default_factory=lambda: {
        "cpu": 0.3125, "gpu": 0.4598, "dla": 0.3571, "vic": 0.2232,
        "pva": 0.2679, "tpu": 0.4018, "default": 0.3571,
    })
    superlinear: float = 0.12   # kappa: factor term beta*x*(1+kappa*x)
    noise: float = 0.0          # rel. sigma of task-irregularity noise (truth only)

    def mt(self, pu_class: str) -> float:
        return self.mt_beta.get(pu_class, self.mt_beta["default"])


def heye_params() -> SlowdownParams:
    """The calibrated model H-EYE's Traverser uses for prediction.

    The paper's step (1) profiles each shared resource "for the slowdown
    they will experience per the amount of concurrent use" — i.e. the
    calibration covers every concurrency level, so the predictor carries
    the same superlinear shape as the system it was profiled on.  What it
    can NOT know is the per-execution irregular-access noise (§5.2 names
    exactly this as the source of H-EYE's residual 3.2% error)."""
    return SlowdownParams(superlinear=0.12)


def truth_params(noise: float = 0.035, superlinear: float = 0.12) -> SlowdownParams:
    """Ground-truth behaviour: profiled contention + irregular-access noise.

    These produce the paper-reported gap: H-EYE predicts within a few
    percent (missing only the noise) while a contention-blind model misses
    the entire slowdown (tens of percent under heavy sharing)."""
    return SlowdownParams(superlinear=superlinear, noise=noise)


class DecoupledSlowdown:
    """slowdown(task on pu | co-running tasks) -> multiplicative factor >= 1."""

    def __init__(self, graph: HWGraph, params: Optional[SlowdownParams] = None,
                 rng: Optional[np.random.Generator] = None) -> None:
        self.graph = graph
        self.params = params or heye_params()
        self.rng = rng
        self._shared_cache: dict[tuple[str, str], Optional[str]] = {}

    # -- helpers -----------------------------------------------------------
    def nearest_shared(self, pu_a: str, pu_b: str) -> Optional[str]:
        """Nearest common resource on the compute paths of two PUs (or None
        if the PUs share nothing, e.g. they sit in different devices)."""
        key = (pu_a, pu_b) if pu_a <= pu_b else (pu_b, pu_a)
        if key not in self._shared_cache:
            a = self.graph.nodes[pu_a]
            pa = (a.get_compute_path() if isinstance(a, ProcessingUnit)
                  else self.graph.resource_path(pu_a))
            b = self.graph.nodes[pu_b]
            pb = set(b.get_compute_path() if isinstance(b, ProcessingUnit)
                     else self.graph.resource_path(pu_b))
            hit = next((r for r in pa if r in pb), None)
            self._shared_cache[key] = hit
        return self._shared_cache[key]

    def invalidate(self) -> None:
        self._shared_cache.clear()

    def _pressure_term(self, beta: float, x: float) -> float:
        if x <= 0.0 or beta <= 0.0:
            return 0.0
        return beta * x * (1.0 + self.params.superlinear * x)

    def _mem_usage(self, task: Task, pu_name: str) -> float:
        """Effective shared-memory pressure of ``task`` when run on ``pu``.
        PUs with private data storage (e.g. VIC, §5.3.1) cap it."""
        u = task.usage.get("mem", 1.0)
        cap = self.graph.nodes[pu_name].attrs.get("mem_usage_cap")
        return min(u, cap) if cap is not None else u

    # -- the model ---------------------------------------------------------
    def factor(self, task: Task, pu_name: str,
               coruns: list[tuple[Task, str]]) -> float:
        """Multiplicative slowdown of ``task`` running on ``pu_name`` while
        each (other_task, other_pu) in ``coruns`` runs concurrently."""
        p = self.params
        f = 1.0
        pu = self.graph.nodes[pu_name]
        pu_class = pu.attrs.get("pu_class_kind", pu.attrs.get("pu_class", "default"))
        # split co-runners: same-PU tenants vs other-PU resource sharers
        mt_pressure = 0.0
        res_pressure: dict[str, float] = {}
        for other, other_pu in coruns:
            if other.uid == task.uid:
                continue
            if other_pu == pu_name:
                mt_pressure += other.usage.get("pu", 1.0)
            else:
                shared = self.nearest_shared(pu_name, other_pu)
                if shared is None:
                    continue
                rclass = self.graph.nodes[shared].attrs.get("rclass", "dram")
                res_pressure[rclass] = (res_pressure.get(rclass, 0.0)
                                        + self._mem_usage(other, other_pu))
        if mt_pressure > 0.0:
            f *= 1.0 + self._pressure_term(p.mt(pu_class), mt_pressure
                                           ) * task.usage.get("pu", 1.0)
        for rclass, x in res_pressure.items():
            f *= 1.0 + self._pressure_term(p.beta.get(rclass, 0.3), x
                                           ) * self._mem_usage(task, pu_name)
        if p.noise > 0.0 and self.rng is not None and f > 1.0:
            irregularity = task.attrs.get("irregularity", 1.0)
            f *= float(np.exp(self.rng.normal(0.0, p.noise * irregularity)))
        return max(1.0, f)


class NoSlowdown:
    """Contention-blind model (what ACE-like baselines assume)."""

    def __init__(self, graph: HWGraph, *a, **k) -> None:
        self.graph = graph

    def factor(self, task: Task, pu_name: str,
               coruns: list[tuple[Task, str]]) -> float:
        return 1.0

    def invalidate(self) -> None:
        pass
