"""Online serving continuum: co-simulated mapping + execution (ROADMAP 1).

The batch reproduction runs H-EYE's two halves as offline passes: a
``SchedulerSession`` maps everything, then a fresh ``TimelineEngine``
executes the frozen mapping.  The paper's orchestrator, however, is
pitched for *live* edge-cloud continua — tasks arrive continuously and
must be mapped against resources whose load changes under them (the
dynamicity / QoS / lifecycle axes of the orchestration surveys in
PAPERS.md).

``ServeLoop`` closes that gap on the **session-resident timeline**
(``SchedulerSession.open_timeline``).  Each admission wave:

1. advances the live DES to just *before* the arrival instant (so
   releases enter the event heap ahead of the clock — arrival-coincident
   completions then drain in the same order the one-shot engine would
   use, which is what keeps online == offline at 1e-9 when every request
   is admitted);
2. reconciles the orchestrator's belief ledger with *actual* completions
   from ``drain_finished`` (``ActiveLedger.retire``);
3. maps the wave through the session — ``Orchestrator.map_batch``
   feasibility against current occupancy, Fig. 14 overhead charging;
4. runs the admission controller (accept / reject / defer per tenant
   against SLA deadlines, ``serve/admission.py``); rejected work is
   withdrawn (ledger + overhead reverted), accepted work is injected
   into the running job tables.

Traffic comes from **open-loop arrival processes** — seeded Poisson and
diurnal (raised-cosine) rate curves, drawn in vectorized batches so
millions-of-users request rates cost one rng call per few thousand
arrivals, and deterministic per seed so serving runs replay exactly.

``ServeStats`` reports the serving-side metrics the paper's mean-latency
figures omit: p50/p99/p999 request latency, per-tenant SLA attainment,
offered/served request rates, and rejected/deferred counts.  The
percentile definitions are shared with the offline ``RunStats``
(``session.percentiles``).  See ``docs/serving.md``.
"""
from __future__ import annotations

import heapq
import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence, Union

import numpy as np

from ..serve.admission import (AdaptiveWindow, AdmissionController, Decision,
                               Verdict)
from .hwgraph import HWGraph
from .orchestrator import Orchestrator
from .session import Policy, SchedulerSession, percentiles
from .task import Task, TaskGraph
from .traverser import Traverser


# ---------------------------------------------------------------------------
# open-loop arrival processes (seeded, deterministic, batched draws)
# ---------------------------------------------------------------------------
class PoissonArrivals:
    """Homogeneous Poisson stream at ``rate`` requests/second.

    Deterministic per ``(rate, seed)``: every ``times`` call re-seeds a
    fresh generator, so two loops over the same spec see byte-identical
    streams.  Inter-arrival gaps are drawn in vectorized blocks of
    ``batch`` (one ``rng.exponential`` + cumsum per block), so
    fleet-scale rates cost microseconds per thousand arrivals instead of
    a Python loop per request.
    """

    def __init__(self, rate: float, seed: int = 0,
                 batch: int = 4096) -> None:
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        self.rate = float(rate)
        self.seed = int(seed)
        self.batch = int(batch)

    def times(self, horizon: float) -> np.ndarray:
        """All arrival instants in ``[0, horizon)``, sorted ascending."""
        rng = np.random.default_rng(self.seed)
        out = []
        t = 0.0
        while t < horizon:
            ts = t + np.cumsum(rng.exponential(1.0 / self.rate, self.batch))
            out.append(ts)
            t = float(ts[-1])
        arr = np.concatenate(out)
        return arr[arr < horizon]


class DiurnalArrivals:
    """Nonhomogeneous Poisson with a raised-cosine diurnal rate curve.

    ``rate(t) = base + (peak - base) * 0.5 * (1 - cos(2 pi (t/period +
    phase)))`` — the load trough sits at ``t = -phase * period`` and the
    peak half a period later.  Sampled by thinning against the peak rate
    (Lewis & Shedler), in the same vectorized blocks as
    :class:`PoissonArrivals`, and equally deterministic per seed.
    """

    def __init__(self, base_rate: float, peak_rate: float,
                 period: float = 86_400.0, seed: int = 0,
                 phase: float = 0.0, batch: int = 4096) -> None:
        if not 0 < base_rate <= peak_rate:
            raise ValueError(
                f"need 0 < base_rate <= peak_rate, got {base_rate}, "
                f"{peak_rate}")
        self.base_rate = float(base_rate)
        self.peak_rate = float(peak_rate)
        self.period = float(period)
        self.seed = int(seed)
        self.phase = float(phase)
        self.batch = int(batch)

    def rate(self, t) -> np.ndarray:
        swing = 0.5 * (1.0 - np.cos(2.0 * np.pi
                                    * (np.asarray(t) / self.period
                                       + self.phase)))
        return self.base_rate + (self.peak_rate - self.base_rate) * swing

    def times(self, horizon: float) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        kept = []
        t = 0.0
        while t < horizon:
            gaps = rng.exponential(1.0 / self.peak_rate, self.batch)
            cand = t + np.cumsum(gaps)
            u = rng.random(self.batch)          # one thinning draw per
            keep = u < self.rate(cand) / self.peak_rate    # candidate
            kept.append(cand[keep])
            t = float(cand[-1])
        arr = np.concatenate(kept)
        return arr[arr < horizon]


class ClosedLoopClients:
    """Closed-loop population of ``clients`` users (ROADMAP 1's
    closed-loop depth): each client issues one request, waits for its
    completion (or terminal rejection), *thinks* for an exponential
    ``think_mean`` interval, then issues the next — so offered load
    self-regulates with system latency instead of piling up open-loop.

    Deterministic per ``(clients, think_mean, seed)``: every client owns
    its own ``default_rng([seed, k])`` substream, consumed in that
    client's request order (which a seeded serving run fixes), and
    :meth:`initial_arrivals` re-seeds all substreams — two loops over the
    same spec replay byte-identically.
    """

    def __init__(self, clients: int, think_mean: float,
                 seed: int = 0) -> None:
        if clients <= 0:
            raise ValueError(f"clients must be positive, got {clients}")
        if think_mean <= 0:
            raise ValueError(
                f"think_mean must be positive, got {think_mean}")
        self.clients = int(clients)
        self.think_mean = float(think_mean)
        self.seed = int(seed)
        self._rngs: list = []

    def initial_arrivals(self, horizon: float) -> list[tuple[float, int]]:
        """``(t, client)`` first-request instants in ``[0, horizon)``, at
        most one per client (an initial think delay desynchronizes the
        population).  Resets every client substream."""
        self._rngs = [np.random.default_rng([self.seed, k])
                      for k in range(self.clients)]
        out = []
        for k, rng in enumerate(self._rngs):
            t = float(rng.exponential(self.think_mean))
            if t < horizon:
                out.append((t, k))
        return out

    def think(self, client: int) -> float:
        """Next think-time draw from ``client``'s substream."""
        return float(self._rngs[client].exponential(self.think_mean))


ArrivalProcess = Union[PoissonArrivals, DiurnalArrivals, ClosedLoopClients]


# ---------------------------------------------------------------------------
# tenants and requests
# ---------------------------------------------------------------------------
@dataclass
class TenantSpec:
    """One tenant's traffic contract.

    ``make_request(rid, t)`` builds the request's TaskGraph with release
    times at ``t`` (tasks inherit ``attrs["tenant"]``/``["request"]``
    stamps from the loop).  ``sla`` is informational default plumbing:
    per-task deadlines on the built tasks are what admission checks.
    """

    name: str
    arrivals: ArrivalProcess
    make_request: Callable[[int, float], TaskGraph]
    sla: Optional[float] = None
    max_inflight: Optional[int] = None


def single_task_request(kind: str, origin: str,
                        sla: Optional[float] = None,
                        **task_kw: Any) -> Callable[[int, float], TaskGraph]:
    """Factory for one-task requests (the mining-reading shape): returns
    a ``make_request`` callable for :class:`TenantSpec`."""
    from .topology import make_task

    def make(rid: int, t: float) -> TaskGraph:
        g = TaskGraph(f"{kind}#{rid}")
        g.add(make_task(kind, origin=origin, deadline=sla,
                        release_time=t, **task_kw))
        return g

    return make


@dataclass
class ServeRequest:
    """One request's lifecycle record."""

    tenant: str
    rid: int
    arrival: float                 # first arrival (defer wait counts
    graph: TaskGraph               # toward latency)
    tasks: list[Task]
    sla: Optional[float] = None
    max_inflight: Optional[int] = None
    defers: int = 0
    verdict: str = "pending"       # pending | accepted | rejected
    reject_reason: str = ""
    remaining: int = 0             # unfinished tasks (accepted requests)
    finish: float = float("nan")
    client: int = -1               # closed-loop client ordinal (-1: open)

    @property
    def latency(self) -> float:
        """Arrival-to-last-task-finish (nan until complete)."""
        return self.finish - self.arrival

    def met_sla(self) -> bool:
        if self.sla is None:
            return True
        return self.latency <= self.sla * (1 + 1e-9)


# ---------------------------------------------------------------------------
# the serving report
# ---------------------------------------------------------------------------
@dataclass
class ServeStats:
    """Tail-latency serving report (simulated-time rates + wall-clock)."""

    requests: list[ServeRequest]
    horizon: float
    wall_s: float
    n_events: int = 0
    mapped_tasks: int = 0
    engine_opens: int = 0          # full TimelineEngine builds (target: 1)
    deferrals: int = 0
    # wall seconds per loop phase (advance / sync / map / admit) and the
    # admission-wave sizes, in wave order — where the serving wall went
    phase_wall: dict[str, float] = field(default_factory=dict)
    wave_sizes: list[int] = field(default_factory=list)

    def wave_size_hist(self) -> dict[int, int]:
        """Histogram of admission-wave sizes (size -> wave count)."""
        out: dict[int, int] = {}
        for n in self.wave_sizes:
            out[n] = out.get(n, 0) + 1
        return out

    # -- request partitions -------------------------------------------------
    @property
    def accepted(self) -> list[ServeRequest]:
        return [r for r in self.requests if r.verdict == "accepted"]

    @property
    def rejected(self) -> list[ServeRequest]:
        return [r for r in self.requests if r.verdict == "rejected"]

    def reject_reasons(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.rejected:
            out[r.reject_reason] = out.get(r.reject_reason, 0) + 1
        return out

    # -- latency tails ------------------------------------------------------
    def latencies(self) -> list[float]:
        return [r.latency for r in self.accepted if r.finish == r.finish]

    def latency_percentiles(self, qs: Sequence[float] = (50.0, 99.0, 99.9),
                            ) -> dict[float, float]:
        return percentiles(self.latencies(), qs)

    def latency_percentiles_by_tenant(
            self, qs: Sequence[float] = (50.0, 99.0, 99.9),
            ) -> dict[str, dict[float, float]]:
        by: dict[str, list[float]] = {}
        for r in self.accepted:
            if r.finish == r.finish:
                by.setdefault(r.tenant, []).append(r.latency)
        return {ten: percentiles(v, qs) for ten, v in by.items()}

    # -- SLA + rates --------------------------------------------------------
    def sla_attainment(self) -> dict[str, float]:
        """Per-tenant fraction of *offered* SLA-carrying requests that
        finished within SLA — a reject counts as a miss (refusing work
        must not launder the attainment number)."""
        tot: dict[str, int] = {}
        ok: dict[str, int] = {}
        for r in self.requests:
            if r.sla is None:
                continue
            tot[r.tenant] = tot.get(r.tenant, 0) + 1
            met = r.verdict == "accepted" and r.finish == r.finish \
                and r.met_sla()
            ok[r.tenant] = ok.get(r.tenant, 0) + (1 if met else 0)
        return {ten: ok[ten] / tot[ten] for ten in tot}

    @property
    def accept_rate(self) -> float:
        return len(self.accepted) / len(self.requests) if self.requests \
            else 1.0

    @property
    def offered_rps(self) -> float:
        """Offered load in simulated time."""
        return len(self.requests) / self.horizon if self.horizon else 0.0

    @property
    def served_rps(self) -> float:
        """Sustained accepted-and-completed request rate, simulated."""
        done = sum(1 for r in self.accepted if r.finish == r.finish)
        return done / self.horizon if self.horizon else 0.0

    @property
    def wall_rps(self) -> float:
        """Requests processed per wall-clock second — the co-simulation
        throughput the benchmark gates."""
        return len(self.requests) / self.wall_s if self.wall_s else 0.0

    def summary(self) -> dict[str, Any]:
        pct = self.latency_percentiles()
        att = self.sla_attainment()
        return {
            "requests": len(self.requests),
            "accepted": len(self.accepted),
            "rejected": len(self.rejected),
            "deferrals": self.deferrals,
            "mapped_tasks": self.mapped_tasks,
            "engine_opens": self.engine_opens,
            "n_events": self.n_events,
            "offered_rps": self.offered_rps,
            "served_rps": self.served_rps,
            "wall_rps": self.wall_rps,
            "p50_ms": pct[50.0] * 1e3,
            "p99_ms": pct[99.0] * 1e3,
            "p999_ms": pct[99.9] * 1e3,
            "sla_attainment": (min(att.values()) if att else 1.0),
            "sla_by_tenant": att,
            "reject_reasons": self.reject_reasons(),
        }


# ---------------------------------------------------------------------------
# the loop
# ---------------------------------------------------------------------------
class ServeLoop:
    """Drive open-loop traffic through online mapping + execution.

    One ``SchedulerSession`` with one resident ``TimelineEngine`` serves
    the whole run — ``stats.engine_opens == 1`` is the zero-rebuild
    guarantee the benchmark asserts.  ``batch_window > 0`` coalesces
    arrivals within that many seconds into one admission wave (larger
    map_batch calls, slightly staler occupancy at admission); an
    :class:`~..serve.admission.AdaptiveWindow` widens that window with
    queue depth / projected slowdown and collapses to per-arrival
    admission when idle.  Closed-loop tenants
    (:class:`ClosedLoopClients`) issue each client's next request on
    completion; open- and closed-loop tenants mix freely.
    """

    def __init__(self, graph: HWGraph, policy: Policy,
                 tenants: Sequence[TenantSpec],
                 truth: Optional[Traverser] = None,
                 admission: Optional[AdmissionController] = None,
                 horizon: float = 1.0,
                 charge_overhead: bool = True,
                 batch_window: Union[float, AdaptiveWindow] = 0.0,
                 interventions: Sequence[tuple[float, Callable[[], Any]]] = (),
                 ) -> None:
        self.tenants = list(tenants)
        self.admission = admission if admission is not None \
            else AdmissionController()
        self.horizon = float(horizon)
        self.batch_window = batch_window \
            if isinstance(batch_window, AdaptiveWindow) \
            else float(batch_window)
        self.session = SchedulerSession(graph, policy, truth=truth,
                                        charge_overhead=charge_overhead)
        self.engine = self.session.open_timeline(interventions)
        self.requests: list[ServeRequest] = []
        self.deferrals = 0
        self._inflight: dict[str, int] = {}
        self._by_uid: dict[int, ServeRequest] = {}   # pending task -> req
        self._events: list[tuple[float, int, int, Any]] = []
        self._rid_next: list[int] = []     # per-tenant arrival counters
        self._ti_of = {s.name: i for i, s in enumerate(self.tenants)}
        self._last_proj = 0.0              # last wave's worst proj/deadline
        self.phase_wall: dict[str, float] = {
            "advance": 0.0, "sync": 0.0, "map": 0.0, "admit": 0.0}
        self.wave_sizes: list[int] = []

    def _push_arrival(self, ti: int, t: float, client: int) -> None:
        """Mint the next rid for tenant ``ti`` and enqueue a kind-0
        arrival at ``t`` (closed-loop follow-ups reuse the same path as
        pre-generated open-loop arrivals)."""
        rid = self._rid_next[ti] * len(self.tenants) + ti
        self._rid_next[ti] += 1
        heapq.heappush(self._events, (t, 0, rid, (ti, client)))

    def _issue_next(self, req: ServeRequest, at: float) -> None:
        """Closed-loop continuation: ``req``'s client thinks, then issues
        its next request (dropped past the horizon)."""
        if req.client < 0:
            return
        ti = self._ti_of[req.tenant]
        t = at + self.tenants[ti].arrivals.think(req.client)
        if t < self.horizon:
            self._push_arrival(ti, t, req.client)

    # -- internals ----------------------------------------------------------
    def _sync_completions(self) -> None:
        """Reconcile the belief ledger with *actual* completions.  The
        ledger's own ``prune`` trusts estimated finishes; the resident
        timeline knows the truth — slow tasks keep occupying their PU
        beliefs past the estimate, fast ones free capacity early."""
        fin = self.engine.drain_finished()
        if not fin:
            return
        pol = self.session.policy
        if isinstance(pol, Orchestrator):
            pol.ledger.retire([t.uid for t in fin])
        for t in fin:
            req = self._by_uid.pop(t.uid, None)
            if req is None:
                continue
            req.remaining -= 1
            if req.remaining == 0:
                req.finish = max(self.engine.finish_of(x.uid)
                                 for x in req.tasks)
                self._inflight[req.tenant] -= 1
                self._issue_next(req, req.finish)

    def _refuse(self, req: ServeRequest, d: Decision, events: list,
                now: float) -> None:
        if d.verdict is Verdict.DEFER:
            req.defers += 1
            self.deferrals += 1
            for t in req.tasks:
                t.release_time = d.retry_at
            heapq.heappush(events, (d.retry_at, 1, req.rid, req))
        else:
            req.verdict = "rejected"
            req.reject_reason = d.reason
            # a terminal reject ends the closed-loop client's wait too —
            # it thinks, then tries again with a fresh request
            self._issue_next(req, now)

    def _admit_wave(self, now: float, wave: list[ServeRequest],
                    events: list) -> None:
        adm = self.admission
        live: list[ServeRequest] = []
        for req in wave:
            d = adm.pre_admit(req, now, self._inflight.get(req.tenant, 0))
            if d is None:
                live.append(req)
            else:
                self._refuse(req, d, events, now)
        if not live:
            return
        for req in live:
            self.session.submit(req.graph)
        w0 = _time.perf_counter()
        results = self.session.map_pending(fallback=False)
        self.phase_wall["map"] += _time.perf_counter() - w0
        proj = 0.0
        for req in live:
            rs = [results.get(t.uid) for t in req.tasks]
            d = adm.post_admit(req, rs, now)
            if d.verdict is Verdict.ACCEPT:
                req.verdict = "accepted"
                req.remaining = len(req.tasks)
                for t, r in zip(req.tasks, rs):
                    self._by_uid[t.uid] = req
                    if t.deadline:
                        proj = max(proj, r.prediction.total / t.deadline)
                self._inflight[req.tenant] = \
                    self._inflight.get(req.tenant, 0) + 1
                self.session.inject(req.tasks)
            else:
                for t in req.tasks:
                    self.session.withdraw(t)
                self._refuse(req, d, events, now)
        # the adaptive window's slowdown-pressure input: this wave's worst
        # projected completion / deadline ratio (0.0 when nothing carried
        # a deadline — depth pressure still applies)
        self._last_proj = proj

    # -- the run ------------------------------------------------------------
    def run(self) -> ServeStats:
        wall0 = _time.perf_counter()
        pw = self.phase_wall
        # event tuples: (t, kind, rid, payload) — kind 0 = fresh arrival
        # (payload: (tenant index, client)), kind 1 = deferred retry
        # (payload: the request).  (t, kind, rid) is unique per push, so
        # heap ordering never compares payloads.
        events = self._events
        self._rid_next = [0] * len(self.tenants)
        for ti, spec in enumerate(self.tenants):
            arr = spec.arrivals
            if hasattr(arr, "think"):          # closed-loop population
                first = arr.initial_arrivals(self.horizon)
                for k, (t, client) in enumerate(first):
                    events.append((t, 0, k * len(self.tenants) + ti,
                                   (ti, client)))
                self._rid_next[ti] = len(first)
            else:
                times = arr.times(self.horizon).tolist()
                for k, t in enumerate(times):
                    events.append((t, 0, k * len(self.tenants) + ti,
                                   (ti, -1)))
                self._rid_next[ti] = len(times)
        heapq.heapify(events)
        bw = self.batch_window
        adaptive = isinstance(bw, AdaptiveWindow)
        while True:
            target = (float(np.nextafter(events[0][0], -np.inf))
                      if events else np.inf)
            tn = self.engine.next_event_time()
            if tn <= target and tn != np.inf:
                # engine work due before the next admission instant:
                # drain that batch and reconcile — a completion may spawn
                # a closed-loop arrival ahead of the current heap head,
                # so re-read the target each step.  (When nothing is due,
                # the advance call — which would only park the clock —
                # is skipped entirely: the idle fast path.)
                w0 = _time.perf_counter()
                self.engine.advance(tn)
                w1 = _time.perf_counter()
                self._sync_completions()
                w2 = _time.perf_counter()
                pw["advance"] += w1 - w0
                pw["sync"] += w2 - w1
                continue
            if not events:
                break
            t0 = events[0][0]
            now = t0
            window = bw.window(sum(self._inflight.values()),
                               self._last_proj) if adaptive else bw
            wave: list[ServeRequest] = []
            while events and events[0][0] <= t0 + window:
                t, kind, rid, payload = heapq.heappop(events)
                now = t
                if kind == 0:
                    ti, client = payload
                    spec = self.tenants[ti]
                    g = spec.make_request(rid // len(self.tenants), t)
                    tasks = list(g)
                    for task in tasks:
                        task.attrs.setdefault("tenant", spec.name)
                        task.attrs["request"] = rid
                    req = ServeRequest(tenant=spec.name, rid=rid,
                                       arrival=t, graph=g, tasks=tasks,
                                       sla=spec.sla,
                                       max_inflight=spec.max_inflight,
                                       client=client)
                    self.requests.append(req)
                else:
                    req = payload
                wave.append(req)
            # admit at the arrival instant: every engine event strictly
            # before the wave's earliest arrival has drained above, so
            # injected releases enter the heap ahead of the clock — same
            # event order as a one-shot run (with a window, occupancy is
            # as of t0, slightly stale for the later arrivals it
            # coalesced)
            w0 = _time.perf_counter()
            self._sync_completions()
            w1 = _time.perf_counter()
            m0 = pw["map"]
            self._admit_wave(now, wave, events)
            w2 = _time.perf_counter()
            pw["sync"] += w1 - w0
            pw["admit"] += (w2 - w1) - (pw["map"] - m0)
            self.wave_sizes.append(len(wave))
        wall = _time.perf_counter() - wall0
        return ServeStats(requests=list(self.requests),
                          horizon=self.horizon, wall_s=wall,
                          n_events=self.engine.n_events,
                          mapped_tasks=self.engine.n,
                          engine_opens=self.session.engine_opens,
                          deferrals=self.deferrals,
                          phase_wall=dict(pw),
                          wave_sizes=list(self.wave_sizes))
