"""Workload generators: the paper's two applications as TaskGraphs.

Cloud-rendered VR (§4.1, Fig. 7): per frame, the serial CFG
  capture -> pose_pred -> render -> encode -> decode -> reproject -> display
with capture/display pinned to the edge device (they touch the camera and
panel) and the middle tasks free to run on any capable PU in the continuum.
Frames are generated at the device's target FPS; every task in a frame
carries the frame deadline (proportionally divided, as §5.3.2 describes).

Mining (§4.2, Fig. 8): each smart-sensor reading (10 Hz) spawns three
parallel ML tasks (SVM, KNN, MLP) that must all finish within 100 ms.

Wireless churn (§5.4.1 dynamic network conditions): a seeded schedule of
``Churn`` batches that degrades and recovers the edge devices' wireless
uplinks, for exercising the bandwidth-overlay delta path.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass
from typing import Optional

from .hwgraph import Churn
from .task import Task, TaskGraph
from .topology import EDGE_FPS, KB, MB, MS, Testbed, make_task

VR_TASKS = ("capture", "pose_pred", "render", "encode", "decode",
            "reproject", "display")
# data volumes between consecutive VR stages (producer -> consumer)
VR_BYTES = {"capture": 48 * KB,       # camera frame features -> pose_pred
            "pose_pred": 4 * KB,      # predicted pose -> render
            "render": 1.5 * MB,       # raw frame -> encode (server-local usually)
            "encode": 250 * KB,       # compressed frame -> decode (crosses WAN)
            "decode": 1.5 * MB,       # raw frame -> reproject
            "reproject": 1.5 * MB,    # final frame -> display
            "display": 0.0}
# tasks that must stay on the originating edge device (camera / pose / panel)
VR_PINNED = ("capture", "reproject", "display")
_COMM_EST = 2.6 * MS     # planner's estimate of one edge<->server round leg


def _vr_plan_shares(edge_kind: str) -> dict[str, float]:
    """Per-task deadline shares (paper §5.3.2: 'we set the deadline of each
    task by proportionally dividing the performance on the edge device over
    the QoS requirement').

    Shares come from the best end-to-end PLAN: a 2-state DP over stage
    placement (edge vs server) that charges every transfer leg between
    consecutive stages — so a stage whose optimal placement implies pulling
    data across the WAN gets that comm time inside its share, instead of
    silently forcing the Orchestrator into raw-frame round trips."""
    from .topology import _VR_EDGE, _VR_SERVER  # digitized Fig. 9 tables

    def stage_cost(kind: str, side: str) -> float:
        if side == "edge":
            return min(_VR_EDGE[kind][edge_kind].values()) * MS
        if kind in VR_PINNED or kind not in _VR_SERVER:
            return float("inf")
        return min(min(p.values()) for p in _VR_SERVER[kind].values()) * MS

    def trans(prev_kind: str, a: str, b: str) -> float:
        return 0.0 if a == b else _COMM_EST * max(
            0.5, VR_BYTES[prev_kind] / (250 * KB))

    # DP over (stage, side): cost and backpointer
    INF = float("inf")
    cost = {("edge",): stage_cost(VR_TASKS[0], "edge")}
    dp = [{"edge": (stage_cost(VR_TASKS[0], "edge"), None),
           "server": (stage_cost(VR_TASKS[0], "server"), None)}]
    for i in range(1, len(VR_TASKS)):
        row = {}
        for side in ("edge", "server"):
            sc = stage_cost(VR_TASKS[i], side)
            best, arg = INF, None
            for prev in ("edge", "server"):
                c = dp[i - 1][prev][0]
                if c == INF or sc == INF:
                    continue
                tot = c + trans(VR_TASKS[i - 1], prev, side) + sc
                if tot < best:
                    best, arg = tot, prev
            row[side] = (best, arg)
        dp.append(row)
    # backtrack the optimal placement
    side = min(("edge", "server"), key=lambda s: dp[-1][s][0])
    sides = [side]
    for i in range(len(VR_TASKS) - 1, 0, -1):
        side = dp[i][side][1]
        sides.append(side)
    sides.reverse()
    plan: dict[str, float] = {}
    for i, kind in enumerate(VR_TASKS):
        c = stage_cost(kind, sides[i])
        if i > 0:
            c += trans(VR_TASKS[i - 1], sides[i - 1], sides[i])
        plan[kind] = c
    total = sum(plan.values())
    return {k: v / total for k, v in plan.items()}


def vr_frame(cfg: TaskGraph, edge: str, edge_kind: str, frame_idx: int,
             fps: Optional[float] = None,
             shares: Optional[dict[str, float]] = None) -> list[Task]:
    fps = fps or EDGE_FPS[edge_kind]
    period = 1.0 / fps
    release = frame_idx * period
    shares = shares or _vr_plan_shares(edge_kind)
    tasks: list[Task] = []
    prev: Optional[Task] = None
    for kind in VR_TASKS:
        t = make_task(kind, origin=edge,
                      deadline=shares[kind] * period,
                      input_bytes=(VR_BYTES[VR_TASKS[VR_TASKS.index(kind) - 1]]
                                   if kind != "capture" else 8 * KB),
                      output_bytes=VR_BYTES[kind],
                      release_time=release)
        t.attrs["frame"] = frame_idx
        t.attrs["period"] = period
        t.attrs["pinned"] = kind in VR_PINNED
        cfg.add(t, deps=[prev] if prev is not None else [])
        tasks.append(t)
        prev = t
    # mark tasks whose immediate successor is pinned to the origin device:
    # their output must travel back, which the Orchestrator charges upfront
    for a, b in zip(tasks, tasks[1:]):
        if b.attrs.get("pinned"):
            a.attrs["succ_pinned_bytes"] = a.output_bytes
    return tasks


def vr_frame_latencies(cfg: TaskGraph, timeline) -> dict[tuple[str, int], float]:
    """(edge, frame) -> end-to-end frame latency (capture release -> display)."""
    out: dict[tuple[str, int], float] = {}
    for t in cfg:
        if t.kind != "display":
            continue
        key = (t.origin or "", t.attrs["frame"])
        out[key] = timeline.finish[t.uid] - t.release_time
    return out


def vr_frame_qos_failure(cfg: TaskGraph, timeline) -> float:
    """Fraction of frames finishing after their period (the paper's §5.5
    metric: 'how many frames are processed later than the latency
    requirement')."""
    total, late = 0, 0
    for t in cfg:
        if t.kind != "display":
            continue
        total += 1
        lat = timeline.finish[t.uid] - t.release_time
        late += lat > t.attrs["period"] * (1 + 1e-9)
    return late / total if total else 0.0


def vr_workload(tb: Testbed, n_frames: int = 30,
                fps_override: Optional[dict[str, float]] = None) -> TaskGraph:
    cfg = TaskGraph("vr")
    for edge in tb.edges:
        kind = tb.edge_kind[edge]
        fps = (fps_override or {}).get(edge, EDGE_FPS[kind])
        for f in range(n_frames):
            vr_frame(cfg, edge, kind, f, fps=fps)
    return cfg


MINING_TASKS = ("svm", "knn", "mlp")
MINING_DEADLINE = 100 * MS
MINING_HZ = 10.0
SENSOR_BYTES = 64 * KB


def mining_reading(cfg: TaskGraph, edge: str, sensor_id: int,
                   reading_idx: int, hz: float = MINING_HZ) -> list[Task]:
    release = reading_idx / hz
    out = []
    for kind in MINING_TASKS:
        t = make_task(kind, origin=edge, deadline=MINING_DEADLINE,
                      input_bytes=SENSOR_BYTES, output_bytes=1 * KB,
                      release_time=release)
        t.attrs["sensor"] = sensor_id
        cfg.add(t)
        out.append(t)
    return out


def wireless_churn_schedule(tb: Testbed, n_waves: int, seed: int = 0,
                            churn_frac: float = 0.25,
                            min_scale: float = 0.05,
                            max_scale: float = 0.5) -> list[Churn]:
    """Seeded bandwidth-volatility schedule over the edge uplinks.

    Models flaky wireless last-hop links (paper §5.4.1: 'dynamic network
    conditions'): each wave first **recovers** every currently degraded
    uplink back to its nominal bandwidth, then **degrades** a fresh random
    ``churn_frac`` sample of uplinks to ``uniform(min_scale, max_scale)``
    of nominal.  Each wave is one :class:`Churn` batch (bandwidth entries
    only — no deaths), so applying it costs a single overlay copy on the
    compiled snapshot and zero topology-layer copies.  Deterministic in
    ``seed``."""
    rng = random.Random(seed)
    links = [f"link_{e}" for e in tb.edges]
    nominal: dict[str, float] = {}
    for adj in tb.graph._adj.values():
        for _, e in adj:
            if e.name in links and e.name not in nominal:
                nominal[e.name] = e.bandwidth
    k = max(1, int(len(links) * churn_frac))
    degraded: dict[str, float] = {}
    waves: list[Churn] = []
    for _ in range(n_waves):
        entries: list[tuple[str, float]] = []
        for name in sorted(degraded):
            entries.append((name, nominal[name]))
        degraded.clear()
        for name in rng.sample(links, k):
            bw = nominal[name] * rng.uniform(min_scale, max_scale)
            degraded[name] = bw
            entries.append((name, bw))
        waves.append(Churn(bandwidth=tuple(entries)))
    return waves


def mining_workload(tb: Testbed, n_sensors: int, n_readings: int = 10,
                    hz: float = MINING_HZ) -> TaskGraph:
    """Sensors are attached to edges round-robin weighted by capability
    (paper: 'we initially connect each smart sensor to the edges based on
    edge device's computing capability')."""
    cfg = TaskGraph("mining")
    weights = {"orin_agx": 4, "xavier_agx": 3, "orin_nano": 2, "xavier_nx": 1}
    ring = list(itertools.chain.from_iterable(
        [e] * weights.get(tb.edge_kind[e], 1) for e in tb.edges))
    if not ring:
        ring = list(tb.edges)
    for s in range(n_sensors):
        edge = ring[s % len(ring)]
        for r in range(n_readings):
            mining_reading(cfg, edge, s, r, hz=hz)
    return cfg
