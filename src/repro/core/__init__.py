"""H-EYE core: holistic resource modeling + management (paper §3).

Public surface:
  HWGraph / Node / ProcessingUnit / Predictable  — graph-based HW repr (§3.3)
  CompiledHWGraph                                — array-native snapshot engine
  Task / TaskGraph                               — CFGs of constrained tasks
  ProfiledModel / RooflineModel / CallableModel  — modular predict() (§3.3)
  DecoupledSlowdown / SlowdownParams             — decoupled slowdown (§3.4)
  Traverser / Timeline / TaskPrediction          — contention intervals (§3.4)
  Orchestrator / build_orchestrators / ActiveLedger — Alg. 1 (§3.5)
  SchedulerSession                               — batch-first mapping API
  ServeLoop / ServeStats / TenantSpec            — online serving continuum
  PoissonArrivals / DiurnalArrivals              — open-loop traffic models
  ClosedLoopClients                              — closed-loop population
  build_testbed / build_tpu_fleet                — topologies (Fig. 4, TPU)
  Runtime / policies                             — experiment harness (§5)
"""
from .compiled import CompiledHWGraph, ShardedHWGraph
from .hwgraph import (Churn, EdgeAttr, HWGraph, Node, NodeKind, Predictable,
                      ProcessingUnit, Unit)
from .orchestrator import (ActiveLedger, MapResult, OrcConfig, Orchestrator,
                           ShardedLedger, build_orchestrators)
from .predict import CallableModel, PerfModel, ProfiledModel, RooflineModel
from .serving import (ClosedLoopClients, DiurnalArrivals, PoissonArrivals,
                      ServeLoop, ServeRequest, ServeStats, TenantSpec,
                      single_task_request)
from .session import RunStats, SchedulerSession, percentiles
from .simulator import (AcePolicy, LatsPolicy, OrchestratorPolicy,
                        Runtime, ground_truth_traverser, heye_traverser)
from .slowdown import (DecoupledSlowdown, NoSlowdown, SlowdownParams,
                       heye_params, truth_params)
from .task import Task, TaskGraph
from .topology import (EDGE_FPS, Testbed, build_edge_device, build_server,
                       build_testbed, build_tpu_fleet, make_task,
                       vr_mining_profile)
from .traverser import TaskPrediction, Timeline, Traverser
from .workloads import (MINING_DEADLINE, mining_workload, vr_frame,
                        vr_workload, wireless_churn_schedule)

__all__ = [n for n in dir() if not n.startswith("_")]
