"""Topology builders: HW-GRAPHs for the paper's testbed and for TPU fleets.

Edge devices follow Fig. 4's multi-layer structure (CPU clusters with private
L2s behind a shared L3, GPU sharing an LLC with the CPU, a vision cluster
whose DLA/PVA share SRAM, a VIC with private storage, all meeting at DRAM).
Servers have a CPU (LLC->DRAM) and a discrete GPU with private VRAM, so
cross-PU contention inside a server is mild while GPU *multi-tenancy* is the
dominant effect — matching the paper's §2.2 narrative.

Standalone task latencies are digitized estimates of the paper's Fig. 9
(the figure is not numerically annotated; values were chosen to preserve
every ordering and bottleneck the text calls out — e.g. rendering is
infeasible at QoS on every edge device, KNN on Xavier NX is the
strong-scaling limiter, VIC is slightly slower standalone than CPU for
reproject but contention-immune).

The TPU fleet builder expresses pods -> hosts -> chips with ICI torus links
inside a pod and a DCN ABSTRACT fabric between pods; chips carry roofline
attrs (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI) consumed by
core/predict.RooflineModel and core/placement.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .hwgraph import HWGraph, Node, NodeKind, ProcessingUnit
from .predict import ProfiledModel
from .task import Task

MS = 1e-3
GB = 1e9
MB = 1e6
KB = 1e3
Gbps = 1e9 / 8

EDGE_KINDS = ("orin_agx", "xavier_agx", "orin_nano", "xavier_nx")
SERVER_KINDS = ("server1", "server2", "server3")

# target FPS per edge kind (paper: slower headsets get lower FPS QoS)
EDGE_FPS = {"orin_agx": 30.0, "xavier_agx": 24.0, "orin_nano": 20.0,
            "xavier_nx": 20.0}


# ---------------------------------------------------------------------------
# Edge SoCs (Fig. 4 layer-2/3 structure)
# ---------------------------------------------------------------------------
def build_edge_device(g: HWGraph, name: str, kind: str,
                      parent: Optional[str] = None,
                      core_level: bool = False) -> Node:
    """Add one Jetson-class SoC to ``g``. Returns the device GROUP node.

    ``core_level=True`` additionally exposes individual CPU cores as PUs
    (used by the Fig. 2 contention-reproduction benchmark)."""
    assert kind in EDGE_KINDS, kind
    dev = g.add_node(Node(name, NodeKind.GROUP, parent=parent,
                          attrs={"orc_level": "device", "devkind": kind}))
    prof = vr_mining_profile()

    def pu(short: str, pu_kind: str, max_tenancy: int = 4) -> ProcessingUnit:
        p = ProcessingUnit(f"{name}.{short}", model=prof, max_tenancy=max_tenancy,
                           parent=name,
                           attrs={"pu_class": f"{kind}.{short.rstrip('0123456789')}",
                                  "pu_class_kind": pu_kind})
        g.add_node(p)
        return p

    def store(short: str, rclass: str) -> Node:
        return g.add_node(Node(f"{name}.{short}", NodeKind.STORAGE, parent=name,
                               attrs={"rclass": rclass}))

    dram = store("dram", "dram")
    llc = store("llc", "llc")
    l3 = store("l3", "l3")
    g.add_edge(llc.name, dram.name, bandwidth=102 * GB, latency=1e-7)
    g.add_edge(l3.name, llc.name, bandwidth=150 * GB, latency=5e-8)

    # two CPU clusters, each with a private L2 (Fig. 2: core0/1 share L2,
    # cross-cluster pairs meet at L3)
    for c in range(2):
        l2 = store(f"l2_{c}", "l2")
        g.add_edge(l2.name, l3.name, bandwidth=200 * GB, latency=2e-8)
        cl = pu(f"cpu{c}", "cpu", max_tenancy=4)
        g.add_edge(cl.name, l2.name, bandwidth=250 * GB, latency=1e-8)
        if core_level:
            for k in range(2):
                core = pu(f"cpu{c}_core{k}", "cpu", max_tenancy=1)
                g.add_edge(core.name, l2.name, bandwidth=250 * GB, latency=1e-8)

    gpu = pu("gpu", "gpu", max_tenancy=4)
    g.add_edge(gpu.name, llc.name, bandwidth=200 * GB, latency=2e-8)

    # vision cluster: DLA + PVA share SRAM (Fig. 4's example)
    sram = store("sram", "sram")
    g.add_edge(sram.name, dram.name, bandwidth=120 * GB, latency=8e-8)
    for short in ("dla", "pva"):
        v = pu(short, short, max_tenancy=2)
        g.add_edge(v.name, sram.name, bandwidth=150 * GB, latency=2e-8)

    # VIC has private storage (contention-immune per §5.3.1): its tasks'
    # effective shared-memory pressure is capped (consumed by DecoupledSlowdown)
    vic_sram = store("vic_sram", "sram")
    vic = pu("vic", "vic", max_tenancy=2)
    vic.attrs["mem_usage_cap"] = 0.15
    g.add_edge(vic.name, vic_sram.name, bandwidth=80 * GB, latency=2e-8)
    g.add_edge(vic_sram.name, dram.name, bandwidth=60 * GB, latency=1e-7)

    # NIC: the device's attachment point for network edges
    nic = g.add_node(Node(f"{name}.nic", NodeKind.CONTROLLER, parent=name,
                          attrs={"rclass": "nic"}))
    g.add_edge(nic.name, dram.name, bandwidth=10 * GB, latency=1e-6)
    return dev


def build_server(g: HWGraph, name: str, kind: str,
                 parent: Optional[str] = None) -> Node:
    assert kind in SERVER_KINDS, kind
    dev = g.add_node(Node(name, NodeKind.GROUP, parent=parent,
                          attrs={"orc_level": "device", "devkind": kind}))
    prof = vr_mining_profile()

    def store(short: str, rclass: str) -> Node:
        return g.add_node(Node(f"{name}.{short}", NodeKind.STORAGE, parent=name,
                               attrs={"rclass": rclass}))

    dram = store("dram", "dram")
    llc = store("llc", "llc")
    g.add_edge(llc.name, dram.name, bandwidth=200 * GB, latency=8e-8)
    cpu = g.add_node(ProcessingUnit(f"{name}.cpu", model=prof, max_tenancy=16,
                                    parent=name,
                                    attrs={"pu_class": f"{kind}.cpu",
                                           "pu_class_kind": "cpu"}))
    g.add_edge(cpu.name, llc.name, bandwidth=400 * GB, latency=1e-8)
    # discrete GPU with private VRAM (server3 is an APU: GPU shares DRAM)
    gpu = g.add_node(ProcessingUnit(f"{name}.gpu", model=prof, max_tenancy=6,
                                    parent=name,
                                    attrs={"pu_class": f"{kind}.gpu",
                                           "pu_class_kind": "gpu"}))
    if kind == "server3":
        g.add_edge(gpu.name, llc.name, bandwidth=100 * GB, latency=5e-8)
    else:
        vram = store("vram", "hbm")
        g.add_edge(gpu.name, vram.name, bandwidth=600 * GB, latency=2e-8)
        g.add_edge(vram.name, dram.name, bandwidth=16 * GB, latency=1e-6)  # PCIe
    nic = g.add_node(Node(f"{name}.nic", NodeKind.CONTROLLER, parent=name,
                          attrs={"rclass": "nic"}))
    g.add_edge(nic.name, dram.name, bandwidth=10 * GB, latency=1e-6)
    return dev


# ---------------------------------------------------------------------------
# Full DECS testbed (Table 2 + §5.1 network)
# ---------------------------------------------------------------------------
@dataclass
class Testbed:
    graph: HWGraph
    edges: list[str]          # edge device names
    servers: list[str]        # server device names
    edge_kind: dict[str, str]
    server_kind: dict[str, str]


def build_testbed(edge_counts: Optional[dict[str, int]] = None,
                  server_counts: Optional[dict[str, int]] = None,
                  lan_bw: float = 1.0 * Gbps * 8,       # edge<->router (WLAN-ish)
                  wan_bw: float = 10 * Gbps,            # router/servers on campus WAN
                  lan_lat: float = 0.3 * MS,
                  wan_lat: float = 1.0 * MS) -> Testbed:
    """Edge devices behind one router; router + servers on a 10 Gbps WAN."""
    edge_counts = edge_counts or {"orin_agx": 1, "xavier_agx": 1,
                                  "orin_nano": 1, "xavier_nx": 2}
    server_counts = server_counts or {"server1": 1, "server2": 1, "server3": 1}
    g = HWGraph()
    root = g.add_node(Node("fleet", NodeKind.GROUP, attrs={"orc_level": "root"}))
    ecl = g.add_node(Node("edge_cluster", NodeKind.GROUP, parent="fleet",
                          attrs={"orc_level": "cluster"}))
    scl = g.add_node(Node("server_cluster", NodeKind.GROUP, parent="fleet",
                          attrs={"orc_level": "cluster"}))
    router = g.add_node(Node("router", NodeKind.CONTROLLER, parent="fleet"))
    wan = g.add_node(Node("wan", NodeKind.ABSTRACT, parent="fleet"))
    g.add_edge("router", "wan", bandwidth=wan_bw, latency=wan_lat)

    edges: list[str] = []
    ek: dict[str, str] = {}
    for kind, n in edge_counts.items():
        for i in range(n):
            name = f"{kind}_e{len(edges)}"
            build_edge_device(g, name, kind, parent="edge_cluster")
            g.add_edge(name, "router", bandwidth=lan_bw, latency=lan_lat,
                       name=f"link_{name}")
            edges.append(name)
            ek[name] = kind
    servers: list[str] = []
    sk: dict[str, str] = {}
    for kind, n in server_counts.items():
        for i in range(n):
            name = f"{kind}_s{len(servers)}"
            build_server(g, name, kind, parent="server_cluster")
            g.add_edge(name, "wan", bandwidth=wan_bw, latency=wan_lat,
                       name=f"link_{name}")
            servers.append(name)
            sk[name] = kind
    return Testbed(graph=g, edges=edges, servers=servers,
                   edge_kind=ek, server_kind=sk)


# ---------------------------------------------------------------------------
# Profiled standalone latencies (digitized from Fig. 9)
# ---------------------------------------------------------------------------
_VR_EDGE = {
    # task: {edge_kind: {pu_short: seconds}}
    "capture":   {"orin_agx": {"cpu": 1.0}, "xavier_agx": {"cpu": 1.2},
                  "orin_nano": {"cpu": 1.8}, "xavier_nx": {"cpu": 2.0}},
    "pose_pred": {"orin_agx": {"cpu": 6.0, "gpu": 3.5},
                  "xavier_agx": {"cpu": 8.0, "gpu": 5.0},
                  "orin_nano": {"cpu": 12.0, "gpu": 7.0},
                  "xavier_nx": {"cpu": 14.0, "gpu": 8.0}},
    "render":    {"orin_agx": {"gpu": 38.0}, "xavier_agx": {"gpu": 55.0},
                  "orin_nano": {"gpu": 90.0}, "xavier_nx": {"gpu": 100.0}},
    "encode":    {"orin_agx": {"gpu": 5.0, "vic": 6.0},
                  "xavier_agx": {"gpu": 7.0, "vic": 8.0},
                  "orin_nano": {"gpu": 10.0, "vic": 12.0},
                  "xavier_nx": {"gpu": 11.0, "vic": 13.0}},
    "decode":    {"orin_agx": {"gpu": 4.0, "vic": 5.0},
                  "xavier_agx": {"gpu": 5.0, "vic": 6.0},
                  "orin_nano": {"gpu": 8.0, "vic": 9.0},
                  "xavier_nx": {"gpu": 9.0, "vic": 10.0}},
    "reproject": {"orin_agx": {"cpu": 3.0, "vic": 4.0},
                  "xavier_agx": {"cpu": 4.0, "vic": 5.0},
                  "orin_nano": {"cpu": 6.0, "vic": 7.0},
                  "xavier_nx": {"cpu": 7.0, "vic": 8.0}},
    "display":   {"orin_agx": {"cpu": 1.5}, "xavier_agx": {"cpu": 2.0},
                  "orin_nano": {"cpu": 3.0}, "xavier_nx": {"cpu": 3.0}},
}
_VR_SERVER = {
    "pose_pred": {"server1": {"cpu": 2.5, "gpu": 1.5},
                  "server2": {"cpu": 2.2, "gpu": 1.3},
                  "server3": {"cpu": 3.5, "gpu": 3.0}},
    "render":    {"server1": {"gpu": 7.0}, "server2": {"gpu": 6.5},
                  "server3": {"gpu": 18.0}},
    "encode":    {"server1": {"gpu": 2.5}, "server2": {"gpu": 2.2},
                  "server3": {"gpu": 6.0}},
    "decode":    {"server1": {"gpu": 2.0}, "server2": {"gpu": 1.8},
                  "server3": {"gpu": 4.0}},
}
_ML_EDGE = {
    "svm": {"orin_agx": {"cpu": 18.0, "gpu": 8.0},
            "xavier_agx": {"cpu": 24.0, "gpu": 10.0},
            "orin_nano": {"cpu": 35.0, "gpu": 15.0},
            "xavier_nx": {"cpu": 38.0, "gpu": 16.0}},
    "knn": {"orin_agx": {"cpu": 30.0, "gpu": 14.0},
            "xavier_agx": {"cpu": 40.0, "gpu": 18.0},
            "orin_nano": {"cpu": 55.0, "gpu": 26.0},
            "xavier_nx": {"cpu": 70.0, "gpu": 30.0}},
    "mlp": {"orin_agx": {"cpu": 12.0, "gpu": 5.0},
            "xavier_agx": {"cpu": 16.0, "gpu": 6.0},
            "orin_nano": {"cpu": 24.0, "gpu": 9.0},
            "xavier_nx": {"cpu": 26.0, "gpu": 10.0}},
}
_ML_SERVER = {
    "svm": {"server1": {"cpu": 3.0, "gpu": 1.5},
            "server2": {"cpu": 2.5, "gpu": 1.2},
            "server3": {"cpu": 6.0, "gpu": 4.0}},
    "knn": {"server1": {"cpu": 5.0, "gpu": 2.5},
            "server2": {"cpu": 4.5, "gpu": 2.0},
            "server3": {"cpu": 9.0, "gpu": 6.0}},
    "mlp": {"server1": {"cpu": 2.0, "gpu": 1.0},
            "server2": {"cpu": 1.8, "gpu": 0.8},
            "server3": {"cpu": 4.0, "gpu": 3.0}},
}
# generic matrix-multiply microbenchmark used by the Fig. 2 reproduction
_MM = {k: {"cpu": 20.0, "cpu_core": 40.0, "gpu": 6.0, "dla": 12.0}
       for k in EDGE_KINDS}

_profile_singleton: Optional[ProfiledModel] = None


def vr_mining_profile() -> ProfiledModel:
    """One shared ProfiledModel keyed by (task kind, pu_class)."""
    global _profile_singleton
    if _profile_singleton is not None:
        return _profile_singleton
    table: dict[tuple[str, str], float] = {}
    for book in (_VR_EDGE, _ML_EDGE):
        for task, per_kind in book.items():
            for devkind, pus in per_kind.items():
                for pu, ms in pus.items():
                    table[(task, f"{devkind}.{pu}")] = ms * MS
    for book in (_VR_SERVER, _ML_SERVER):
        for task, per_kind in book.items():
            for devkind, pus in per_kind.items():
                for pu, ms in pus.items():
                    table[(task, f"{devkind}.{pu}")] = ms * MS
    for devkind, pus in _MM.items():
        table[("mm", f"{devkind}.cpu")] = pus["cpu"] * MS
        table[("mm", f"{devkind}.cpu_core")] = pus["cpu_core"] * MS
        table[("mm", f"{devkind}.gpu")] = pus["gpu"] * MS
        table[("mm", f"{devkind}.dla")] = pus["dla"] * MS
        table[("dnn", f"{devkind}.gpu")] = 15.0 * MS
        table[("dnn", f"{devkind}.dla")] = 25.0 * MS
    _profile_singleton = ProfiledModel(table=table)
    return _profile_singleton


# generalized resource usage per task kind (§3.4 slowdown calculation step 2)
TASK_USAGE = {
    "capture":   {"pu": 0.3, "mem": 0.2},
    "pose_pred": {"pu": 1.0, "mem": 0.7},
    "render":    {"pu": 1.0, "mem": 0.9},
    "encode":    {"pu": 0.8, "mem": 0.5},
    "decode":    {"pu": 0.7, "mem": 0.4},
    "reproject": {"pu": 0.8, "mem": 0.6},
    "display":   {"pu": 0.2, "mem": 0.1},
    "svm":       {"pu": 1.0, "mem": 0.6},
    "knn":       {"pu": 1.0, "mem": 0.9},
    "mlp":       {"pu": 1.0, "mem": 0.5},
    "mm":        {"pu": 1.0, "mem": 1.0},
    "dnn":       {"pu": 1.0, "mem": 1.0},
}
# irregular-access multiplier (ground-truth noise scale; §5.2: the ML tasks'
# "intricate and irregular data access patterns" dominate H-EYE's 3.2% error)
TASK_IRREGULARITY = {"knn": 2.2, "svm": 1.4, "mlp": 1.0, "render": 1.2,
                     "pose_pred": 1.1, "mm": 0.6, "dnn": 1.0}


def make_task(kind: str, origin: Optional[str] = None,
              deadline: Optional[float] = None,
              input_bytes: float = 0.0, output_bytes: float = 0.0,
              release_time: float = 0.0, size: float = 1.0) -> Task:
    t = Task(kind=kind, size=size, deadline=deadline, origin=origin,
             input_bytes=input_bytes, output_bytes=output_bytes,
             usage=dict(TASK_USAGE.get(kind, {"pu": 1.0, "mem": 0.5})))
    t.release_time = release_time
    t.attrs["irregularity"] = TASK_IRREGULARITY.get(kind, 1.0)
    return t




# ---------------------------------------------------------------------------
# TPU fleet (the hardware-adaptation target)
# ---------------------------------------------------------------------------
TPU_V5E = {"peak_flops": 197e12, "mem_bw": 819e9, "link_bw": 50e9,
           "hbm_bytes": 16e9}


def build_tpu_fleet(n_pods: int = 2, hosts_per_pod: int = 16,
                    chips_per_host: int = 16,
                    dcn_bw: float = 25 * GB, dcn_lat: float = 1e-4,
                    ici_bw: float = 50 * GB, ici_lat: float = 1e-6) -> Testbed:
    """pods -> hosts -> chips. ICI links chip<->chip in a ring per host plus
    host<->host rings in the pod (coarse torus abstraction); DCN fabric is an
    ABSTRACT node exactly like the paper's unknown WAN."""
    g = HWGraph()
    g.add_node(Node("fleet", NodeKind.GROUP, attrs={"orc_level": "root"}))
    dcn = g.add_node(Node("dcn", NodeKind.ABSTRACT, parent="fleet"))
    pods: list[str] = []
    for p in range(n_pods):
        pod = f"pod{p}"
        g.add_node(Node(pod, NodeKind.GROUP, parent="fleet",
                        attrs={"orc_level": "cluster"}))
        pods.append(pod)
        host_names = []
        for h in range(hosts_per_pod):
            host = f"{pod}.host{h}"
            g.add_node(Node(host, NodeKind.GROUP, parent=pod,
                            attrs={"orc_level": "device"}))
            host_names.append(host)
            prev_chip = None
            for c in range(chips_per_host):
                chip = ProcessingUnit(f"{host}.chip{c}", model=None,
                                      max_tenancy=2, parent=host,
                                      attrs={"pu_class": "tpu_v5e",
                                             "pu_class_kind": "tpu",
                                             **TPU_V5E})
                g.add_node(chip)
                hbm = g.add_node(Node(f"{host}.chip{c}.hbm", NodeKind.STORAGE,
                                      parent=host, attrs={"rclass": "hbm"}))
                g.add_edge(chip.name, hbm.name, bandwidth=TPU_V5E["mem_bw"],
                           latency=1e-7)
                if prev_chip is not None:
                    g.add_edge(prev_chip, chip.name, bandwidth=ici_bw,
                               latency=ici_lat, name=f"ici_{chip.name}")
                prev_chip = chip.name
        for i, host in enumerate(host_names):     # host ring over ICI
            nxt = host_names[(i + 1) % len(host_names)]
            g.add_edge(host, nxt, bandwidth=ici_bw * chips_per_host / 4,
                       latency=ici_lat, name=f"ici_{host}")
            g.add_edge(host, "dcn", bandwidth=dcn_bw, latency=dcn_lat,
                       name=f"dcn_{host}")
    return Testbed(graph=g, edges=[], servers=pods, edge_kind={},
                   server_kind={p: "tpu_pod" for p in pods})
