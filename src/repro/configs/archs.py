"""Import all assigned architecture configs (registers them)."""
from .gemma3_4b import GEMMA3_4B
from .gemma3_1b import GEMMA3_1B
from .gemma2_2b import GEMMA2_2B
from .minitron_4b import MINITRON_4B
from .llama4_maverick_400b_a17b import LLAMA4_MAVERICK
from .granite_moe_1b_a400m import GRANITE_MOE
from .recurrentgemma_9b import RECURRENTGEMMA_9B
from .whisper_large_v3 import WHISPER_LARGE_V3
from .rwkv6_1b6 import RWKV6_1B6
from .phi3_vision_4b import PHI3_VISION

ALL = [GEMMA3_4B, GEMMA3_1B, GEMMA2_2B, MINITRON_4B, LLAMA4_MAVERICK,
       GRANITE_MOE, RECURRENTGEMMA_9B, WHISPER_LARGE_V3, RWKV6_1B6,
       PHI3_VISION]
