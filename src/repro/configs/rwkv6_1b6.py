"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay WKV.
[arXiv:2404.05892; unverified]

The channel-mix FFN is realized as a gated MLP of the listed d_ff; the
time-mix keeps RWKV6's data-dependent decay (w from a low-rank projection)
and the bonus-u term; token-shift uses static learned mix ratios."""
from .base import ModelConfig, register

RWKV6_1B6 = register(ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, d_ff=7168,
    vocab=65536, head_dim=64,
    layer_pattern=("rwkv",), act="silu",
))
