from .base import ModelConfig, all_configs, get_config, register
from .shapes import SHAPES, Shape, cells, input_specs
