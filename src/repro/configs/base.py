"""Model configuration + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

# layer kinds usable in ``layer_pattern``
LAYER_KINDS = ("global", "local", "rglru", "rwkv", "enc")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | audio | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    # attention structure
    layer_pattern: tuple[str, ...] = ("global",)   # cycled across layers
    window: int = 1024                             # sliding-window span
    attn_softcap: Optional[float] = None           # gemma2 logit softcapping
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # layer i is MoE iff n_experts>0 and i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 1024       # GShard dispatch group size (placement-tuned)
    moe_impl: str = "einsum"    # "einsum" (GSPMD-partitionable) | "scatter"
    # recurrent blocks
    lru_width: Optional[int] = None
    conv1d_size: int = 4
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attn: bool = False
    src_seq: int = 1500         # encoder positions (whisper 30 s -> 1500 frames)
    # modality frontend stub
    frontend: Optional[str] = None   # None | "audio" | "vision"
    n_patches: int = 576             # vlm patch positions carved at seq start
    # numerics
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act: str = "gelu"                # mlp gate activation: gelu | silu

    # ---- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def kind_of_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_every) == self.moe_offset

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(k in ("rglru", "rwkv") for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer needs full-sequence quadratic attention
        (pure local windows / recurrent) -> eligible for long_500k."""
        return all(k in ("rglru", "rwkv", "local") for k in self.layer_pattern)

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        pat = self.layer_pattern
        return self.scaled(
            name=self.name + "-smoke",
            n_layers=max(2, len(pat)),
            d_model=64,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv=1 if self.n_kv == 1 else 2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            window=16,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            lru_width=32 if self.lru_width else None,
            encoder_layers=2 if self.encoder_layers else 0,
            src_seq=24 if self.encoder_layers else self.src_seq,
            n_patches=8 if self.frontend == "vision" else self.n_patches,
        )

    # params count (for 6ND model-flops accounting)
    def param_count(self) -> int:
        d, ff, V, hd = self.d_model, self.d_ff, self.vocab, self.hd
        n_q, n_kv = self.n_heads, self.n_kv
        total = V * d                       # embedding
        if not self.tie_embeddings:
            total += V * d
        for i in range(self.n_layers):
            kind = self.kind_of_layer(i)
            if kind in ("global", "local", "enc"):
                total += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            elif kind == "rglru":
                w = self.lru_width or d
                total += 2 * d * w + w * d          # in(x2: x&gate), out proj
                total += w * self.conv1d_size + 3 * w   # conv + lru gates
            elif kind == "rwkv":
                total += 5 * d * d                      # r,k,v,g,o projections
                total += 2 * d * 64                     # w lora (rank 64)
                total += 7 * d + n_q * hd               # mu, bias, ln, u
            if self.cross_attn and kind == "global" and self.is_encdec:
                total += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            if self.is_moe_layer(i):
                total += self.n_experts * 3 * d * ff + d * self.n_experts
            else:
                total += 3 * d * ff     # gated mlp (rwkv channel-mix incl.)
            total += 2 * d                               # norms
        for _ in range(self.encoder_layers):
            total += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            total += 3 * d * ff + 2 * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        n_moe = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        all_exp = n_moe * self.n_experts * 3 * self.d_model * self.d_ff
        act_exp = n_moe * max(1, self.top_k) * 3 * self.d_model * self.d_ff
        return full - all_exp + act_exp


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from . import archs  # noqa: F401  (registers everything)
