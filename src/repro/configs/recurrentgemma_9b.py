"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU recurrent blocks + local
attention, 1 attention : 2 recurrent. MQA (kv=1). [arXiv:2402.19427; unverified]"""
from .base import ModelConfig, register

RECURRENTGEMMA_9B = register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288,
    vocab=256000, head_dim=256,
    layer_pattern=("rglru", "rglru", "local"), window=2048,
    lru_width=4096, conv1d_size=4, act="gelu",
))
