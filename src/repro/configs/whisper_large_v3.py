"""whisper-large-v3 [audio] — encoder-decoder backbone; the conv audio
frontend is a STUB (input_specs() provides precomputed 1500-frame embeddings).
MHA (kv=20). [arXiv:2212.04356; unverified]

Backbone deviations (documented in DESIGN.md): rotary embeddings instead of
learned absolute positions; gated MLP instead of plain GELU MLP."""
from .base import ModelConfig, register

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120,
    vocab=51866, head_dim=64,
    layer_pattern=("global",), act="gelu",
    encoder_layers=32, cross_attn=True, src_seq=1500,
    frontend="audio", tie_embeddings=True,
))
