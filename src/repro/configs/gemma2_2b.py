"""gemma2-2b [dense] — alternating local/global attention + logit softcaps.
[arXiv:2408.00118; hf]"""
from .base import ModelConfig, register

GEMMA2_2B = register(ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv=4, d_ff=9216,
    vocab=256000, head_dim=256,
    layer_pattern=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0, act="gelu",
))
