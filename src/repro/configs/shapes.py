"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention and only
runs for recurrentgemma-9b / rwkv6-1.6b (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .base import ModelConfig, all_configs


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    mode: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention layers in pattern -> quadratic at 500k; "
                       "skipped per assignment (run only for SSM/hybrid)")
    return True, ""


def cells(include_skipped: bool = False) -> list[tuple[str, str, bool, str]]:
    """All (arch, shape, runs, reason) cells in assignment order."""
    out = []
    for arch, cfg in all_configs().items():
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok or include_skipped:
                out.append((arch, shape.name, ok, why))
    return out


def input_specs(cfg: ModelConfig, shape: Shape,
                dtype: jnp.dtype = jnp.bfloat16) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every *data* input of the step function
    (weak-type-correct, shardable, no device allocation).  Caches / params are
    produced by ``jax.eval_shape`` over the model's init functions."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.mode == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.mode == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a cache of S
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["positions"] = jax.ShapeDtypeStruct((B,), i32)
    # modality frontend stubs provide precomputed embeddings
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.src_seq, cfg.d_model),
                                               dtype)
    elif cfg.frontend == "vision" and shape.mode != "decode":
        specs["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model),
                                                dtype)
    return specs
