"""gemma3-4b [dense] — 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ModelConfig, register

GEMMA3_4B = register(ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv=4, d_ff=10240,
    vocab=262144, head_dim=256,
    layer_pattern=("local",) * 5 + ("global",), window=1024,
    rope_theta=1_000_000.0, qk_norm=True, act="gelu",
))
