"""granite-moe-1b-a400m [moe] — 32 experts top-8, tiny experts (d_ff=512):
a dispatch-overhead stress test. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ModelConfig, register

GRANITE_MOE = register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_ff=512,
    vocab=49155, head_dim=64,
    layer_pattern=("global",), act="silu",
    n_experts=32, top_k=8, moe_every=1, moe_group=64,
))
