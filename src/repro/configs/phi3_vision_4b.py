"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend STUB
(input_specs() provides precomputed patch embeddings occupying the first
n_patches sequence positions). [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ModelConfig, register

PHI3_VISION = register(ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv=32, d_ff=8192,
    vocab=32064, head_dim=96,
    layer_pattern=("global",), act="silu",
    frontend="vision", n_patches=576,
))
