"""gemma3-1b [dense] — 5:1 local:global, MQA (kv=1), 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ModelConfig, register

GEMMA3_1B = register(ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv=1, d_ff=6912,
    vocab=262144, head_dim=256,
    layer_pattern=("local",) * 5 + ("global",), window=512,
    rope_theta=1_000_000.0, qk_norm=True, act="gelu",
))
