"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1 on every other layer
(interleaved MoE matches the 400B-total / 17B-active budget), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Note: the assignment lists d_ff=8192 — used for both the per-expert FFN and
the dense layers' FFN."""
from .base import ModelConfig, register

LLAMA4_MAVERICK = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192,
    vocab=202048, head_dim=128,
    layer_pattern=("global",), act="silu",
    n_experts=128, top_k=1, moe_every=2, moe_offset=1, moe_group=256,
    rope_theta=500_000.0,
))
