"""Gradient compression: blockwise int8 quantization with error feedback.

Used by the distributed-optimization path to shrink cross-pod (DCN) gradient
traffic ~4x: gradients are quantized to int8 with a per-block fp32 scale
before the reduction, and the quantization residual is carried in an error-
feedback buffer so the compression is unbiased over time (momentum-SGD /
Adam tolerate this well in practice).

On a real multi-pod run the quantized tensors are what crosses DCN (the
launcher reduces the int8 payload inside shard_map); here the transform is
exact and testable standalone.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any
BLOCK = 256


def _pad_to(x: jax.Array, m: int) -> jax.Array:
    pad = (-x.size) % m
    return jnp.pad(x.reshape(-1), (0, pad))


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """g -> (int8 payload, per-block fp32 scales)."""
    flat = _pad_to(g.astype(jnp.float32), BLOCK).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(flat / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale[:, 0]


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape).astype(dtype)


def compress_grads(grads: Pytree, error: Pytree) -> tuple[Pytree, Pytree]:
    """Returns (compressed-then-decompressed grads, new error buffers).

    ``error`` is a pytree of fp32 buffers shaped like grads (init zeros).
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        deq = dequantize(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err


def init_error(grads_like: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_bytes(grads: Pytree) -> int:
    """DCN bytes after compression (int8 payload + fp32 block scales)."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        blocks = -(-n // BLOCK)
        total += n + 4 * blocks
    return total
