from .adamw import (OptConfig, adamw_update, clip_by_global_norm, global_norm,
                    init_opt_state, schedule)
from .compress import compress_grads, compressed_bytes, init_error
