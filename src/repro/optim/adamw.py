"""AdamW with dtype-configurable state (fp32 / bf16 8-byte-per-param modes),
warmup-cosine schedule, global-norm clipping — pure JAX, pytree-native.

State layout is a flat dict so sharding rules apply uniformly:
    state = {"step": (), "m": tree, "v": tree}
ZeRO-style sharding of m/v over the data axis is applied by the launcher's
sharding rules (see launch/sharding.py), not here.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32     # bf16 halves optimizer memory


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params: Pytree, cfg: OptConfig) -> Pytree:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {"step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Pytree, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def adamw_update(params: Pytree, grads: Pytree, state: Pytree,
                 cfg: OptConfig) -> tuple[Pytree, Pytree, dict]:
    step = state["step"] + 1
    lr = schedule(step, cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype))

    # flatten explicitly: the param tree contains structural tuples (the
    # unrolled remainder layers), so tuple-is_leaf unzipping would mis-fire.
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state["m"])
    v_leaves = treedef.flatten_up_to(state["v"])
    triples = [upd(p, g, m, v) for p, g, m, v in
               zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    params_new = jax.tree_util.tree_unflatten(treedef, [t[0] for t in triples])
    m_new = jax.tree_util.tree_unflatten(treedef, [t[1] for t in triples])
    v_new = jax.tree_util.tree_unflatten(treedef, [t[2] for t in triples])
    new_state = {"step": step, "m": m_new, "v": v_new}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return params_new, new_state, metrics
