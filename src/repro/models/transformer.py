"""Decoder/encoder stack assembly.

Layers are grouped into *superblocks* of P = lcm(|pattern|, moe_every) layers
so every superblock is structurally identical; parameters are stacked over
superblocks and the stack is applied with ``jax.lax.scan`` (small HLO even at
48 layers).  ``n_layers % P`` trailing layers form an unrolled remainder.

Each sublayer is pre-norm residual:
    x += mix(norm(x))        mix in {attention, RG-LRU, RWKV6 time-mix}
    x += ffn(norm(x))        ffn in {gated MLP, MoE}
(+ an extra cross-attention sublayer in enc-dec decoder layers).

Three entry points share the layer code:
    apply_stack(...)                   training (no cache)
    apply_stack(..., cache=...)        prefill (fills the decode cache)
    apply_stack_decode(...)            one-token decode
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import moe as moe_mod
from . import recurrent as rec
from .layers import (ParallelCtx, attention_decode, attention_layer,
                     decode_attention, init_attention, init_attn_cache,
                     init_mlp, init_norm, mlp, rms_norm, _project_qkv)

Pytree = Any


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def superblock_len(cfg) -> int:
    p = len(cfg.layer_pattern)
    if cfg.n_experts > 0:
        p = _lcm(p, cfg.moe_every)
    return p


def layer_meta(cfg, i: int) -> dict:
    return {"kind": cfg.kind_of_layer(i), "moe": cfg.is_moe_layer(i),
            "cross": cfg.cross_attn and cfg.is_encdec}


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------
def init_layer(key, cfg, meta: dict) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_norm(cfg.d_model),
                         "norm2": init_norm(cfg.d_model)}
    kind = meta["kind"]
    if kind in ("global", "local", "enc"):
        p["attn"] = init_attention(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"] = rec.init_rglru(ks[0], cfg)
    elif kind == "rwkv":
        p["rwkv"] = rec.init_rwkv(ks[0], cfg)
    else:
        raise ValueError(kind)
    if meta["cross"] and kind != "enc":
        p["norm_x"] = init_norm(cfg.d_model)
        p["cross"] = init_attention(ks[2], cfg)
    if meta["moe"]:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def init_layer_cache(cfg, meta: dict, B: int, S: int,
                     dtype=jnp.bfloat16) -> dict:
    kind = meta["kind"]
    c: dict[str, Any] = {}
    if kind in ("global", "local", "enc"):
        c["attn"] = init_attn_cache(cfg, B, S, kind, dtype)
    elif kind == "rglru":
        c["rec"] = rec.init_rglru_cache(cfg, B, dtype)
    else:
        c["rec"] = rec.init_rwkv_cache(cfg, B, dtype)
    if meta["cross"] and kind != "enc":
        c["cross_kv"] = {
            "k": jnp.zeros((B, cfg.src_seq, cfg.n_kv, cfg.hd), dtype),
            "v": jnp.zeros((B, cfg.src_seq, cfg.n_kv, cfg.hd), dtype)}
    return c


# ---------------------------------------------------------------------------
# cache write helpers (prefill)
# ---------------------------------------------------------------------------
def _write_attn_cache(entry: dict, k: jax.Array, v: jax.Array,
                      kind: str) -> dict:
    """Write S prefilled (roped) k/v into a decode cache buffer.

    Global: positions [0, S) go to slots [0, S).  Local: the buffer is a
    rolling window (slot = pos % C) so the last C entries land rolled by S%C.
    """
    S = k.shape[1]
    C = entry["k"].shape[1]
    kd, vd = k.astype(entry["k"].dtype), v.astype(entry["v"].dtype)
    if kind == "local" and S >= C:
        kd = jnp.roll(kd[:, -C:], S % C, axis=1)
        vd = jnp.roll(vd[:, -C:], S % C, axis=1)
        return {"k": kd, "v": vd}
    n = min(S, C)
    return {"k": lax.dynamic_update_slice_in_dim(entry["k"], kd[:, :n], 0, 1),
            "v": lax.dynamic_update_slice_in_dim(entry["v"], vd[:, :n], 0, 1)}


# ---------------------------------------------------------------------------
# per-layer apply (training / prefill)
# ---------------------------------------------------------------------------
def apply_layer(p, x, cfg, ctx: ParallelCtx, meta: dict,
                positions: jax.Array,
                enc_out: Optional[jax.Array] = None,
                cache: Optional[dict] = None):
    """Returns (x, aux_loss, updated_cache_or_None)."""
    kind = meta["kind"]
    dt = ctx.compute_dtype
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local", "enc"):
        o = attention_layer(p["attn"], h, cfg, ctx, kind, positions)
        if cache is not None:
            _, k, v = _project_qkv(p["attn"], h, cfg, positions, dt, ctx=ctx)
            new_cache["attn"] = _write_attn_cache(cache["attn"], k, v, kind)
    elif kind == "rglru":
        if cache is not None:
            o, st = rec.rglru_layer(p["rglru"], h, cfg, ctx, return_cache=True)
            new_cache["rec"] = jax.tree.map(
                lambda a, b: a.astype(b.dtype), st, cache["rec"])
        else:
            o = rec.rglru_layer(p["rglru"], h, cfg, ctx)
    else:  # rwkv
        if cache is not None:
            o, st = rec.rwkv_layer(p["rwkv"], h, cfg, ctx, return_cache=True)
            new_cache["rec"] = jax.tree.map(
                lambda a, b: a.astype(b.dtype), st, cache["rec"])
        else:
            o = rec.rwkv_layer(p["rwkv"], h, cfg, ctx)
    x = x + o
    if meta["cross"] and kind != "enc" and enc_out is not None:
        hx = rms_norm(x, p["norm_x"], cfg.norm_eps)
        o, ckv = _cross_attention(p["cross"], hx, enc_out, cfg, ctx)
        x = x + o
        if cache is not None:
            new_cache["cross_kv"] = jax.tree.map(
                lambda a, b: a.astype(b.dtype), ckv, cache["cross_kv"])
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if meta["moe"]:
        o, aux = moe_mod.moe_layer(p["moe"], h, cfg, ctx)
    else:
        o = mlp(p["mlp"], h, cfg, ctx)
    x = x + o
    return x, aux, new_cache


def _cross_attention(p, x, enc_out, cfg, ctx: ParallelCtx):
    """Decoder cross-attention over encoder output (no mask, no rope)."""
    from .layers import full_attention
    dt = ctx.compute_dtype
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.n_heads, hd)
    Senc = enc_out.shape[1]
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, Senc, cfg.n_kv, hd)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, Senc, cfg.n_kv, hd)
    o = full_attention(q, k, v, causal=False)
    o = o.reshape(B, S, cfg.n_heads * hd) @ p["wo"].astype(dt)
    return o, {"k": k, "v": v}


def _cross_decode(p, x, cross_kv, cfg, ctx: ParallelCtx) -> jax.Array:
    dt = ctx.compute_dtype
    B = x.shape[0]
    hd = cfg.hd
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, cfg.n_heads, hd)
    k = cross_kv["k"].astype(dt)
    v = cross_kv["v"].astype(dt)
    mask = jnp.ones((B, k.shape[1]), bool)
    o = decode_attention(q, k, v, length_mask=mask)
    return o.reshape(B, 1, cfg.n_heads * hd) @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# per-layer apply (decode)
# ---------------------------------------------------------------------------
def apply_layer_decode(p, x, cache, cfg, ctx: ParallelCtx, meta: dict,
                       positions: jax.Array):
    kind = meta["kind"]
    new_cache = dict(cache)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local", "enc"):
        o, new_cache["attn"] = attention_decode(p["attn"], h, cache["attn"],
                                                cfg, ctx, kind, positions)
    elif kind == "rglru":
        o, new_cache["rec"] = rec.rglru_decode(p["rglru"], h, cache["rec"],
                                               cfg, ctx)
    else:
        o, new_cache["rec"] = rec.rwkv_decode(p["rwkv"], h, cache["rec"],
                                              cfg, ctx)
    x = x + o
    if meta["cross"] and kind != "enc" and "cross_kv" in cache:
        hx = rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + _cross_decode(p["cross"], hx, cache["cross_kv"], cfg, ctx)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if meta["moe"]:
        o, _ = moe_mod.moe_layer(p["moe"], h, cfg, ctx)
    else:
        o = mlp(p["mlp"], h, cfg, ctx)
    return x + o, new_cache


# ---------------------------------------------------------------------------
# stack = scan(superblocks) + unrolled remainder
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StackMeta:
    P: int
    n_super: int
    remainder: int
    metas: tuple           # per-sublayer meta dicts, len P
    rem_metas: tuple


def stack_meta(cfg, n_layers: Optional[int] = None,
               pattern_override: Optional[tuple] = None) -> StackMeta:
    n = n_layers if n_layers is not None else cfg.n_layers
    if pattern_override is not None:
        P = len(pattern_override)
        if P > n:
            P = n
        n_super, rem = n // P, n % P
        metas = tuple({"kind": pattern_override[j], "moe": False,
                       "cross": False} for j in range(P))
        rem_metas = tuple({"kind": pattern_override[j], "moe": False,
                           "cross": False} for j in range(rem))
        return StackMeta(P, n_super, rem, metas, rem_metas)
    P = superblock_len(cfg)
    if P > n:
        P = n
    n_super = n // P
    rem = n - n_super * P
    metas = tuple(layer_meta(cfg, j) for j in range(P))
    rem_metas = tuple(layer_meta(cfg, n_super * P + j) for j in range(rem))
    return StackMeta(P=P, n_super=n_super, remainder=rem, metas=metas,
                     rem_metas=rem_metas)


def init_stack(key, cfg, sm: StackMeta) -> dict:
    keys = jax.random.split(key, sm.n_super + 1)
    sb_params = []
    for s in range(sm.n_super):
        lkeys = jax.random.split(keys[s], sm.P)
        sb_params.append(tuple(init_layer(lkeys[j], cfg, sm.metas[j])
                               for j in range(sm.P)))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *sb_params) \
        if sm.n_super > 0 else ()
    rem_keys = jax.random.split(keys[-1], max(sm.remainder, 1))
    rem = tuple(init_layer(rem_keys[j], cfg, sm.rem_metas[j])
                for j in range(sm.remainder))
    return {"blocks": stacked, "rem": rem}


def init_stack_cache(cfg, sm: StackMeta, B: int, S: int,
                     dtype=jnp.bfloat16) -> dict:
    per_sb = tuple(init_layer_cache(cfg, sm.metas[j], B, S, dtype)
                   for j in range(sm.P))
    stacked = jax.tree.map(
        lambda x: jnp.zeros((sm.n_super,) + x.shape, x.dtype), per_sb) \
        if sm.n_super > 0 else ()
    rem = tuple(init_layer_cache(cfg, sm.rem_metas[j], B, S, dtype)
                for j in range(sm.remainder))
    return {"blocks": stacked, "rem": rem}


def _index_cache(cblocks, i):
    return jax.tree.map(
        lambda buf: lax.dynamic_index_in_dim(buf, i, 0, keepdims=False),
        cblocks)


def _update_cache(cblocks, new_c, i):
    return jax.tree.map(
        lambda buf, nc: lax.dynamic_update_index_in_dim(
            buf, nc.astype(buf.dtype), i, 0),
        cblocks, new_c)


def apply_stack(stack_params, x, cfg, ctx: ParallelCtx, sm: StackMeta,
                positions, enc_out=None, cache: Optional[dict] = None):
    """Training (cache=None) or prefill (cache filled). Returns
    (x, aux_total, new_cache_or_None).

    The stacked cache travels as a scan CARRY updated in place with
    dynamic_update_index (not as xs/ys, which would double-buffer the
    entire KV cache in HBM — a 2x cache-memory regression measured in the
    decode_32k dry-run cells)."""
    fill = cache is not None
    aux0 = jnp.zeros((), jnp.float32)

    if fill:
        def sb_fn(carry, inp):
            h, aux, cblocks = carry
            i, p_sb = inp
            c_sb = _index_cache(cblocks, i)
            new_cs = []
            for j in range(sm.P):
                h, a, cj = apply_layer(p_sb[j], h, cfg, ctx, sm.metas[j],
                                       positions, enc_out, c_sb[j])
                aux = aux + a
                new_cs.append(cj)
            cblocks = _update_cache(cblocks, tuple(new_cs), i)
            return (h, aux, cblocks), None
    else:
        def sb_fn(carry, p_sb):
            h, aux = carry
            for j in range(sm.P):
                h, a, _ = apply_layer(p_sb[j], h, cfg, ctx, sm.metas[j],
                                      positions, enc_out, None)
                aux = aux + a
            return (h, aux), None

    if ctx.remat == "block":
        sb_fn = jax.checkpoint(sb_fn)

    sb_caches = cache["blocks"] if fill else ()
    if sm.n_super > 0:
        if fill:
            (x, aux, sb_caches), _ = lax.scan(
                sb_fn, (x, aux0, cache["blocks"]),
                (jnp.arange(sm.n_super), stack_params["blocks"]))
        else:
            (x, aux), _ = lax.scan(sb_fn, (x, aux0), stack_params["blocks"])
    else:
        aux = aux0
    rem_caches = []
    for j in range(sm.remainder):
        cj = cache["rem"][j] if fill else None
        x, a, cj = apply_layer(stack_params["rem"][j], x, cfg, ctx,
                               sm.rem_metas[j], positions, enc_out, cj)
        aux = aux + a
        rem_caches.append(cj)
    new_cache = ({"blocks": sb_caches, "rem": tuple(rem_caches)}
                 if fill else None)
    return x, aux, new_cache


def apply_stack_decode(stack_params, x, cache, cfg, ctx: ParallelCtx,
                       sm: StackMeta, positions):
    """One-token decode; the stacked cache is a scan carry (in-place)."""
    def sb_fn(carry, inp):
        h, cblocks = carry
        i, p_sb = inp
        c_sb = _index_cache(cblocks, i)
        new_c = []
        for j in range(sm.P):
            h, cj = apply_layer_decode(p_sb[j], h, c_sb[j], cfg, ctx,
                                       sm.metas[j], positions)
            new_c.append(cj)
        cblocks = _update_cache(cblocks, tuple(new_c), i)
        return (h, cblocks), None

    if sm.n_super > 0:
        (x, new_blocks), _ = lax.scan(
            sb_fn, (x, cache["blocks"]),
            (jnp.arange(sm.n_super), stack_params["blocks"]))
    else:
        new_blocks = ()
    new_rem = []
    for j in range(sm.remainder):
        x, cj = apply_layer_decode(stack_params["rem"][j], x,
                                   cache["rem"][j], cfg, ctx,
                                   sm.rem_metas[j], positions)
        new_rem.append(cj)
    return x, {"blocks": new_blocks, "rem": tuple(new_rem)}
