from .layers import ParallelCtx
from .model import Model, build_model
