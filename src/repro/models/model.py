"""Unified Model API over all assigned architectures.

    model = Model(cfg, ctx)
    params = model.init(rng)
    logits, aux = model.forward(params, batch)                    # train
    cache = model.init_cache(B, max_len)
    logits, cache = model.prefill(params, batch, cache)           # prefill
    logits, cache = model.decode_step(params, cache, tokens, pos) # decode

``batch`` is a dict: tokens (B,S) plus optional modality-stub inputs
(``patches`` for vlm, ``frames`` for audio enc-dec).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import transformer as tf
from .layers import ParallelCtx, embed, init_embedding, init_norm, rms_norm, unembed

Pytree = Any


@dataclass
class Model:
    cfg: ModelConfig
    ctx: ParallelCtx

    def __post_init__(self) -> None:
        cfg = self.cfg
        self.sm = tf.stack_meta(cfg)
        self.enc_sm = (tf.stack_meta(cfg, n_layers=cfg.encoder_layers,
                                     pattern_override=("enc",))
                       if cfg.is_encdec else None)

    # -- params ---------------------------------------------------------------
    def init(self, rng: jax.Array) -> Pytree:
        cfg = self.cfg
        k_emb, k_stack, k_enc, k_head = jax.random.split(rng, 4)
        params: dict[str, Any] = {
            "embed": init_embedding(k_emb, cfg.vocab, cfg.d_model),
            "stack": tf.init_stack(k_stack, cfg, self.sm),
            "final_norm": init_norm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_embedding(k_head, cfg.vocab, cfg.d_model)
        if cfg.is_encdec:
            params["encoder"] = tf.init_stack(k_enc, cfg, self.enc_sm)
            params["enc_norm"] = init_norm(cfg.d_model)
        return params

    # -- shared pieces ----------------------------------------------------------
    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg, ctx = self.cfg, self.ctx
        x = embed(batch["tokens"], params["embed"], ctx.compute_dtype)
        if cfg.frontend == "vision" and "patches" in batch:
            n = min(cfg.n_patches, x.shape[1])
            x = jax.lax.dynamic_update_slice_in_dim(
                x, batch["patches"][:, :n].astype(x.dtype), 0, 1)
        return x

    def _encode(self, params, batch) -> Optional[jax.Array]:
        if not self.cfg.is_encdec:
            return None
        frames = batch["frames"].astype(self.ctx.compute_dtype)
        pos = jnp.arange(frames.shape[1])
        h, _, _ = tf.apply_stack(params["encoder"], frames, self.cfg, self.ctx,
                                 self.enc_sm, pos)
        return rms_norm(h, params["enc_norm"], self.cfg.norm_eps)

    def _logits(self, params, x) -> jax.Array:
        table = params.get("lm_head", params["embed"])
        return unembed(x, table, self.cfg.final_softcap)

    # -- entry points -------------------------------------------------------------
    def forward(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """Training/scoring forward. Returns (logits (B,S,V) fp32, aux)."""
        cfg, ctx = self.cfg, self.ctx
        x = self._embed_inputs(params, batch)
        enc_out = self._encode(params, batch)
        pos = jnp.arange(x.shape[1])
        x, aux, _ = tf.apply_stack(params["stack"], x, cfg, ctx, self.sm, pos,
                                   enc_out=enc_out)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x), aux

    def init_cache(self, B: int, max_len: int, dtype=jnp.bfloat16) -> Pytree:
        return tf.init_stack_cache(self.cfg, self.sm, B, max_len, dtype)

    def prefill(self, params, batch, cache) -> tuple[jax.Array, Pytree]:
        """Run S prompt tokens, filling the decode cache.
        Returns (last-position logits (B,V), cache)."""
        cfg, ctx = self.cfg, self.ctx
        x = self._embed_inputs(params, batch)
        enc_out = self._encode(params, batch)
        pos = jnp.arange(x.shape[1])
        x, _, cache = tf.apply_stack(params["stack"], x, cfg, ctx, self.sm,
                                     pos, enc_out=enc_out, cache=cache)
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return self._logits(params, x)[:, 0], cache

    def decode_step(self, params, cache, tokens: jax.Array,
                    positions: jax.Array,
                    batch: Optional[dict] = None) -> tuple[jax.Array, Pytree]:
        """One decode step. tokens (B,1) int32, positions (B,) int32.
        Returns (logits (B,V) fp32, new cache)."""
        cfg, ctx = self.cfg, self.ctx
        x = embed(tokens, params["embed"], ctx.compute_dtype)
        x, cache = tf.apply_stack_decode(params["stack"], x, cache, cfg, ctx,
                                         self.sm, positions)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x)[:, 0], cache


def build_model(cfg: ModelConfig, ctx: Optional[ParallelCtx] = None) -> Model:
    return Model(cfg, ctx or ParallelCtx())
