"""Model building blocks: norms, rotary embeddings, attention variants,
gated MLP — pure JAX, shape-static, scan- and pjit-friendly.

Attention comes in three execution strategies:
* full masked attention            — small sequences / smoke tests
* flash-style chunked attention    — online softmax, O(S * kc) live memory;
                                     used for 'global' layers at long S
* banded chunked local attention   — O(S * 2w) compute for sliding windows

All math runs in ``compute_dtype`` (bf16 by default) with fp32 softmax.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

Pytree = Any
NEG_INF = -2.0 ** 30   # large-but-finite mask value (bf16-safe)


@dataclass(frozen=True)
class ParallelCtx:
    """Execution context threaded through the model code."""

    batch_axes: tuple[str, ...] = ()     # mesh axes sharding the batch dim
    model_axis: Optional[str] = None     # tensor-parallel axis name
    model_size: int = 1                  # size of the model axis (for guards)
    use_kernels: bool = False            # pallas kernels (TPU) vs pure jnp
    remat: str = "none"                  # "none" | "block"
    compute_dtype: Any = jnp.bfloat16
    flash_block: int = 1024              # q/kv chunk for chunked attention
    flash_threshold: int = 8192          # use chunked attention when S >= this

    def shard(self, x: jax.Array, *spec) -> jax.Array:
        """with_sharding_constraint when running under a mesh, else no-op."""
        if self.model_axis is None and not self.batch_axes:
            return x
        try:
            return lax.with_sharding_constraint(
                x, jax.sharding.PartitionSpec(*spec))
        except (ValueError, RuntimeError):
            return x

    def head_axis(self, n_heads: int) -> Optional[str]:
        """The model axis iff the head count divides it — sharding 8 heads
        onto a 16-way axis pads 2x and triggers SPMD full-remat copies."""
        if self.model_axis is not None and n_heads % max(self.model_size, 1) == 0:
            return self.model_axis
        return None


# ---------------------------------------------------------------------------
# initializers / norms / embeddings
# ---------------------------------------------------------------------------
def _dense_init(key, shape, scale: float = 1.0, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def init_norm(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


def init_embedding(key, vocab: int, d: int) -> jax.Array:
    return _dense_init(key, (vocab, d), scale=1.0)


def embed(tokens: jax.Array, table: jax.Array, compute_dtype) -> jax.Array:
    x = table.astype(compute_dtype)[tokens]
    return x * jnp.asarray(math.sqrt(table.shape[1]), compute_dtype)


def unembed(x: jax.Array, table: jax.Array,
            softcap: Optional[float] = None) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------
def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*n_rep, hd)"""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def _softcap(scores: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def full_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   q_offset: int = 0) -> jax.Array:
    """Reference masked attention. q: (B,Sq,Hq,hd), k/v: (B,Skv,Hkv,hd)."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, Hq // Hkv)
    v = _repeat_kv(v, Hq // Hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = _softcap(scores, softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def flash_attention_jnp(q, k, v, *, causal: bool = True,
                        softcap: Optional[float] = None,
                        block: int = 1024) -> jax.Array:
    """Chunked online-softmax attention (flash-style) in pure jnp.

    q chunks are processed in parallel (extra batch dim); kv chunks are
    scanned sequentially with running (max, sum, acc) statistics, so peak
    live memory is O(S * block) instead of O(S^2).
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    n_rep = Hq // Hkv
    blk = min(block, S)
    assert S % blk == 0, (S, blk)
    n = S // blk
    scale = 1.0 / math.sqrt(hd)

    qc = q.reshape(B, n, blk, Hq, hd)
    kc = k.reshape(B, n, blk, Hkv, hd)
    vc = v.reshape(B, n, blk, Hkv, hd)

    def kv_step(carry, inputs):
        o_acc, m, l = carry                       # (B,n,blk,Hq,hd) fp32, ...
        kj, vj, j = inputs
        kj = _repeat_kv(kj, n_rep)                # (B,blk,Hq,hd)
        vj = _repeat_kv(vj, n_rep)
        s = jnp.einsum("bnqhd,bkhd->bnhqk", qc, kj).astype(jnp.float32) * scale
        s = _softcap(s, softcap)
        if causal:
            qpos = (jnp.arange(n)[:, None] * blk + jnp.arange(blk)[None, :])
            kpos = j * blk + jnp.arange(blk)
            mask = kpos[None, None, :] <= qpos[:, :, None]    # (n,blk,blk)
            s = jnp.where(mask[None, :, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))                # (B,n,H,blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bnhqk,bkhd->bnqhd", p.astype(q.dtype), vj)
        o_new = (o_acc * jnp.transpose(corr, (0, 1, 3, 2))[..., None]
                 + pv.astype(jnp.float32))
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, n, blk, Hq, hd), jnp.float32)
    m0 = jnp.full((B, n, Hq, blk), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, n, Hq, blk), jnp.float32)
    ks = jnp.moveaxis(kc, 1, 0)
    vs = jnp.moveaxis(vc, 1, 0)
    (o, m, l), _ = lax.scan(kv_step, (o0, m0, l0),
                            (ks, vs, jnp.arange(n)))
    l = jnp.transpose(l, (0, 1, 3, 2))[..., None]             # (B,n,blk,Hq,1)
    out = (o / jnp.maximum(l, 1e-20)).astype(q.dtype)
    return out.reshape(B, S, Hq, hd)


def local_attention_jnp(q, k, v, *, window: int,
                        softcap: Optional[float] = None) -> jax.Array:
    """Banded sliding-window attention: chunk size = window; each q chunk
    attends to its own + the previous chunk -> exact for span <= window,
    O(S * 2w * hd) compute."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    w = min(window, S)
    if S % w != 0:      # pad sequence to a chunk multiple
        pad = w - S % w
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = q.shape[1]
    n = Sp // w
    n_rep = Hq // Hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    qc = q.reshape(B, n, w, Hq, hd)
    kc = k.reshape(B, n, w, Hq, hd)
    vc = v.reshape(B, n, w, Hq, hd)
    # previous chunk (zeros before chunk 0)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kc], axis=2)                 # (B,n,2w,H,hd)
    v2 = jnp.concatenate([vprev, vc], axis=2)
    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qc, k2).astype(jnp.float32)
    s = s / math.sqrt(hd)
    s = _softcap(s, softcap)
    qpos = jnp.arange(w)
    kpos = jnp.arange(2 * w) - w                              # rel. to chunk start
    mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - w)
    first = jnp.arange(n) == 0                                # chunk 0 has no prev
    mask_first = mask & (kpos[None, :] >= 0)
    m = jnp.where(first[:, None, None], mask_first[None], mask[None])
    s = jnp.where(m[None, :, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bnhqk,bnkhd->bnqhd", p, v2)
    return o.reshape(B, Sp, Hq, hd)[:, :S]


def decode_attention(q, k_cache, v_cache, *, length_mask: jax.Array,
                     softcap: Optional[float] = None) -> jax.Array:
    """Single-token attention against a cache.
    q: (B,1,Hq,hd); caches: (B,Skv,Hkv,hd); length_mask: (B,Skv) bool."""
    B, _, Hq, hd = q.shape
    Hkv = k_cache.shape[2]
    k = _repeat_kv(k_cache, Hq // Hkv)
    v = _repeat_kv(v_cache, Hq // Hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    s = _softcap(s, softcap)
    s = jnp.where(length_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# ---------------------------------------------------------------------------
# attention layer (projections + cache handling)
# ---------------------------------------------------------------------------
def init_attention(key, cfg) -> dict[str, jax.Array]:
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(k1, (d, cfg.n_heads * hd)),
        "wk": _dense_init(k2, (d, cfg.n_kv * hd)),
        "wv": _dense_init(k3, (d, cfg.n_kv * hd)),
        "wo": _dense_init(k4, (cfg.n_heads * hd, d), scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd)
        p["k_norm"] = init_norm(hd)
    return p


def _project_qkv(p, x, cfg, positions, dt, use_rope: bool = True,
                 ctx: Optional["ParallelCtx"] = None):
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, cfg.n_kv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, cfg.n_kv, hd)
    if ctx is not None and (ctx.batch_axes or ctx.model_axis):
        ba = ctx.batch_axes or None
        q = ctx.shard(q, ba, None, ctx.head_axis(cfg.n_heads), None)
        kv_ax = ctx.head_axis(cfg.n_kv)
        k = ctx.shard(k, ba, None, kv_ax, None)
        v = ctx.shard(v, ba, None, kv_ax, None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_layer(p, x, cfg, ctx: ParallelCtx, kind: str,
                    positions: jax.Array) -> jax.Array:
    """Training/prefill attention. kind in {'global','local','enc'}."""
    dt = ctx.compute_dtype
    B, S, _ = x.shape
    causal = kind != "enc"
    q, k, v = _project_qkv(p, x, cfg, positions, dt, use_rope=True, ctx=ctx)
    if ctx.use_kernels:
        from repro.kernels import ops as kops
        window = cfg.window if kind == "local" else None
        o = kops.flash_attention(q, k, v, causal=causal, window=window,
                                 softcap=cfg.attn_softcap)
    elif kind == "local":
        o = local_attention_jnp(q, k, v, window=cfg.window,
                                softcap=cfg.attn_softcap)
    elif S >= ctx.flash_threshold and causal:
        o = flash_attention_jnp(q, k, v, causal=True,
                                softcap=cfg.attn_softcap,
                                block=ctx.flash_block)
    else:
        o = full_attention(q, k, v, causal=causal, softcap=cfg.attn_softcap)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return o @ p["wo"].astype(dt)


def attention_decode(p, x, cache, cfg, ctx: ParallelCtx, kind: str,
                     positions: jax.Array):
    """One-token decode. cache = {'k','v'}: (B, C, Hkv, hd); positions (B,).

    For 'local' layers the cache is a rolling buffer of size window;
    for 'global' it is the full sequence length.
    """
    dt = ctx.compute_dtype
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, positions[:, None], dt, use_rope=True,
                           ctx=ctx)
    C = cache["k"].shape[1]
    slot = positions % C if kind == "local" else positions
    idx = slot[:, None]                                     # (B,1)
    bidx = jnp.arange(B)[:, None]
    k_cache = cache["k"].at[bidx, idx].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, idx].set(v.astype(cache["v"].dtype))
    kpos = jnp.arange(C)[None, :]
    if kind == "local":
        # rolling buffer: valid entries are the last min(pos+1, window)
        valid = kpos < jnp.minimum(positions[:, None] + 1, C)
    else:
        valid = kpos <= positions[:, None]
    o = decode_attention(q, k_cache.astype(dt), v_cache.astype(dt),
                         length_mask=valid, softcap=cfg.attn_softcap)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"].astype(dt)
    return o, {"k": k_cache, "v": v_cache}


def init_attn_cache(cfg, B: int, S: int, kind: str,
                    dtype=jnp.bfloat16) -> dict[str, jax.Array]:
    C = min(cfg.window, S) if kind == "local" else S
    shape = (B, C, cfg.n_kv, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg, d_ff: Optional[int] = None) -> dict[str, jax.Array]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": _dense_init(k1, (d, ff)),
        "wu": _dense_init(k2, (d, ff)),
        "wd": _dense_init(k3, (ff, d), scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def mlp(p, x, cfg, ctx: ParallelCtx) -> jax.Array:
    dt = ctx.compute_dtype
    act = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    h = act(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    h = ctx.shard(h, ctx.batch_axes or None, None, ctx.model_axis)
    return h @ p["wd"].astype(dt)
