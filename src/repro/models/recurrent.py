"""Recurrent sequence-mixing blocks: RG-LRU (Griffin/recurrentgemma) and
RWKV6 (Finch) time-mix — pure JAX, with chunked formulations whose oracles
live in kernels/ref.py.

RG-LRU recurrence (per channel):
    r_t = sigmoid(alpha_r * x_t + beta_r)          (recurrence gate)
    i_t = sigmoid(alpha_i * x_t + beta_i)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Training/prefill uses jax.lax.associative_scan (parallel in S); decode is a
single fused step.  The gates are per-channel (diagonal) — a documented
simplification of Griffin's block-diagonal gate matrices.

RWKV6 time-mix: data-dependent per-channel decay w_t from a low-rank
projection; state S (dk x dv) per head:
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
Training/prefill uses an exact chunked form: within a chunk the pairwise
decay factors exp(lw_{t-1} - lw_i) are materialized per channel (c x c x dk),
inter-chunk contributions flow through the carried state.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .layers import ParallelCtx, _dense_init, init_norm, rms_norm

RG_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU block
# ---------------------------------------------------------------------------
def init_rglru(key, cfg) -> dict[str, jax.Array]:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 5)
    # Lambda init so a ~ U(0.9, 0.999)^c at r=1 (griffin's init range)
    u = jax.random.uniform(ks[4], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))        # softplus^-1(-log u)
    return {
        "w_x": _dense_init(ks[0], (d, w)),
        "w_gate": _dense_init(ks[1], (d, w)),
        "w_out": _dense_init(ks[2], (w, d), scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "conv_w": _dense_init(ks[3], (cfg.conv1d_size, w), scale=1.0),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "alpha_r": jnp.zeros((w,), jnp.float32),
        "beta_r": jnp.zeros((w,), jnp.float32),
        "alpha_i": jnp.zeros((w,), jnp.float32),
        "beta_i": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
    }


def _rglru_gates(p, u: jax.Array):
    """u: (..., W) post-conv activations -> (log_a, b_t) of the recurrence
    h_t = a_t h + b_t (all fp32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(p["alpha_r"] * uf + p["beta_r"])
    i = jax.nn.sigmoid(p["alpha_i"] * uf + p["beta_i"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def _causal_conv(p, x: jax.Array, ctx) -> jax.Array:
    """depthwise causal conv over (B, S, W) with kernel size K."""
    K = p["conv_w"].shape[0]
    out = jnp.zeros_like(x)
    for j in range(K):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted * p["conv_w"][K - 1 - j].astype(x.dtype)
    return out + p["conv_b"].astype(x.dtype)


def rglru_layer(p, x: jax.Array, cfg, ctx: ParallelCtx,
                return_cache: bool = False):
    """Training/prefill: (B, S, d) -> (B, S, d)."""
    dt = ctx.compute_dtype
    u_pre = x @ p["w_x"].astype(dt)               # (B, S, W) pre-conv
    u = _causal_conv(p, u_pre, ctx)
    a, b = _rglru_gates(p, u)
    if ctx.use_kernels:
        from repro.kernels import ops as kops
        h = kops.lru_scan(a, b)
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2
        _, h = lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    out = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    if return_cache:
        K = p["conv_w"].shape[0]
        conv_hist = u_pre[:, -(K - 1):]
        if conv_hist.shape[1] < K - 1:            # S < K-1: left-pad zeros
            pad = K - 1 - conv_hist.shape[1]
            conv_hist = jnp.pad(conv_hist, ((0, 0), (pad, 0), (0, 0)))
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_hist}
    return out


def rglru_decode(p, x: jax.Array, cache: dict, cfg, ctx: ParallelCtx):
    """One step. x: (B, 1, d); cache = {'h': (B,W) fp32, 'conv': (B,K-1,W)}."""
    dt = ctx.compute_dtype
    u = x @ p["w_x"].astype(dt)                   # (B, 1, W)
    K = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"].astype(dt), u], axis=1)  # (B,K,W)
    uc = jnp.einsum("bkw,kw->bw", hist, p["conv_w"].astype(dt))[:, None]
    uc = uc + p["conv_b"].astype(dt)
    a, b = _rglru_gates(p, uc)                    # (B,1,W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    out = (h[:, None].astype(dt) * gate) @ p["w_out"].astype(dt)
    new_cache = {"h": h, "conv": hist[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache


def init_rglru_cache(cfg, B: int, dtype=jnp.bfloat16) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((B, w), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv1d_size - 1, w), dtype)}


# ---------------------------------------------------------------------------
# RWKV6 time-mix
# ---------------------------------------------------------------------------
W_LORA_RANK = 64


def init_rwkv(key, cfg) -> dict[str, jax.Array]:
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    assert H * hd == d, "rwkv requires n_heads*head_dim == d_model"
    ks = jax.random.split(key, 9)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),     # token-shift mixes (r,k,v,w,g)
        "w_r": _dense_init(ks[0], (d, d)),
        "w_k": _dense_init(ks[1], (d, d)),
        "w_v": _dense_init(ks[2], (d, d)),
        "w_g": _dense_init(ks[3], (d, d)),
        "w_o": _dense_init(ks[4], (d, d), scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "w_lora_a": _dense_init(ks[5], (d, W_LORA_RANK)),
        "w_lora_b": _dense_init(ks[6], (W_LORA_RANK, d), scale=0.1),
        "w_bias": jnp.full((d,), -2.0, jnp.float32),   # decay bias (w ~ 0.87)
        "u": _dense_init(ks[7], (H, hd), scale=1.0),
        "ln_out": init_norm(d),
    }


def _rwkv_project(p, x: jax.Array, x_prev: jax.Array, cfg, dt):
    """Token-shift + projections. x, x_prev: (B, S, d)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    mu = p["mu"].astype(dt)
    xs = [x + mu[i] * (x_prev - x) for i in range(5)]
    r = (xs[0] @ p["w_r"].astype(dt)).reshape(B, S, H, hd)
    k = (xs[1] @ p["w_k"].astype(dt)).reshape(B, S, H, hd)
    v = (xs[2] @ p["w_v"].astype(dt)).reshape(B, S, H, hd)
    w_raw = (xs[3] @ p["w_lora_a"].astype(dt)) @ p["w_lora_b"].astype(dt)
    log_w = -jnp.exp(jnp.clip(w_raw.astype(jnp.float32)
                              + p["w_bias"], -8.0, 8.0))        # (B,S,d) <= 0
    log_w = log_w.reshape(B, S, H, hd)
    g = jax.nn.silu(xs[4] @ p["w_g"].astype(dt))
    return r, k, v, log_w, g


def _shift(x: jax.Array) -> jax.Array:
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


# "factored" (default): per-row decay factors, no pairwise tensor.
# "pairwise": materializes the (B, c, c, H, hd) decay tensor — kept for the
# §Perf A/B comparison and as the reference for the factored form's tests.
WKV_FORM = "factored"
# chunk length: pairwise cost grows as c^2, factored as c — the factored
# form makes larger chunks (fewer sequential scan steps, bigger MXU dots)
# affordable.  §Perf iteration settled on 64.
WKV_CHUNK = 64


def wkv_chunked(r, k, v, log_w, u, chunk: int = 16,
                state0: Optional[jax.Array] = None,
                form: Optional[str] = None):
    """Exact chunked WKV6 scan in factored form.

    r,k,v,log_w: (B, S, H, hd); u: (H, hd).  Returns (out, final_state) with
    state (B, H, hd_k, hd_v).

    Intra-chunk scores need pairwise decays exp(lwprev[t] - lwcum[i]); the
    naive form materializes a (B, c, c, H, hd) tensor — measured as the
    dominant HBM-traffic term of the rwkv prefill_32k dry-run cell.  Here
    the decay factors into per-row terms relative to the chunk end E:
        exp(lwprev[t] - lwcum[i]) = exp(lwprev[t] - E) * exp(E - lwcum[i])
    with exp(E - lwcum[i]) <= 1 always, and the true product <= 1, so the
    r-side exponent can be clamped at +40: whenever it exceeds 40 the
    k-side factor is < e^-40 and the product underflows to 0 either way.
    Memory drops from O(c^2 * hd) to O(c * hd) per chunk (~c x less HBM
    traffic); results stay exact to fp32 within ~e^-40.
    """
    B, S, H, hd = r.shape
    c = math.gcd(S, chunk) if S % min(chunk, S) else min(chunk, S)
    n = S // c
    f32 = jnp.float32
    rc = jnp.moveaxis(r.reshape(B, n, c, H, hd), 1, 0).astype(f32)
    kc = jnp.moveaxis(k.reshape(B, n, c, H, hd), 1, 0).astype(f32)
    vc = jnp.moveaxis(v.reshape(B, n, c, H, hd), 1, 0).astype(f32)
    lwc = jnp.moveaxis(log_w.reshape(B, n, c, H, hd), 1, 0).astype(f32)

    if state0 is None:
        state0 = jnp.zeros((B, H, hd, hd), f32)

    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)       # strictly causal (i < t)
    use_pairwise = (form or WKV_FORM) == "pairwise"

    def step(S0, inp):
        rt, kt, vt, lw = inp                           # (B,c,H,hd)
        lw_cum = jnp.cumsum(lw, axis=1)                # lw_1..t inclusive
        lw_prev = lw_cum - lw                          # lw_1..t-1
        E = lw_cum[:, -1:]                             # (B,1,H,hd), chunk total
        k_fac = kt * jnp.exp(E - lw_cum)               # decay i -> chunk end
        if use_pairwise:
            decay = jnp.exp(jnp.clip(
                lw_prev[:, :, None] - lw_cum[:, None, :], -60.0, 0.0))
            score = jnp.einsum("bthd,bihd,btihd->bhti", rt, kt, decay)
        else:
            # factored intra-chunk decay (no pairwise tensor):
            r_fac = rt * jnp.exp(jnp.minimum(lw_prev - E, 40.0))
            score = jnp.einsum("bthd,bihd->bhti", r_fac, k_fac)
        score = score * tri[None, None]
        # bonus (i == t) term with u
        bonus = jnp.einsum("bthd,hd,bthd->bth", rt, u.astype(f32), kt)
        o = jnp.einsum("bhti,bihd->bthd", score, vt)
        o = o + bonus[..., None] * vt
        # inter-chunk: r_t decayed back to chunk start hits carried state
        r_dec = rt * jnp.exp(lw_prev)
        o = o + jnp.einsum("bthk,bhkv->bthv", r_dec, S0)
        # state update: S' = diag(prod w) S0 + sum_i diag(decay_i->end) k_i v_i
        S1 = (S0 * jnp.exp(E[:, 0])[..., None]
              + jnp.einsum("bihk,bihv->bhkv", k_fac, vt))
        return S1, o

    state, outs = lax.scan(step, state0, (rc, kc, vc, lwc))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
    return out, state


def rwkv_layer(p, x: jax.Array, cfg, ctx: ParallelCtx,
               chunk: Optional[int] = None, return_cache: bool = False):
    dt = ctx.compute_dtype
    B, S, d = x.shape
    r, k, v, log_w, g = _rwkv_project(p, x, _shift(x), cfg, dt)
    o, state = wkv_chunked(r, k, v, log_w, p["u"],
                           chunk=chunk or WKV_CHUNK)
    o = rms_norm(o.reshape(B, S, d).astype(dt), p["ln_out"], cfg.norm_eps)
    out = (o * g) @ p["w_o"].astype(dt)
    if return_cache:
        return out, {"state": state, "x_prev": x[:, -1:]}
    return out


def rwkv_decode(p, x: jax.Array, cache: dict, cfg, ctx: ParallelCtx):
    """cache = {'state': (B,H,hd,hd) fp32, 'x_prev': (B,1,d)}."""
    dt = ctx.compute_dtype
    B, _, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    r, k, v, log_w, g = _rwkv_project(p, x, cache["x_prev"].astype(dt), cfg, dt)
    f32 = jnp.float32
    rt, kt, vt = (a[:, 0].astype(f32) for a in (r, k, v))
    w = jnp.exp(log_w[:, 0])                              # (B,H,hd)
    S0 = cache["state"]
    o = jnp.einsum("bhk,bhkv->bhv", rt, S0)
    bonus = jnp.einsum("bhk,hk,bhk->bh", rt, p["u"].astype(f32), kt)
    o = o + bonus[..., None] * vt
    S1 = S0 * w[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
    o = rms_norm(o.reshape(B, 1, d).astype(dt), p["ln_out"], cfg.norm_eps)
    out = (o * g) @ p["w_o"].astype(dt)
    return out, {"state": S1, "x_prev": x.astype(cache["x_prev"].dtype)}


def init_rwkv_cache(cfg, B: int, dtype=jnp.bfloat16) -> dict:
    H, hd = cfg.n_heads, cfg.hd
    return {"state": jnp.zeros((B, H, hd, hd), jnp.float32),
            "x_prev": jnp.zeros((B, 1, cfg.d_model), dtype)}
