"""Mixture-of-Experts layer: capacity-bounded top-k routing.

Two dispatch implementations, selected by ``cfg.moe_impl``:

* ``einsum`` (default under SPMD) — GShard-style one-hot dispatch/combine
  tensors of shape (G, g, E, C).  Einsums partition cleanly under GSPMD
  (groups over the batch axes, experts over the model axis = expert
  parallelism; XLA inserts the G<->E all-to-all at the constraint
  boundaries).  Memory cost: the one-hots scale as tokens * g*cf*k — the
  placement search (core/placement.py) sizes microbatches accordingly, and
  the dispatch einsum FLOPs are visible in the roofline's
  MODEL_FLOPS/HLO_FLOPs ratio (a documented GShard overhead).

* ``scatter`` — scatter-add dispatch / gather combine.  No one-hot tensors
  (memory-lean, no dispatch FLOPs) and exactly the same routing semantics,
  but GSPMD replicates scatters whose indices cross the expert sharding,
  so this path is for single-device execution and as the building block
  for a future shard_map expert-parallel kernel.

Routing semantics (identical in both): within a group of ``g`` tokens,
capacity C = ceil(g * cf * k / E); slot s of token t claims position
``running_count[expert]`` if below C, else the token-slot is dropped.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import ParallelCtx, _dense_init


def init_moe(key, cfg) -> dict[str, jax.Array]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _dense_init(k1, (d, E)),
        "wg": _dense_init(k2, (E, d, ff)),
        "wu": _dense_init(k3, (E, d, ff)),
        "wd": _dense_init(k4, (E, ff, d), scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def _route(logits: jax.Array, k: int):
    """logits (..., E) -> (gate_vals (..., k), expert_idx (..., k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / jnp.clip(vals.sum(-1, keepdims=True), 1e-9)   # renormalize
    return vals, idx


def _group(cfg, tokens: int, group: Optional[int]) -> tuple[int, int, int]:
    g = min(group if group is not None else cfg.moe_group, tokens)
    while tokens % g != 0:       # shapes are powers of two; this terminates
        g //= 2
    G = tokens // g
    C = max(1, math.ceil(g * cfg.capacity_factor * max(1, cfg.top_k)
                         / cfg.n_experts))
    return G, g, C


def _aux_loss(logits: jax.Array, idx: jax.Array, E: int) -> jax.Array:
    """Switch-style load-balance loss over the full batch."""
    probs_mean = jax.nn.softmax(logits.astype(jnp.float32), -1).mean((0, 1))
    frac = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), (0, 1))
    return E * jnp.sum(probs_mean * frac)


def _expert_ffn(p, xin: jax.Array, cfg, dt) -> jax.Array:
    """xin: (..., E, C, d) -> (..., E, C, d) through per-expert gated MLP."""
    act = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    h = act(jnp.einsum("...ecd,edf->...ecf", xin, p["wg"].astype(dt)))
    h = h * jnp.einsum("...ecd,edf->...ecf", xin, p["wu"].astype(dt))
    return jnp.einsum("...ecf,efd->...ecd", h, p["wd"].astype(dt))


# ---------------------------------------------------------------------------
# einsum (GShard) dispatch — GSPMD-partitionable
# ---------------------------------------------------------------------------
def moe_layer_einsum(p, x: jax.Array, cfg, ctx: ParallelCtx,
                     group: Optional[int] = None):
    dt = ctx.compute_dtype
    B, S, d = x.shape
    E, k = cfg.n_experts, max(1, cfg.top_k)
    G, g, C = _group(cfg, B * S, group)

    xg = x.reshape(G, g, d)
    logits = xg @ p["router"].astype(dt)                     # (G, g, E)
    gate_vals, idx = _route(logits, k)
    aux = _aux_loss(logits, idx, E)

    # per-slot dispatch with capacity-priority across slots
    disp = jnp.zeros((G, g, E, C), dt)
    comb = jnp.zeros((G, g, E, C), jnp.float32)
    prev_counts = jnp.zeros((G, E), jnp.int32)
    for slot in range(k):
        oh = jax.nn.one_hot(idx[..., slot], E, dtype=jnp.int32)   # (G, g, E)
        pos = jnp.cumsum(oh, axis=1) - oh + prev_counts[:, None, :]
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(pos, C, dtype=dt) * keep[..., None].astype(dt)
        slot_disp = oh[..., None].astype(dt) * pos_oh             # (G,g,E,C)
        disp = disp + slot_disp
        comb = comb + slot_disp.astype(jnp.float32) * gate_vals[..., slot][..., None, None]
        prev_counts = prev_counts + jnp.sum(oh * keep, axis=1)

    # dispatch -> expert FFN -> combine.  Sharding constraints implement EP:
    # groups shard over the batch axes, experts over the model axis — the
    # G<->E resharding of xin/out_e is the all-to-all of expert parallelism.
    ba = ctx.batch_axes or None
    disp = ctx.shard(disp, ba, None, ctx.model_axis, None)
    comb = ctx.shard(comb, ba, None, ctx.model_axis, None)
    xin = jnp.einsum("gsec,gsd->gecd", disp, xg)                 # (G, E, C, d)
    xin = ctx.shard(xin, ba, ctx.model_axis, None, None)
    out_e = _expert_ffn(p, xin, cfg, dt)                         # (G, E, C, d)
    out_e = ctx.shard(out_e, ba, ctx.model_axis, None, None)
    out = jnp.einsum("gsec,gecd->gsd", comb.astype(dt), out_e)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# scatter/gather dispatch — memory-lean, single-shard semantics
# ---------------------------------------------------------------------------
def moe_layer_scatter(p, x: jax.Array, cfg, ctx: ParallelCtx,
                      group: Optional[int] = None):
    dt = ctx.compute_dtype
    B, S, d = x.shape
    E, k = cfg.n_experts, max(1, cfg.top_k)
    G, g, C = _group(cfg, B * S, group)

    xg = x.reshape(G, g, d)
    logits = xg @ p["router"].astype(dt)
    gate_vals, idx = _route(logits, k)
    aux = _aux_loss(logits, idx, E)

    gidx = jnp.arange(G)[:, None]                            # (G, 1)
    prev_counts = jnp.zeros((G, E), jnp.int32)
    slot_pos, slot_keep = [], []
    for slot in range(k):
        e_s = idx[..., slot]                                 # (G, g)
        oh = jax.nn.one_hot(e_s, E, dtype=jnp.int32)         # (G, g, E)
        pos = jnp.cumsum(oh, axis=1) - oh + prev_counts[:, None, :]
        pos_tok = jnp.take_along_axis(pos, e_s[..., None], -1)[..., 0]
        keep = pos_tok < C
        slot_pos.append(jnp.where(keep, pos_tok, C))         # C = overflow bin
        slot_keep.append(keep)
        prev_counts = prev_counts + jnp.sum(oh * keep[..., None], axis=1)

    xin = jnp.zeros((G, E, C + 1, d), dt)
    for slot in range(k):
        xin = xin.at[gidx, idx[..., slot], slot_pos[slot]].add(
            jnp.where(slot_keep[slot][..., None], xg, 0))
    out_e = _expert_ffn(p, xin[:, :, :C], cfg, dt)           # (G, E, C, d)
    out_e = jnp.pad(out_e, ((0, 0), (0, 0), (0, 1), (0, 0)))  # overflow -> 0

    out = jnp.zeros((G, g, d), dt)
    for slot in range(k):
        y = out_e[gidx, idx[..., slot], slot_pos[slot]]      # (G, g, d)
        w = (gate_vals[..., slot] * slot_keep[slot])[..., None].astype(dt)
        out = out + y * w
    return out.reshape(B, S, d), aux


def moe_layer(p, x: jax.Array, cfg, ctx: ParallelCtx,
              group: Optional[int] = None) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  Impl chosen by cfg.moe_impl
    ('einsum' | 'scatter'); einsum is the SPMD-partitionable default."""
    impl = getattr(cfg, "moe_impl", "einsum")
    fn = moe_layer_scatter if impl == "scatter" else moe_layer_einsum
    return fn(p, x, cfg, ctx, group)
