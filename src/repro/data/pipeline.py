"""Synthetic token pipeline: deterministic, seedable, host-side generation
with background prefetch — stands in for a real corpus loader while keeping
the training loop's input path (host -> device_put w/ sharding) realistic.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    vocab: int
    seed: int = 0


def synthetic_batches(cfg: DataConfig, model_cfg=None) -> Iterator[dict]:
    """Markov-ish synthetic tokens (not uniform noise, so loss can fall)."""
    rng = np.random.default_rng(cfg.seed)
    # low-entropy transition structure: each token prefers a few successors
    fanout = 8
    nxt = rng.integers(0, cfg.vocab, size=(min(cfg.vocab, 4096), fanout))
    while True:
        toks = np.empty((cfg.batch, cfg.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=cfg.batch)
        pick = rng.integers(0, fanout, size=(cfg.batch, cfg.seq))
        jump = rng.random((cfg.batch, cfg.seq)) < 0.05
        randv = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq))
        for t in range(cfg.seq):
            follow = nxt[toks[:, t] % nxt.shape[0], pick[:, t]]
            toks[:, t + 1] = np.where(jump[:, t], randv[:, t], follow)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if model_cfg is not None and model_cfg.frontend == "vision":
            batch["labels"][:, :model_cfg.n_patches] = -1     # mask patch slots
            batch["patches"] = rng.standard_normal(
                (cfg.batch, model_cfg.n_patches, model_cfg.d_model)).astype(np.float32) * 0.02
        if model_cfg is not None and model_cfg.frontend == "audio":
            batch["frames"] = rng.standard_normal(
                (cfg.batch, model_cfg.src_seq, model_cfg.d_model)).astype(np.float32) * 0.02
        yield batch


def make_batch_specs(cfg: DataConfig, model_cfg=None) -> dict:
    specs = {"tokens": jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)}
    if model_cfg is not None and model_cfg.frontend == "vision":
        specs["patches"] = jax.ShapeDtypeStruct(
            (cfg.batch, model_cfg.n_patches, model_cfg.d_model), jnp.float32)
    if model_cfg is not None and model_cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (cfg.batch, model_cfg.src_seq, model_cfg.d_model), jnp.float32)
    return specs


class Prefetcher:
    """Background-thread prefetch of host batches (depth-bounded queue)."""

    def __init__(self, it: Iterator[dict], depth: int = 2,
                 sharding: Optional[jax.sharding.Sharding] = None) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._sharding = sharding
        self._stop = False

        def worker():
            for item in it:
                if self._stop:
                    return
                if self._sharding is not None:
                    item = jax.tree.map(
                        lambda x: jax.device_put(x, self._sharding), item)
                self._q.put(item)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self) -> None:
        self._stop = True
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
