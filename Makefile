PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-compile

# tier-1 verification (see ROADMAP.md)
test:
	python -m pytest -x -q

# all paper-figure benchmarks
bench:
	python -m benchmarks.run

# object-path vs compiled-path engine throughput; writes BENCH_graph_compile.json
bench-compile:
	python -m benchmarks.graph_compile
