PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-compile bench-session

# tier-1 verification (see ROADMAP.md)
test:
	python -m pytest -x -q

# all paper-figure benchmarks
bench:
	python -m benchmarks.run

# object-path vs compiled-path engine throughput; writes BENCH_graph_compile.json
bench-compile:
	python -m benchmarks.graph_compile

# frontier-batched vs sequential mapping + mult=64 delta-churn run; writes
# BENCH_session.json and fails on a >20% mapped-tasks/sec regression vs the
# checked-in baseline
bench-session:
	python -m benchmarks.graph_compile session --check
