PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-compile bench-session bench-des bench-des-smoke

# tier-1 verification (see ROADMAP.md)
test:
	python -m pytest -x -q

# all paper-figure benchmarks
bench:
	python -m benchmarks.run

# object-path vs compiled-path engine throughput; writes BENCH_graph_compile.json
bench-compile:
	python -m benchmarks.graph_compile

# frontier-batched vs sequential mapping + mult=64 delta-churn run; writes
# BENCH_session.json and fails on a >20% mapped-tasks/sec regression vs the
# checked-in baseline
bench-session:
	python -m benchmarks.graph_compile session --check

# array-native DES engine vs the seed heapq loop at mult=8 oversubscribed,
# plus the mult=128 lazy snapshot build and the fused wave-batched mapping
# walk over the whole fleet; writes BENCH_des.json and fails on a >20%
# events/sec or mapped-tasks/sec regression, a <3x speedup vs the seed
# loop, or mult=128 mapping breaching its absolute 2 s budget
bench-des:
	python -m benchmarks.des --check

# seconds-scale DES parity + mapping-throughput smoke (CI)
bench-des-smoke:
	python -m benchmarks.des --smoke
