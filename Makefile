PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-compile bench-session bench-des bench-des-smoke \
        bench-churn-smoke bench-serve bench-serve-smoke

# tier-1 verification (see ROADMAP.md)
test:
	python -m pytest -x -q

# all paper-figure benchmarks
bench:
	python -m benchmarks.run

# object-path vs compiled-path engine throughput; writes BENCH_graph_compile.json
bench-compile:
	python -m benchmarks.graph_compile

# frontier-batched vs sequential mapping + mult=64 delta-churn run; writes
# BENCH_session.json and fails on a >20% mapped-tasks/sec regression vs the
# checked-in baseline
bench-session:
	python -m benchmarks.graph_compile session --check

# array-native DES engine vs the seed heapq loop at mult=8 oversubscribed,
# plus the mult=128 lazy snapshot build and the group-sharded wave-batched
# mapping walk over the whole mult=128 and mult=256 fleets (shard-count
# rows + sharded-vs-fused bit-identity at mult=8); writes BENCH_des.json
# and fails on a >20% events/sec or mapped-tasks/sec (x128 or x256)
# regression, a <3x speedup vs the seed loop, or the absolute mapping
# walls (x128 3 s, x256 12 s)
bench-des:
	python -m benchmarks.des --check

# seconds-scale DES parity + mapping-throughput smoke, incl. the mult=8
# sharded-walk parity assert (CI)
bench-des-smoke:
	python -m benchmarks.des --smoke

# bandwidth-volatile wireless-edge scenario at mult=8: seeded uplink
# degrade/recover Churn waves interleaved with mapping, driven under both
# the group-sharded walk and the fused oracle — asserts bit-identical
# placements and zero route-topology copies (CI)
bench-churn-smoke:
	python -m benchmarks.des --churn-smoke

# online serving continuum: seeded Poisson + diurnal traffic through the
# session-resident ServeLoop at mult=8 and mult=64; writes BENCH_serve.json
# (requests/sec, p99/p999 latency, per-tenant SLA attainment) and fails on
# a >20% wall_rps or p99 regression, a >2-point SLA-attainment drop, or
# any full TimelineEngine rebuild after warmup (engine_opens != 1)
bench-serve:
	python -m benchmarks.serve --check

# seconds-scale serving-loop smoke at mult=2 (CI)
bench-serve-smoke:
	python -m benchmarks.serve --smoke
