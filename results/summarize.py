"""Render EXPERIMENTS.md tables from results/dryrun.json."""
import json
import sys

r = json.load(open("results/dryrun.json"))

print("### Baseline roofline table (single-pod 16x16 unless noted)\n")
print("| arch | shape | mesh | plan | peak GB | fits | Tc s | Tm s | Tl s | bound | useful | frac |")
print("|---|---|---|---|---|---|---|---|---|---|---|---|")
order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
for mesh in ("single", "multi"):
    for arch in sorted({v["arch"] for v in r.values()}):
        for shape in order:
            key = f"{arch}|{shape}|{mesh}|baseline"
            if key not in r:
                continue
            v = r[key]
            if v["status"] == "skipped":
                print(f"| {arch} | {shape} | {mesh} | — | — | — | — | — | — | "
                      f"skip: quadratic attn | — | — |")
                continue
            if v["status"] != "ok":
                print(f"| {arch} | {shape} | {mesh} | FAILED {v['error'][:40]} |")
                continue
            p = v["plan"]
            rf = v["roofline"]
            plan = (f"{p['policy']}/mb{p['microbatches']}"
                    f"{'/r' if p['remat']=='block' else ''}"
                    f"{'/bf16' if p['param_dtype']!='float32' else ''}"
                    f"{'/c-' + p['cache_mode'] if v['shape'] != 'train_4k' else ''}")
            print(f"| {arch} | {shape} | {mesh} | {plan} "
                  f"| {v['memory']['peak_gb']:.1f} "
                  f"| {'Y' if v['memory']['fits_hbm'] else 'N'} "
                  f"| {rf['t_compute_s']:.3f} | {rf['t_memory_s']:.3f} "
                  f"| {rf['t_collective_s']:.3f} | {rf['bottleneck'][:4]} "
                  f"| {rf['useful_flops_ratio']:.2f} "
                  f"| {rf['roofline_fraction']:.3f} |")

print("\n### Variants (hillclimb)\n")
for key, v in sorted(r.items()):
    if v.get("variant", "baseline") == "baseline" or v["status"] != "ok":
        continue
    rf = v["roofline"]
    base = r.get(f"{v['arch']}|{v['shape']}|{v['mesh']}|baseline", {})
    brf = base.get("roofline", {})
    print(f"- `{key}`: Tc={rf['t_compute_s']:.3f}s Tm={rf['t_memory_s']:.3f}s "
          f"Tl={rf['t_collective_s']:.3f}s peak={v['memory']['peak_gb']:.1f}GB "
          f"(baseline Tm={brf.get('t_memory_s', 0):.3f}s "
          f"Tl={brf.get('t_collective_s', 0):.3f}s "
          f"peak={base.get('memory', {}).get('peak_gb', 0):.1f}GB)")
