"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import platform
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core import (AcePolicy, LatsPolicy, NoSlowdown, OrchestratorPolicy,
                        Runtime, Traverser, build_orchestrators,
                        build_testbed, heye_traverser)


@dataclass
class Row:
    name: str
    value: float
    unit: str = ""
    extra: dict = field(default_factory=dict)


class Table:
    """A paper table/figure reproduction: rows of (metric, value)."""

    def __init__(self, figure: str, title: str) -> None:
        self.figure = figure
        self.title = title
        self.rows: list[Row] = []
        self.t0 = time.time()

    def add(self, name: str, value: float, unit: str = "", **extra) -> None:
        self.rows.append(Row(name, float(value), unit, extra))

    def print_csv(self) -> None:
        dt = time.time() - self.t0
        print(f"# {self.figure}: {self.title}  [{dt:.1f}s]")
        for r in self.rows:
            extras = "".join(f",{k}={v}" for k, v in r.extra.items())
            print(f"{self.figure},{r.name},{r.value:.6g},{r.unit}{extras}")

    def get(self, name: str) -> float:
        return next(r.value for r in self.rows if r.name == name)


# ---------------------------------------------------------------------------
# BENCH_*.json schema: one writer + one gate checker for every benchmark
# ---------------------------------------------------------------------------
def bench_payload(t: Table, smoke: bool = False,
                  gates: dict | None = None,
                  extra_meta: dict | None = None) -> dict:
    """The shared ``BENCH_*.json`` layout every benchmark writes:

    ``figure``/``smoke``      what ran (smoke payloads are never written),
    ``meta``                  run metadata (host shape + wall time) so a
                              checked-in baseline carries the machine it
                              was measured on, plus any benchmark-supplied
                              ``extra_meta`` (e.g. the run's route-table
                              copy counters),
    ``gates``                 the regression thresholds the ``--check``
                              mode enforced when the file was written
                              (documentation for the next reader, and the
                              CI diff shows threshold changes explicitly),
    ``rows``                  ``{name: {value, unit, **extra}}``.
    """
    meta = {
        "python": platform.python_version(),
        "machine": platform.machine(),
        "cpus": os.cpu_count(),
        "wall_s": round(time.time() - t.t0, 2),
    }
    meta.update(extra_meta or {})
    return {
        "figure": t.figure,
        "smoke": smoke,
        "meta": meta,
        "gates": gates or {},
        "rows": {r.name: {"value": r.value, "unit": r.unit, **r.extra}
                 for r in t.rows},
    }


def write_payload(t: Table, path: Path, smoke: bool = False,
                  gates: dict | None = None,
                  extra_meta: dict | None = None) -> None:
    """Serialize ``t`` to ``path`` in the shared schema (no-op in smoke
    mode: smoke rows are tiny variants and must never become baselines)."""
    if smoke:
        return
    path.write_text(
        json.dumps(bench_payload(t, smoke, gates, extra_meta), indent=2)
        + "\n")


def check_gate(t: Table, baseline: dict | None, name: str,
               floor_ratio: float | None = None,
               ceil_ratio: float | None = None,
               floor_delta: float | None = None,
               note: str = "") -> str | None:
    """One baseline-relative regression gate; returns the failure message
    (or None).  Exactly one of the three thresholds applies:

    * ``floor_ratio=0.8``   fail when new < 80% of baseline (throughput),
    * ``ceil_ratio=1.2``    fail when new > 120% of baseline (latency),
    * ``floor_delta=0.02``  fail when new < baseline - 0.02 (fractions).

    Missing baseline / missing row means no gate (first run, renamed row).
    """
    if baseline is None:
        return None
    old = baseline.get("rows", {}).get(name, {}).get("value")
    if old is None:
        return None
    new = t.get(name)
    suffix = f" ({note})" if note else ""
    if floor_ratio is not None and new < floor_ratio * old:
        return (f"REGRESSION: {name} {new:.6g} < {floor_ratio:.0%} of "
                f"baseline {old:.6g}{suffix}")
    if ceil_ratio is not None and new > ceil_ratio * old:
        return (f"REGRESSION: {name} {new:.6g} > {ceil_ratio:.0%} of "
                f"baseline {old:.6g}{suffix}")
    if floor_delta is not None and new < old - floor_delta:
        return (f"REGRESSION: {name} {new:.6g} < baseline {old:.6g} - "
                f"{floor_delta:g}{suffix}")
    return None


def fail_gates(t: Table, failures: list) -> None:
    """Print the CSV + every non-None gate failure, then exit 1."""
    import sys
    failures = [f for f in failures if f]
    if failures:
        t.print_csv()
        for f in failures:
            print(f)
        sys.exit(1)


def make_policy(name: str, tb):
    """Fresh policy over a fresh ledger for testbed ``tb``."""
    if name == "heye":
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        return OrchestratorPolicy(root)
    blind = Traverser(tb.graph, slowdown=NoSlowdown(tb.graph))
    if name == "ace":
        return AcePolicy(tb.graph, blind)
    if name == "lats":
        return LatsPolicy(tb.graph, blind)
    raise ValueError(name)


def mean_latency(stats, cfg) -> float:
    return float(np.mean([stats.timeline.latency(t) for t in cfg]))
