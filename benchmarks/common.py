"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import (AcePolicy, LatsPolicy, NoSlowdown, OrchestratorPolicy,
                        Runtime, Traverser, build_orchestrators,
                        build_testbed, heye_traverser)


@dataclass
class Row:
    name: str
    value: float
    unit: str = ""
    extra: dict = field(default_factory=dict)


class Table:
    """A paper table/figure reproduction: rows of (metric, value)."""

    def __init__(self, figure: str, title: str) -> None:
        self.figure = figure
        self.title = title
        self.rows: list[Row] = []
        self.t0 = time.time()

    def add(self, name: str, value: float, unit: str = "", **extra) -> None:
        self.rows.append(Row(name, float(value), unit, extra))

    def print_csv(self) -> None:
        dt = time.time() - self.t0
        print(f"# {self.figure}: {self.title}  [{dt:.1f}s]")
        for r in self.rows:
            extras = "".join(f",{k}={v}" for k, v in r.extra.items())
            print(f"{self.figure},{r.name},{r.value:.6g},{r.unit}{extras}")

    def get(self, name: str) -> float:
        return next(r.value for r in self.rows if r.name == name)


def make_policy(name: str, tb):
    """Fresh policy over a fresh ledger for testbed ``tb``."""
    if name == "heye":
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        return OrchestratorPolicy(root)
    blind = Traverser(tb.graph, slowdown=NoSlowdown(tb.graph))
    if name == "ace":
        return AcePolicy(tb.graph, blind)
    if name == "lats":
        return LatsPolicy(tb.graph, blind)
    raise ValueError(name)


def mean_latency(stats, cfg) -> float:
    return float(np.mean([stats.timeline.latency(t) for t in cfg]))
