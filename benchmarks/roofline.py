"""Roofline report over the dry-run artifact (§Roofline deliverable).

Reads results/dryrun.json (written by repro.launch.dryrun) and prints the
per-(arch x shape x mesh) three-term roofline table: compute / memory /
collective seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and
the roofline fraction.  No compilation happens here — run the dry-run first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import json
import os

from .common import Table

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun.json")


def run() -> Table:
    t = Table("roofline", "three-term roofline per (arch x shape x mesh)")
    if not os.path.exists(RESULTS):
        t.add("missing_results", -1, f"run dryrun first ({RESULTS})")
        return t
    with open(RESULTS) as f:
        recs = json.load(f)
    n_ok = n_skip = n_fail = 0
    for key, rec in sorted(recs.items()):
        name = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
        if rec.get("variant", "baseline") != "baseline":
            continue
        if rec["status"] == "skipped":
            n_skip += 1
            continue
        if rec["status"] != "ok":
            n_fail += 1
            t.add(f"{name}_FAILED", -1, rec.get("error", "?"))
            continue
        n_ok += 1
        r = rec["roofline"]
        t.add(name, r["roofline_fraction"], "frac",
              Tc_ms=round(r["t_compute_s"] * 1e3, 2),
              Tm_ms=round(r["t_memory_s"] * 1e3, 2),
              Tl_ms=round(r["t_collective_s"] * 1e3, 2),
              bound=r["bottleneck"],
              useful=round(r["useful_flops_ratio"], 3),
              peak_gb=round(rec["memory"]["peak_gb"], 1),
              fits=rec["memory"]["fits_hbm"])
    t.add("cells_ok", n_ok, "cells")
    t.add("cells_skipped", n_skip, "cells (long_500k on quadratic archs)")
    t.add("cells_failed", n_fail, "cells")
    return t


if __name__ == "__main__":
    run().print_csv()
