"""Fig. 13 reproduction: weak and strong scaling.

Weak-1 (mining): sensors, edges and servers double together; completion
time per reading should stay flat (~81 ms in the paper).
Weak-2 (VR): edges and servers double together; QoS failure per frame
should stay low.
Strong (mining): total sensors fixed; devices double; completion time
drops until the longest task (KNN on Xavier NX) limits.
"""
from __future__ import annotations

import numpy as np

from repro.core import Runtime, build_testbed, mining_workload, vr_workload
from repro.core.workloads import vr_frame_qos_failure

from .common import Table, make_policy


def mining_counts(mult: int) -> tuple[dict, dict]:
    """Fig. 13 mining topology at 1/8th of the paper's ratios, scaled by
    ``mult`` — mult=8 is the paper's real 100-sensor/80-edge/24-server scale,
    reachable now that evaluation runs on the compiled HW-GRAPH engine."""
    ec = {"orin_agx": 3 * mult, "xavier_agx": 3 * mult,
          "orin_nano": 2 * mult, "xavier_nx": 2 * mult}
    sc = {"server1": mult, "server2": mult, "server3": mult}
    return ec, sc


def _mining_completion(tb, n_sensors, n_readings=2, seed=0):
    cfg = mining_workload(tb, n_sensors=n_sensors, n_readings=n_readings)
    stats = Runtime(tb.graph, seed=seed).run(cfg, make_policy("heye", tb))
    # completion time of a reading = latency of its slowest ML task
    per_reading: dict[tuple, float] = {}
    for t in cfg:
        key = (t.attrs["sensor"], round(t.release_time, 6))
        per_reading[key] = max(per_reading.get(key, 0.0),
                               stats.timeline.latency(t))
    return float(np.mean(list(per_reading.values()))), stats, cfg


def run() -> Table:
    t = Table("fig13", "weak/strong scaling")

    # ---- weak scaling 1: mining -------------------------------------------
    # paper starts at 100 sensors / 80 edges / 24 servers; the series starts
    # 8x below that and doubles up to mult=8 — the paper's real ratios,
    # restored by the compiled-array evaluation path.
    for mult in (1, 2, 4, 8):
        ec, sc = mining_counts(mult)
        tb = build_testbed(edge_counts=ec, server_counts=sc)
        comp, _, _ = _mining_completion(tb, n_sensors=12 * mult)
        t.add(f"weak_mining_x{mult}_completion", comp * 1e3, "ms",
              devices=sum(ec.values()) + sum(sc.values()))

    # ---- weak scaling 2: VR ------------------------------------------------
    for mult in (1, 2, 4):
        ec = {"orin_agx": mult, "xavier_agx": mult, "orin_nano": mult,
              "xavier_nx": mult}
        sc = {"server1": mult, "server2": mult}
        tb = build_testbed(edge_counts=ec, server_counts=sc)
        cfg = vr_workload(tb, n_frames=6)
        stats = Runtime(tb.graph, seed=0).run(cfg, make_policy("heye", tb))
        t.add(f"weak_vr_x{mult}_qos_fail",
              vr_frame_qos_failure(cfg, stats.timeline) * 100, "%",
              edges=4 * mult)

    # ---- strong scaling: mining -------------------------------------------
    # fixed total of 144 sensor bursts: the smallest system is overloaded
    # (queueing dominates); doubling devices cuts completion until the
    # longest contended task (KNN on Xavier NX) becomes the floor
    n_sensors = 144
    comps = []
    for mult in (1, 2, 4, 8):
        ec = {"orin_agx": mult, "xavier_agx": mult,
              "orin_nano": mult, "xavier_nx": mult}
        sc = {"server1": mult, "server2": mult}
        tb = build_testbed(edge_counts=ec, server_counts=sc)
        comp, _, _ = _mining_completion(tb, n_sensors=n_sensors, n_readings=1)
        comps.append(comp)
        t.add(f"strong_mining_x{mult}_completion", comp * 1e3, "ms",
              devices=4 * mult + 2 * mult)
    t.add("strong_speedup_x8_over_x1", comps[0] / comps[-1], "x")
    # the floor: the longest standalone task (KNN on the slowest edge) —
    # completion cannot drop below it (paper: KNN on Xavier NX limits)
    from repro.core.topology import _ML_EDGE
    floor = _ML_EDGE["knn"]["xavier_nx"]["gpu"] * 1e-3
    t.add("strong_floor_knn_nx", floor * 1e3, "ms")
    t.add("strong_final_over_floor", comps[-1] / floor, "x")
    return t


if __name__ == "__main__":
    run().print_csv()
