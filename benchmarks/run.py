"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all figures
    PYTHONPATH=src python -m benchmarks.run fig11      # one figure

Prints ``figure,metric,value,unit[,extras]`` CSV per module plus a summary
of the headline claims vs the paper.
"""
from __future__ import annotations

import sys
import time

MODULES = ("contention", "validation", "vr_perf", "dynamic", "scaling",
           "overhead", "strategies", "roofline", "graph_compile")
FIG_OF = {"contention": "fig2", "validation": "fig10", "vr_perf": "fig11",
          "dynamic": "fig12", "scaling": "fig13", "overhead": "fig14",
          "strategies": "fig15", "roofline": "roofline",
          "graph_compile": "graph_compile"}


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    wanted = set(args) if args else None
    tables = {}
    t0 = time.time()
    for mod_name in MODULES:
        fig = FIG_OF[mod_name]
        if wanted and fig not in wanted and mod_name not in wanted:
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        table = mod.run()
        table.print_csv()
        tables[fig] = table
        print()

    # headline summary vs the paper's claims
    if not wanted:
        print("# headline claims vs paper")
        try:
            print(f"headline,fig2_max_calibration_err,"
                  f"{max(r.extra['err_pct'] for r in tables['fig2'].rows)},%")
            print(f"headline,prediction_err_heye,"
                  f"{tables['fig10'].get('mean_err_heye'):.2f},% (paper 3.2)")
            print(f"headline,prediction_err_blind,"
                  f"{tables['fig10'].get('mean_err_ace'):.2f},% (paper 27.4)")
            print(f"headline,latency_improvement_max,"
                  f"{tables['fig11'].get('improvement_max'):.1f},% "
                  f"(paper up-to-47)")
            print(f"headline,frame_qos_heye,"
                  f"{tables['fig11'].get('frame_qos_failure_heye'):.1f},%")
            print(f"headline,sched_overhead_mining,"
                  f"{tables['fig14'].get('mining_x1_overhead'):.2f},% "
                  f"(paper <2)")
            print(f"headline,sched_overhead_vr,"
                  f"{tables['fig14'].get('vr_x1_overhead'):.2f},% (paper ~4)")
        except StopIteration:
            pass
    print(f"# total {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
