"""Object-path vs compiled-path HW-GRAPH evaluation throughput.

Measures the three hot paths the array-native engine (core/compiled.py)
vectorized, against a faithful replica of the seed's per-pair object-graph
algorithms, on the Fig. 13 mining topology at mult=4 — and then runs the
weak-scaling mining row at mult=8, the paper's real 100-sensor/80-edge/
24-server ratios that the object path was too slow to reach:

* ``slowdown_pool``    — joint co-run factors of a fleet-wide pool (what the
  Traverser recomputes at every contention-interval boundary)
* ``slowdown_pairs``   — all pairwise co-run factors (``slowdown_matrix``)
* ``constraint_check`` — an ORC scoring every candidate PU of a busy device
  including the Alg. 1 l.15 re-check of active tasks' constraints

Emits ``BENCH_graph_compile.json`` next to the repo root so the perf
trajectory is tracked from PR to PR.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (ActiveLedger, DecoupledSlowdown, Runtime,
                        build_orchestrators, build_testbed, heye_params,
                        heye_traverser, mining_workload)
from repro.core.topology import make_task

from .common import Table, make_policy
from .scaling import _mining_completion, mining_counts

_JSON = Path(__file__).resolve().parent.parent / "BENCH_graph_compile.json"


class ObjectPathSlowdown:
    """The seed's pre-compilation algorithm, kept verbatim as the baseline:
    per-pair compute-path scans with a dict cache, Python dict loops for
    pressure aggregation."""

    def __init__(self, graph, params=None):
        self.graph = graph
        self.params = params or heye_params()
        self._shared_cache: dict[tuple[str, str], str | None] = {}

    def nearest_shared(self, pu_a, pu_b):
        key = (pu_a, pu_b) if pu_a <= pu_b else (pu_b, pu_a)
        if key not in self._shared_cache:
            pa = self.graph.nodes[pu_a].get_compute_path()
            pb = set(self.graph.nodes[pu_b].get_compute_path())
            self._shared_cache[key] = next((r for r in pa if r in pb), None)
        return self._shared_cache[key]

    def _pressure_term(self, beta, x):
        if x <= 0.0 or beta <= 0.0:
            return 0.0
        return beta * x * (1.0 + self.params.superlinear * x)

    def _mem_usage(self, task, pu_name):
        u = task.usage.get("mem", 1.0)
        cap = self.graph.nodes[pu_name].attrs.get("mem_usage_cap")
        return min(u, cap) if cap is not None else u

    def factor(self, task, pu_name, coruns):
        p = self.params
        f = 1.0
        pu = self.graph.nodes[pu_name]
        pu_class = pu.attrs.get("pu_class_kind",
                                pu.attrs.get("pu_class", "default"))
        mt_pressure = 0.0
        res_pressure: dict[str, float] = {}
        for other, other_pu in coruns:
            if other.uid == task.uid:
                continue
            if other_pu == pu_name:
                mt_pressure += other.usage.get("pu", 1.0)
            else:
                shared = self.nearest_shared(pu_name, other_pu)
                if shared is None:
                    continue
                rclass = self.graph.nodes[shared].attrs.get("rclass", "dram")
                res_pressure[rclass] = (res_pressure.get(rclass, 0.0)
                                        + self._mem_usage(other, other_pu))
        if mt_pressure > 0.0:
            f *= 1.0 + self._pressure_term(p.mt(pu_class), mt_pressure
                                           ) * task.usage.get("pu", 1.0)
        for rclass, x in res_pressure.items():
            f *= 1.0 + self._pressure_term(p.beta.get(rclass, 0.3), x
                                           ) * self._mem_usage(task, pu_name)
        return max(1.0, f)


def _fleet_pool(tb, per_device=4):
    kinds = ("dnn", "knn", "svm", "mlp", "render", "encode")
    pool = []
    for i, e in enumerate(tb.edges):
        for j, short in enumerate(("cpu0", "gpu", "dla", "vic")[:per_device]):
            pool.append((make_task(kinds[(i + j) % len(kinds)]),
                         f"{e}.{short}"))
    for s in tb.servers:
        pool.append((make_task("knn"), f"{s}.gpu"))
        pool.append((make_task("mlp"), f"{s}.cpu"))
    return pool


def _time(fn, reps):
    fn()                                   # warmup (jit/caches/compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run() -> Table:
    t = Table("graph_compile", "object vs compiled HW-GRAPH engine")
    ec, sc = mining_counts(4)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    g = tb.graph
    obj = ObjectPathSlowdown(g)
    sd = DecoupledSlowdown(g, heye_params())
    pool = _fleet_pool(tb)

    # parity first: the two paths must agree before their speeds mean anything
    want = np.array([obj.factor(tk, pu, pool) for tk, pu in pool])
    np.testing.assert_allclose(sd.factor_batch(pool), want,
                               atol=1e-9, rtol=1e-9)

    # --- joint factors of the whole pool (contention-interval repricing) ----
    obj_s = _time(lambda: [obj.factor(tk, pu, pool) for tk, pu in pool], 5)
    cmp_s = _time(lambda: sd.factor_batch(pool), 5)
    t.add("slowdown_pool_object", obj_s * 1e3, "ms", n=len(pool))
    t.add("slowdown_pool_compiled", cmp_s * 1e3, "ms", n=len(pool))
    t.add("slowdown_pool_speedup", obj_s / cmp_s, "x")

    # --- all pairwise co-run factors ---------------------------------------
    obj_pairs = _time(lambda: [[obj.factor(ti, pi, [(tj, pj)])
                                for tj, pj in pool] for ti, pi in pool], 2)
    cmp_pairs = _time(lambda: sd.slowdown_matrix(pool), 2)
    t.add("slowdown_pairs_object", obj_pairs * 1e3, "ms", n=len(pool))
    t.add("slowdown_pairs_compiled", cmp_pairs * 1e3, "ms", n=len(pool))
    t.add("slowdown_pairs_speedup", obj_pairs / cmp_pairs, "x")

    # --- ORC constraint check over every candidate PU of a busy device -----
    trav = heye_traverser(g)
    ledger = ActiveLedger()
    root = build_orchestrators(g, trav, ledger=ledger)
    dev = tb.edges[0]
    orc = root.find_device_orc(dev)
    active = [(make_task(k, origin=dev, deadline=0.5), f"{dev}.{pu}")
              for k, pu in (("dnn", "gpu"), ("dnn", "gpu"), ("svm", "cpu0"),
                            ("mlp", "cpu1"), ("encode", "vic"),
                            ("dnn", "dla"), ("render", "gpu"))]
    for tk, pu in active:
        ledger.add(tk, pu, trav.predict_task(tk, pu, active), now=0.0)
    task = make_task("render", origin=dev, deadline=0.1)

    def object_check():
        # the seed's per-candidate flow: one factor for the newcomer plus a
        # re-factor of every active task, per candidate PU
        out = []
        for pu in orc.leaf_pus:
            f_new = obj.factor(task, pu, active)
            pool_c = active + [(task, pu)]
            refac = [obj.factor(tk, p, pool_c) for tk, p in active]
            out.append((f_new, refac))
        return out

    obj_chk = _time(object_check, 20)
    cmp_chk = _time(lambda: orc._check_candidates(task, orc.leaf_pus, 0.0), 20)
    t.add("constraint_check_object", obj_chk * 1e6, "us",
          candidates=len(orc.leaf_pus), active=len(active))
    t.add("constraint_check_compiled", cmp_chk * 1e6, "us",
          candidates=len(orc.leaf_pus), active=len(active))
    t.add("constraint_check_speedup", obj_chk / cmp_chk, "x")

    # --- weak scaling restored to the paper's real ratios (mult=8) ---------
    wall = {}
    for mult in (4, 8):
        ecm, scm = mining_counts(mult)
        tbm = build_testbed(edge_counts=ecm, server_counts=scm)
        t0 = time.perf_counter()
        comp, _, _ = _mining_completion(tbm, n_sensors=12 * mult)
        wall[mult] = time.perf_counter() - t0
        t.add(f"weak_mining_x{mult}_completion", comp * 1e3, "ms",
              devices=sum(ecm.values()) + sum(scm.values()),
              wall_s=round(wall[mult], 2))

    payload = {
        "figure": t.figure,
        "rows": {r.name: {"value": r.value, "unit": r.unit, **r.extra}
                 for r in t.rows},
    }
    _JSON.write_text(json.dumps(payload, indent=2) + "\n")
    return t


if __name__ == "__main__":
    run().print_csv()
