"""Object-path vs compiled-path HW-GRAPH evaluation throughput.

Measures the three hot paths the array-native engine (core/compiled.py)
vectorized, against a faithful replica of the seed's per-pair object-graph
algorithms, on the Fig. 13 mining topology at mult=4 — and then runs the
weak-scaling mining row at mult=8, the paper's real 100-sensor/80-edge/
24-server ratios that the object path was too slow to reach:

* ``slowdown_pool``    — joint co-run factors of a fleet-wide pool (what the
  Traverser recomputes at every contention-interval boundary)
* ``slowdown_pairs``   — all pairwise co-run factors (``slowdown_matrix``)
* ``constraint_check`` — an ORC scoring every candidate PU of a busy device
  including the Alg. 1 l.15 re-check of active tasks' constraints

Emits ``BENCH_graph_compile.json`` next to the repo root so the perf
trajectory is tracked from PR to PR.

``bench-session`` mode (``python -m benchmarks.graph_compile session``)
measures the batch-first scheduling surface instead:

* mapped-tasks/sec of ``Orchestrator.map_batch`` frontier waves vs the
  seed's sequential per-task mapping stack (object-list ledger, per-device
  scoring loops, Python Alg. 1 l.15 re-checks — replicated verbatim below,
  like ``ObjectPathSlowdown`` replicates the seed slowdown), with an
  assignment-parity check between the two;
* the Fig. 13 weak-scaling mining row at mult=64 driven through a
  ``SchedulerSession`` with ``Churn`` delta-batch churn mid-run — possible
  only because topology churn is absorbed by ``apply_delta`` snapshot
  patches (the run asserts zero full recompiles after the initial build).

Emits ``BENCH_session.json``; ``--check`` fails (exit 1) when batched
mapped-tasks/sec regresses >20% vs the checked-in baseline.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from typing import Optional

import numpy as np

from repro.core import (ActiveLedger, Churn, DecoupledSlowdown, Runtime,
                        SchedulerSession, build_orchestrators, build_testbed,
                        ground_truth_traverser, heye_params, heye_traverser,
                        mining_workload)
from repro.core.orchestrator import MapResult, Orchestrator
from repro.core.topology import make_task
from repro.core.traverser import TaskPrediction

from .common import (Table, check_gate, fail_gates, make_policy,
                     write_payload)
from .scaling import _mining_completion, mining_counts

_JSON = Path(__file__).resolve().parent.parent / "BENCH_graph_compile.json"
_SESSION_JSON = Path(__file__).resolve().parent.parent / "BENCH_session.json"


class ObjectPathSlowdown:
    """The seed's pre-compilation algorithm, kept verbatim as the baseline:
    per-pair compute-path scans with a dict cache, Python dict loops for
    pressure aggregation."""

    def __init__(self, graph, params=None):
        self.graph = graph
        self.params = params or heye_params()
        self._shared_cache: dict[tuple[str, str], str | None] = {}

    def nearest_shared(self, pu_a, pu_b):
        key = (pu_a, pu_b) if pu_a <= pu_b else (pu_b, pu_a)
        if key not in self._shared_cache:
            pa = self.graph.nodes[pu_a].get_compute_path()
            pb = set(self.graph.nodes[pu_b].get_compute_path())
            self._shared_cache[key] = next((r for r in pa if r in pb), None)
        return self._shared_cache[key]

    def _pressure_term(self, beta, x):
        if x <= 0.0 or beta <= 0.0:
            return 0.0
        return beta * x * (1.0 + self.params.superlinear * x)

    def _mem_usage(self, task, pu_name):
        u = task.usage.get("mem", 1.0)
        cap = self.graph.nodes[pu_name].attrs.get("mem_usage_cap")
        return min(u, cap) if cap is not None else u

    def factor(self, task, pu_name, coruns):
        p = self.params
        f = 1.0
        pu = self.graph.nodes[pu_name]
        pu_class = pu.attrs.get("pu_class_kind",
                                pu.attrs.get("pu_class", "default"))
        mt_pressure = 0.0
        res_pressure: dict[str, float] = {}
        for other, other_pu in coruns:
            if other.uid == task.uid:
                continue
            if other_pu == pu_name:
                mt_pressure += other.usage.get("pu", 1.0)
            else:
                shared = self.nearest_shared(pu_name, other_pu)
                if shared is None:
                    continue
                rclass = self.graph.nodes[shared].attrs.get("rclass", "dram")
                res_pressure[rclass] = (res_pressure.get(rclass, 0.0)
                                        + self._mem_usage(other, other_pu))
        if mt_pressure > 0.0:
            f *= 1.0 + self._pressure_term(p.mt(pu_class), mt_pressure
                                           ) * task.usage.get("pu", 1.0)
        for rclass, x in res_pressure.items():
            f *= 1.0 + self._pressure_term(p.beta.get(rclass, 0.3), x
                                           ) * self._mem_usage(task, pu_name)
        return max(1.0, f)


def _fleet_pool(tb, per_device=4):
    kinds = ("dnn", "knn", "svm", "mlp", "render", "encode")
    pool = []
    for i, e in enumerate(tb.edges):
        for j, short in enumerate(("cpu0", "gpu", "dla", "vic")[:per_device]):
            pool.append((make_task(kinds[(i + j) % len(kinds)]),
                         f"{e}.{short}"))
    for s in tb.servers:
        pool.append((make_task("knn"), f"{s}.gpu"))
        pool.append((make_task("mlp"), f"{s}.cpu"))
    return pool


def _time(fn, reps):
    fn()                                   # warmup (jit/caches/compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run() -> Table:
    t = Table("graph_compile", "object vs compiled HW-GRAPH engine")
    ec, sc = mining_counts(4)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    g = tb.graph
    t0 = time.perf_counter()
    g.compiled()
    t.add("snapshot_build_s", time.perf_counter() - t0, "s",
          pus=len(g.compiled().pu_names))
    obj = ObjectPathSlowdown(g)
    sd = DecoupledSlowdown(g, heye_params())
    pool = _fleet_pool(tb)

    # parity first: the two paths must agree before their speeds mean anything
    want = np.array([obj.factor(tk, pu, pool) for tk, pu in pool])
    np.testing.assert_allclose(sd.factor_batch(pool), want,
                               atol=1e-9, rtol=1e-9)

    # --- joint factors of the whole pool (contention-interval repricing) ----
    obj_s = _time(lambda: [obj.factor(tk, pu, pool) for tk, pu in pool], 5)
    cmp_s = _time(lambda: sd.factor_batch(pool), 5)
    t.add("slowdown_pool_object", obj_s * 1e3, "ms", n=len(pool))
    t.add("slowdown_pool_compiled", cmp_s * 1e3, "ms", n=len(pool))
    t.add("slowdown_pool_speedup", obj_s / cmp_s, "x")

    # --- all pairwise co-run factors ---------------------------------------
    obj_pairs = _time(lambda: [[obj.factor(ti, pi, [(tj, pj)])
                                for tj, pj in pool] for ti, pi in pool], 2)
    cmp_pairs = _time(lambda: sd.slowdown_matrix(pool), 2)
    t.add("slowdown_pairs_object", obj_pairs * 1e3, "ms", n=len(pool))
    t.add("slowdown_pairs_compiled", cmp_pairs * 1e3, "ms", n=len(pool))
    t.add("slowdown_pairs_speedup", obj_pairs / cmp_pairs, "x")

    # --- ORC constraint check over every candidate PU of a busy device -----
    trav = heye_traverser(g)
    ledger = ActiveLedger()
    root = build_orchestrators(g, trav, ledger=ledger)
    dev = tb.edges[0]
    orc = root.find_device_orc(dev)
    active = [(make_task(k, origin=dev, deadline=0.5), f"{dev}.{pu}")
              for k, pu in (("dnn", "gpu"), ("dnn", "gpu"), ("svm", "cpu0"),
                            ("mlp", "cpu1"), ("encode", "vic"),
                            ("dnn", "dla"), ("render", "gpu"))]
    for tk, pu in active:
        ledger.add(tk, pu, trav.predict_task(tk, pu, active), now=0.0)
    task = make_task("render", origin=dev, deadline=0.1)

    def object_check():
        # the seed's per-candidate flow: one factor for the newcomer plus a
        # re-factor of every active task, per candidate PU
        out = []
        for pu in orc.leaf_pus:
            f_new = obj.factor(task, pu, active)
            pool_c = active + [(task, pu)]
            refac = [obj.factor(tk, p, pool_c) for tk, p in active]
            out.append((f_new, refac))
        return out

    obj_chk = _time(object_check, 20)
    cmp_chk = _time(lambda: orc._check_candidates(task, orc.leaf_pus, 0.0), 20)
    t.add("constraint_check_object", obj_chk * 1e6, "us",
          candidates=len(orc.leaf_pus), active=len(active))
    t.add("constraint_check_compiled", cmp_chk * 1e6, "us",
          candidates=len(orc.leaf_pus), active=len(active))
    t.add("constraint_check_speedup", obj_chk / cmp_chk, "x")

    # --- weak scaling restored to the paper's real ratios (mult=8) ---------
    wall = {}
    for mult in (4, 8):
        ecm, scm = mining_counts(mult)
        tbm = build_testbed(edge_counts=ecm, server_counts=scm)
        t0 = time.perf_counter()
        comp, _, _ = _mining_completion(tbm, n_sensors=12 * mult)
        wall[mult] = time.perf_counter() - t0
        t.add(f"weak_mining_x{mult}_completion", comp * 1e3, "ms",
              devices=sum(ecm.values()) + sum(scm.values()),
              wall_s=round(wall[mult], 2))

    # snapshot lifecycle of the bench graph: full rebuilds vs incremental
    # deltas vs lazily materialized route rows (laziness = route Dijkstras
    # happen per *touched* source, not per routable node at build)
    t.add("recompile_count", g.recompile_count)
    t.add("delta_count", g.delta_count)
    t.add("route_rows_built", g.route_row_builds,
          routable=len(g.compiled().routable_names))

    write_payload(t, _JSON)
    return t


# ---------------------------------------------------------------------------
# bench-session: frontier-batched vs the seed's sequential mapping stack
# ---------------------------------------------------------------------------
class SeedLedger:
    """The seed's object-list ActiveLedger, kept verbatim as the baseline."""

    def __init__(self) -> None:
        self.by_pu: dict[str, list] = {}

    def add(self, task, pu, pred, now):
        from repro.core.orchestrator import ActiveEntry
        e = ActiveEntry(task=task, pu=pu, est_finish=now + pred.total,
                        factor=pred.factor)
        self.by_pu.setdefault(pu, []).append(e)
        return e

    def prune(self, now):
        for pu in list(self.by_pu):
            self.by_pu[pu] = [e for e in self.by_pu[pu] if e.est_finish > now]
            if not self.by_pu[pu]:
                del self.by_pu[pu]

    def on_device(self, graph, pu_name):
        comp = graph.compiled()
        dev = comp.device_name(pu_name)
        out = []
        for pu, entries in self.by_pu.items():
            if comp.device_name(pu) == dev:
                out.extend(entries)
        return out

    def count(self, pu):
        return len(self.by_pu.get(pu, []))


class SeedOrchestrator(Orchestrator):
    """The seed's per-task mapping flow, replicated verbatim: per-device
    scoring loops over object ledger entries, per-candidate predict calls,
    and a Python Alg. 1 l.15 loop — no frontier batching, no fused
    cross-device kernel, no struct-of-arrays ledger."""

    def map_task(self, task, now=0.0, commit=True):
        self.ledger.prune(now)
        res = self._traverse_children(task, now)
        if res is None:
            res = self._ask_parent(task, now, origin=self)
        if res is None and self.config.allow_best_effort:
            res = self._best_effort(task, now)
        if res is not None and commit:
            self.ledger.add(task, res.pu, res.prediction, now)
            task.assigned_pu = res.pu
        return res

    def _traverse_children(self, task, now, ctx=None, scored=None, pre=None):
        candidates = []
        queries = 0
        hops = 0
        overhead = 0.0
        checks = self._check_candidates(task, self.leaf_pus, now)
        for pu_name, (ok, pred) in zip(self.leaf_pus, checks):
            queries += 1
            if ok:
                r = MapResult(pu=pu_name, prediction=pred)
                if self.config.objective == "first_fit":
                    r.queries = queries
                    r.overhead = overhead + queries * self.config.local_query_cost
                    r.hops = hops
                    return r
                candidates.append(r)
        for child in self.children:
            hops += 1
            overhead += self._hop_cost(child)
            sub = child._traverse_children(task, now)
            if sub is not None:
                queries += sub.queries
                hops += sub.hops
                overhead += sub.overhead
                if self.config.objective == "first_fit":
                    sub.queries = queries
                    sub.hops = hops
                    sub.overhead = overhead + queries * self.config.local_query_cost
                    return sub
                candidates.append(sub)
        if not candidates:
            return None
        best = self._select(candidates)
        best.queries = queries
        best.hops = hops
        best.overhead = overhead + queries * self.config.local_query_cost
        return best

    def _ask_parent(self, task, now, origin, ctx=None, scored=None):
        if self.parent is None:
            return None
        parent = self.parent
        results = []
        hops = 1
        overhead = self._hop_cost(parent)
        for sibling in parent.children:
            if sibling is self:
                continue
            hops += 1
            overhead += parent._hop_cost(sibling)
            sub = sibling._traverse_children(task, now)
            if sub is not None:
                sub.hops += hops
                sub.overhead += overhead
                if parent.config.objective == "first_fit":
                    return sub
                results.append(sub)
        if results:
            return self._select(results)
        return parent._ask_parent(task, now, origin=origin)

    def _best_effort(self, task, now, ctx=None, scored=None):
        root = self
        while root.parent is not None:
            root = root.parent
        best = None
        for orc in root.iter_tree():
            if not orc.leaf_pus:
                continue
            scores = self._score_candidates(task, orc.leaf_pus, now,
                                            with_constraints=False)
            for pu_name, (ok, pred) in zip(orc.leaf_pus, scores):
                if not ok:
                    continue
                if best is None or pred.total < best.prediction.total:
                    best = MapResult(pu=pu_name, prediction=pred)
        return best

    def _score_candidates(self, task, pu_names, now, *, with_constraints,
                          ctx=None):
        from repro.core.hwgraph import ProcessingUnit
        graph = self.graph
        comp = graph.compiled()
        infeasible = (False, TaskPrediction(float("inf"), 1.0, 0.0))
        results = [None] * len(pu_names)
        eligible = []
        for i, name in enumerate(pu_names):
            pu = graph.nodes.get(name)
            if (not isinstance(pu, ProcessingUnit) or not pu.alive
                    or (pu.model is not None
                        and not pu.model.supports(task, pu))
                    or (task.attrs.get("pinned")
                        and comp.device_name(name) != task.origin)):
                results[i] = infeasible
            else:
                eligible.append(i)
        if not eligible:
            return results
        sd = self.traverser.slowdown
        batch = getattr(sd, "factors_with_candidates", None)
        by_dev = {}
        for i in eligible:
            by_dev.setdefault(comp.device_name(pu_names[i]), []).append(i)
        ret_bytes = task.attrs.get("succ_pinned_bytes", 0.0)
        for dev, idxs in by_dev.items():
            names = [pu_names[i] for i in idxs]
            entries = self.ledger.on_device(graph, names[0])
            pairs = [(e.task, e.pu) for e in entries]
            if batch is not None:
                new_f, act_f = batch(task, names, pairs)
            else:
                new_f = [sd.factor(task, p, pairs) for p in names]
                act_f = None
            comm = self.traverser.comm_time(task, names[0], comp)
            if ret_bytes > 0 and task.origin is not None and dev != task.origin:
                comm += comp.transfer_time(dev, task.origin, ret_bytes)
            for c, i in enumerate(idxs):
                name = names[c]
                pu = graph.nodes[name]
                pred = TaskPrediction(standalone=pu.predict(task),
                                      factor=float(new_f[c]), comm=comm)
                if not with_constraints:
                    results[i] = (True, pred)
                    continue
                on_pu = self.ledger.by_pu.get(name, [])
                if len(on_pu) >= pu.max_tenancy:
                    wait = min(e.est_finish for e in on_pu) - now
                    pred = TaskPrediction(standalone=pred.standalone,
                                          factor=pred.factor,
                                          comm=pred.comm + max(0.0, wait))
                if task.deadline is not None and pred.total > task.deadline:
                    results[i] = (False, pred)
                    continue
                ok = True
                if entries:
                    if act_f is None:
                        new_factors = self.traverser.predict_active_with(
                            task, name, pairs)
                    for a, e in enumerate(entries):
                        if e.task.deadline is None:
                            continue
                        f = (float(act_f[c, a]) if act_f is not None
                             else new_factors[e.task.uid])
                        rem = e.remaining_standalone(now)
                        new_finish = now + rem * f
                        if (new_finish - e.task.release_time
                                > e.task.deadline * (1 + 1e-9)):
                            ok = False
                            break
                results[i] = (ok, pred)
        return results


def _session_workload(mult: int, n_readings: int, seed_cls=None,
                      n_sensors: Optional[int] = None):
    ec, sc = mining_counts(mult)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    kwargs = {}
    if seed_cls is not None:
        kwargs = {"cls": seed_cls, "ledger": SeedLedger()}
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph), **kwargs)
    cfg = mining_workload(tb, n_sensors=n_sensors or 12 * mult,
                          n_readings=n_readings)
    waves: dict[float, list] = {}
    for t in cfg:
        waves.setdefault(round(t.release_time, 9), []).append(t)
    tb.graph.compiled()                       # warm the snapshot
    return tb, root, [waves[k] for k in sorted(waves)]


def _mapped_per_sec(mult: int, n_sensors: int):
    """(sequential seed-stack rate, frontier-batched rate) in tasks/s,
    with an assignment-parity assert between the two."""
    tb1, root1, waves1 = _session_workload(mult, 2, seed_cls=SeedOrchestrator,
                                           n_sensors=n_sensors)
    n = sum(len(w) for w in waves1)
    t0 = time.perf_counter()
    seq_assign = []
    for w in waves1:
        now = w[0].release_time
        for task in w:
            res = root1._entry_orc(task).map_task(task, now)
            seq_assign.append(res.pu if res else None)
    seq_s = time.perf_counter() - t0

    # throwaway pass: absorb the one-time jit compilation of the fused
    # scan kernels, which would otherwise be charged to the first timed
    # wave (the sequential object walk above pays no such cost)
    tbw, rootw, wavesw = _session_workload(mult, 2, n_sensors=n_sensors)
    for w in wavesw:
        list(rootw.map_batch(w, w[0].release_time, route=True))
    del tbw, rootw, wavesw

    tb2, root2, waves2 = _session_workload(mult, 2, n_sensors=n_sensors)
    t0 = time.perf_counter()
    bat_assign = []
    for w in waves2:
        for res in root2.map_batch(w, w[0].release_time, route=True):
            bat_assign.append(res.pu if res else None)
    bat_s = time.perf_counter() - t0
    mismatch = sum(1 for a, b in zip(seq_assign, bat_assign) if a != b)
    if mismatch:
        raise AssertionError(
            f"batched assignments diverged from sequential: {mismatch}/{n}")
    return n, n / seq_s, n / bat_s


def run_session(check: bool = False) -> Table:
    t = Table("session", "frontier-batched vs sequential mapping")
    baseline = None
    if _SESSION_JSON.exists():
        baseline = json.loads(_SESSION_JSON.read_text())

    # --- mapped-tasks/sec at mult=8 (two release waves: cold + warm) -------
    # nominal = the Fig. 13 weak-scaling sensor ratio; loaded = 3x that
    # (the oversubscribed regime where per-task Python dispatch and the
    # object-ledger scans of the sequential stack dominate)
    n, seq_r, bat_r = _mapped_per_sec(8, 12 * 8)
    t.add("mapped_per_sec_sequential", seq_r, "tasks/s", n=n)
    t.add("mapped_per_sec_batched", bat_r, "tasks/s", n=n)
    t.add("map_batch_speedup", bat_r / seq_r, "x")
    n, seq_r, bat_r = _mapped_per_sec(8, 36 * 8)
    t.add("mapped_per_sec_sequential_loaded", seq_r, "tasks/s", n=n)
    t.add("mapped_per_sec_batched_loaded", bat_r, "tasks/s", n=n)
    t.add("map_batch_speedup_loaded", bat_r / seq_r, "x")

    # --- Fig. 13 weak scaling at mult=64 through a SchedulerSession --------
    # with topology churn absorbed by apply_delta (no full recompiles)
    t0 = time.perf_counter()
    ec, sc = mining_counts(64)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    g = tb.graph
    g.compiled()
    build_s = time.perf_counter() - t0
    root = build_orchestrators(g, heye_traverser(g))
    session = SchedulerSession(g, root, truth=ground_truth_traverser(g, 0))
    cfg = mining_workload(tb, n_sensors=12 * 64, n_readings=1)
    rebuilds0 = g.recompile_count
    t0 = time.perf_counter()
    session.submit(cfg)
    session.map_pending()
    # mid-run churn: an edge dies and rejoins; the next frontier maps
    # against delta-patched snapshots
    session.churn(Churn(dead=[tb.edges[0]]))
    churn = mining_workload(tb, n_sensors=16, n_readings=1)
    for task in churn:
        task.release_time = 1.0
    session.submit(churn)
    session.map_pending()
    session.churn(Churn(alive=[tb.edges[0]]))
    map_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    stats = session.execute()
    exec_s = time.perf_counter() - t0
    per_reading: dict[tuple, float] = {}
    for task in cfg:
        key = (task.attrs["sensor"], round(task.release_time, 6))
        per_reading[key] = max(per_reading.get(key, 0.0),
                               stats.timeline.latency(task))
    rebuilds = g.recompile_count - rebuilds0
    if rebuilds:
        raise AssertionError(f"topology churn forced {rebuilds} full "
                             "recompiles; apply_delta should absorb it")
    t.add("weak_mining_x64_completion",
          float(np.mean(list(per_reading.values()))) * 1e3, "ms",
          devices=sum(mining_counts(64)[0].values())
          + sum(mining_counts(64)[1].values()),
          tasks=len(cfg) + len(churn))
    t.add("x64_build_s", build_s, "s")
    t.add("x64_map_s", map_s, "s")
    t.add("x64_exec_s", exec_s, "s")
    t.add("x64_full_recompiles", rebuilds)
    t.add("x64_snapshot_deltas", g.delta_count)
    t.add("x64_route_rows_built", g.route_row_builds,
          routable=len(g.compiled().routable_names))

    gates = {"mapped_per_sec_batched": {"floor_ratio": 0.8},
             "mapped_per_sec_batched_loaded": {"floor_ratio": 0.8}}
    write_payload(t, _SESSION_JSON, gates=gates)
    if check:
        fail_gates(t, [
            check_gate(t, baseline, row, floor_ratio=0.8)
            for row in ("mapped_per_sec_batched",
                        "mapped_per_sec_batched_loaded")])
    return t


if __name__ == "__main__":
    args = sys.argv[1:]
    if args and args[0] == "session":
        run_session(check="--check" in args).print_csv()
    else:
        run().print_csv()
