"""DES timeline-engine throughput: array-native vs the seed heapq loop.

Measures the struct-of-arrays ``TimelineEngine`` (core/timeline.py)
against the seed's per-job heapq event loop (kept verbatim as
``Traverser.traverse_reference``) on the Fig. 13 mining topology at
mult=8 under an **oversubscribed burst**: every sensor fires at once at
many times the nominal sensor:device ratio, the regime where the seed
loop's per-member completion pushes and per-event Python settles
dominate (and where fleet-sized timelines live).  Parity is asserted at
1e-9 before anything is timed.

Also records what the lazy route-table work bought: full snapshot
build time at mult=128 (the ROADMAP blocker was ~6 s at mult=64 for the
eager all-pairs build) plus the route-rows-built counter.

Also times the group-sharded wave-batched Alg. 1 mapping walk over the
whole mult=128 and mult=256 fleets (``x128_map_s`` / ``x256_map_s`` +
tasks/sec and shard-count rows) with absolute wall budgets, asserts
sharded-vs-fused bit-identity at mult=8 (the ``--smoke`` CI step always
runs this), and reports the canonical factor-cache hit/miss counters.

Also runs the **bandwidth-volatile wireless-edge scenario** at mult=64
and mult=128: waves of seeded ``Churn`` bandwidth batches degrade and
recover the edge uplinks between mapping waves, exercising the layered
route table's overlay path.  The scenario asserts the delta stays
bandwidth-only (``route_holder_copies == 0`` — no O(D^2) topology-layer
copy ever fires) and reports the overlay-copy count alongside the
``x{K}_bwchurn_map_s`` wall.

Emits ``BENCH_des.json`` (shared schema via ``common.write_payload``);
``--check`` fails (exit 1) when the array engine's events/sec or the
mult=128/256 mapping throughput regresses >20% vs the checked-in
baseline; ``--smoke`` runs a seconds-scale variant for CI;
``--churn-smoke`` runs only the bandwidth-churn sharded-vs-fused parity
assert at mult=8 (the ``make bench-churn-smoke`` CI step).
"""
from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (SchedulerSession, build_orchestrators, build_testbed,
                        ground_truth_traverser, heye_traverser)

from .common import Table, check_gate, fail_gates, write_payload
from .scaling import mining_counts

_JSON = Path(__file__).resolve().parent.parent / "BENCH_des.json"


def _workload(mult: int, n_sensors: int):
    from repro.core import mining_workload
    ec, sc = mining_counts(mult)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    cfg = mining_workload(tb, n_sensors=n_sensors, n_readings=1)
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    session = SchedulerSession(tb.graph, root)
    session.submit(cfg)
    session.map_pending()
    return tb, cfg, dict(session.mapping)


def _time_des(traverser_fn, cfg, mapping, reference: bool):
    trav = traverser_fn()
    t0 = time.perf_counter()
    tl = (trav.traverse_reference(cfg, mapping) if reference
          else trav.traverse(cfg, mapping))
    return time.perf_counter() - t0, tl


def _sharded_parity(t: Table, mult: int = 8) -> None:
    """Map one whole-fleet frontier twice — group-sharded driver vs the
    fused single-shard oracle (``REPRO_SHARDED_WALK=0``) — and assert the
    mappings are bit-identical.  This is the CI smoke gate for the
    sharded walk (docs/sharding.md)."""
    from repro.core import mining_workload
    outs = []
    saved = os.environ.get("REPRO_SHARDED_WALK")
    try:
        for flag in ("1", "0"):
            os.environ["REPRO_SHARDED_WALK"] = flag
            ec, sc = mining_counts(mult)
            tb = build_testbed(edge_counts=ec, server_counts=sc)
            root = build_orchestrators(
                tb.graph, heye_traverser(tb.graph)).prepare()
            cfg = mining_workload(tb, n_sensors=12 * mult, n_readings=1)
            res = root.map_batch(list(cfg), 0.0, route=True)
            outs.append([None if r is None else
                         (r.pu, r.prediction.total, r.prediction.factor,
                          r.overhead, r.queries, r.hops) for r in res])
            if flag == "1":
                n_shards = (len(root._sharded_hw.shards)
                            if root._sharded_hw is not None else 1)
    finally:
        if saved is None:
            os.environ.pop("REPRO_SHARDED_WALK", None)
        else:
            os.environ["REPRO_SHARDED_WALK"] = saved
    if outs[0] != outs[1]:
        bad = sum(a != b for a, b in zip(*outs))
        raise AssertionError(
            f"sharded walk diverged from the fused oracle on {bad}/"
            f"{len(outs[0])} tasks at mult={mult}")
    t.add(f"x{mult}_sharded_parity_tasks", len(outs[0]), "tasks",
          shards=n_shards)


def _bwchurn(t: Table, mult: int, n_waves: int = 8) -> None:
    """Bandwidth-volatile wireless-edge scenario: interleave seeded
    uplink degrade/recover ``Churn`` waves with mapping waves over the
    mult-scaled mining fleet.  The mapping walk keeps building lazy
    route rows between churn batches, so every wave exercises the
    overlay path against a part-built table.  Hard invariant: a
    bandwidth-only delta must never copy the topology layer
    (``route_holder_copies == 0``) and must absorb every wave as a
    delta (no silent full-rebuild fallback)."""
    from repro.core import mining_workload, wireless_churn_schedule
    ec, sc = mining_counts(mult)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    tb.graph.compiled()                  # snapshot outside the churn timer
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    session = SchedulerSession(tb.graph, root)
    waves = wireless_churn_schedule(tb, n_waves, seed=1234)
    per_wave = max(1, (12 * mult) // n_waves)
    g = tb.graph
    h0, o0 = g.route_holder_copies, g.route_overlay_copies
    d0 = g.delta_count
    n_tasks = 0
    t0 = time.perf_counter()
    for churn in waves:
        session.churn(churn)
        cfg = mining_workload(tb, n_sensors=per_wave, n_readings=1)
        n_tasks += len(list(cfg))
        session.submit(cfg)
        session.map_pending()
    wall = time.perf_counter() - t0
    holders = g.route_holder_copies - h0
    overlays = g.route_overlay_copies - o0
    if holders != 0:
        raise AssertionError(
            f"bandwidth-only churn at mult={mult} copied the route "
            f"topology layer {holders}x — the overlay split has regressed "
            "to O(D^2) per delta")
    if g.delta_count - d0 != n_waves:
        raise AssertionError(
            f"bandwidth churn at mult={mult} absorbed "
            f"{g.delta_count - d0}/{n_waves} waves as deltas — the rest "
            "fell back to full snapshot rebuilds")
    assert not session.unmapped, f"bwchurn mult={mult} left tasks unmapped"
    t.add(f"x{mult}_bwchurn_map_s", wall, "s", waves=n_waves,
          tasks=n_tasks)
    t.add(f"x{mult}_bwchurn_tasks_per_sec", n_tasks / wall, "tasks/s")
    t.add(f"x{mult}_route_holder_copies", holders, "copies")
    t.add(f"x{mult}_route_overlay_copies", overlays, "copies")


def churn_smoke(mult: int = 8, n_waves: int = 4) -> None:
    """``make bench-churn-smoke``: drive the bandwidth-volatile scenario
    at mult=8 under both the group-sharded walk and the fused oracle
    (``REPRO_SHARDED_WALK=0``) and assert the mapped placements and
    predictions are bit-identical wave for wave.  Also enforces the
    zero-topology-copy invariant on both runs."""
    from repro.core import mining_workload, wireless_churn_schedule
    outs = []
    saved = os.environ.get("REPRO_SHARDED_WALK")
    try:
        for flag in ("1", "0"):
            os.environ["REPRO_SHARDED_WALK"] = flag
            ec, sc = mining_counts(mult)
            tb = build_testbed(edge_counts=ec, server_counts=sc)
            tb.graph.compiled()
            root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
            session = SchedulerSession(tb.graph, root)
            h0 = tb.graph.route_holder_copies
            per = []
            for churn in wireless_churn_schedule(tb, n_waves, seed=7):
                session.churn(churn)
                cfg = mining_workload(tb, n_sensors=3 * mult, n_readings=1)
                session.submit(cfg)
                res = session.map_pending()
                for uid in sorted(res):
                    r = res[uid]
                    per.append(None if r is None else
                               (r.pu, r.prediction.total,
                                r.prediction.factor, r.overhead,
                                r.queries, r.hops))
            if tb.graph.route_holder_copies != h0:
                raise AssertionError(
                    "bandwidth-only churn copied the route topology layer "
                    f"(sharded={flag})")
            outs.append(per)
    finally:
        if saved is None:
            os.environ.pop("REPRO_SHARDED_WALK", None)
        else:
            os.environ["REPRO_SHARDED_WALK"] = saved
    if outs[0] != outs[1]:
        bad = sum(a != b for a, b in zip(*outs))
        raise AssertionError(
            f"bandwidth-churn sharded walk diverged from the fused oracle "
            f"on {bad}/{len(outs[0])} tasks at mult={mult}")
    print(f"# des: bwchurn sharded-vs-fused parity OK "
          f"({len(outs[0])} tasks, {n_waves} waves, mult={mult})")


def run(smoke: bool = False, check: bool = False) -> Table:
    t = Table("des", "array-native DES vs seed heapq event loop")
    baseline = json.loads(_JSON.read_text()) if _JSON.exists() else None

    # --- sharded-vs-fused bit-identity at mult=8 (always; the CI smoke
    # step leans on this as the cheap whole-fleet parity assert) ------------
    _sharded_parity(t, mult=8)

    # --- mult=8 oversubscribed burst (smoke: mult=2) -----------------------
    mult = 2 if smoke else 8
    n_sensors = 288 * mult               # 24x the Fig. 13 nominal ratio
    tb, cfg, mapping = _workload(mult, n_sensors)

    # parity gate before timing means anything (prediction + ground truth)
    heye = lambda: heye_traverser(tb.graph)                      # noqa: E731
    truth = lambda: ground_truth_traverser(tb.graph, 0)          # noqa: E731
    for label, mk in (("heye", heye), ("truth", truth)):
        ref_tl = mk().traverse_reference(cfg, mapping)
        arr_tl = mk().traverse(cfg, mapping)
        err = max(abs(ref_tl.finish[k] - arr_tl.finish[k])
                  for k in ref_tl.finish)
        if err > 1e-9:
            raise AssertionError(f"{label} DES parity broke: {err:.3e}")

    # --- timed runs: the H-EYE predictor DES (deterministic) ---------------
    ref_s, ref_tl = _time_des(heye, cfg, mapping, reference=True)
    arr_s, arr_tl = _time_des(heye, cfg, mapping, reference=False)
    n_tasks = len(list(cfg))
    t.add("des_seed_heapq_s", ref_s, "s", tasks=n_tasks,
          events=ref_tl.n_events)
    t.add("des_array_s", arr_s, "s", tasks=n_tasks, events=arr_tl.n_events)
    t.add("des_events_per_sec", arr_tl.n_events / arr_s, "ev/s")
    t.add("des_tasks_per_sec", n_tasks / arr_s, "tasks/s")
    t.add("des_speedup", ref_s / arr_s, "x")
    # the noisy ground-truth engine (rng draws break eta ties -> smaller
    # flush batches; reported, not gated)
    tref_s, _ = _time_des(truth, cfg, mapping, reference=True)
    tarr_s, _ = _time_des(truth, cfg, mapping, reference=False)
    t.add("des_truth_speedup", tref_s / tarr_s, "x")

    # --- lazy snapshot build at mult=128 (the old all-pairs blocker) -------
    # drop the burst-section objects first: millions of live task/event
    # objects make every gen2 GC pass during the timed build pay for them
    del tb, cfg, mapping, ref_tl, arr_tl, heye, truth
    import gc
    gc.collect()
    # pre-fault a fleet-sized scratch block: the *first* large allocation
    # after the burst section pays a one-time multi-second page-reclaim
    # stall on micro-VM hosts — take it here, outside the timed build
    np.full(90_000_000, -1, dtype=np.int64)
    bmult = 16 if smoke else 128
    ec, sc = mining_counts(bmult)
    t0 = time.perf_counter()
    tbb = build_testbed(edge_counts=ec, server_counts=sc)
    build_tb = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp = tbb.graph.compiled()
    build_s = time.perf_counter() - t0
    t.add(f"x{bmult}_snapshot_build_s", build_s, "s",
          pus=len(comp.pu_names), testbed_s=round(build_tb, 2))
    if not smoke and build_s > 2.0:
        raise AssertionError(
            f"mult=128 snapshot build took {build_s:.2f}s (budget: 2s)")

    # --- the Fig. 13 weak-scaling row itself at mult=128 -------------------
    # (the acceptance claims: the run *completes*, completion stays on the
    # ~55 ms plateau the x1..x64 rows sit on, and the fused wave-batched
    # Alg. 1 walk keeps whole-fleet mapping under the 2 s wall)
    from repro.core import mining_workload
    root = build_orchestrators(tbb.graph, heye_traverser(tbb.graph))
    session = SchedulerSession(tbb.graph, root,
                               truth=ground_truth_traverser(tbb.graph, 0))
    wcfg = mining_workload(tbb, n_sensors=12 * bmult, n_readings=1)
    # warm one-time runtime imports (jitted walk kernel backend probe,
    # scipy's batched Dijkstra) so map_s times mapping, not module loads
    from repro.kernels.walk_kernel import scan_reduce as _warm_kernel  # noqa
    _warm_kernel(np.ones(1, bool), np.zeros(1), np.zeros(1, np.int64),
                 np.ones(1, np.int64), np.ones(1, np.int64),
                 np.zeros(1, np.int64), np.zeros(1), np.zeros(1, np.int64),
                 0.0)
    try:
        import scipy.sparse.csgraph  # noqa: F401
    except ImportError:
        pass
    n_wtasks = len(list(wcfg))
    t0 = time.perf_counter()
    session.submit(wcfg)
    session.map_pending()
    map_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    stats = session.execute()
    exec_s = time.perf_counter() - t0
    per: dict = {}
    for task in wcfg:
        key = (task.attrs["sensor"], round(task.release_time, 6))
        per[key] = max(per.get(key, 0.0), stats.timeline.latency(task))
    completion_ms = float(np.mean(list(per.values()))) * 1e3
    t.add(f"weak_mining_x{bmult}_completion", completion_ms, "ms",
          devices=sum(ec.values()) + sum(sc.values()), tasks=n_wtasks)
    # tail metrics via the shared percentile definitions (same as the
    # online ServeStats — see benchmarks/serve.py / docs/serving.md)
    pct = stats.latency_percentiles(wcfg)
    t.add(f"x{bmult}_latency_p50_ms", pct[50.0] * 1e3, "ms")
    t.add(f"x{bmult}_latency_p99_ms", pct[99.0] * 1e3, "ms")
    t.add(f"x{bmult}_latency_p999_ms", pct[99.9] * 1e3, "ms")
    t.add(f"x{bmult}_map_s", map_s, "s")
    t.add(f"x{bmult}_map_tasks_per_sec", n_wtasks / map_s, "tasks/s",
          tasks=n_wtasks)
    t.add(f"x{bmult}_exec_s", exec_s, "s")
    t.add(f"x{bmult}_route_rows_built", tbb.graph.route_row_builds,
          "rows", routable=len(comp.routable_names))
    t.add(f"x{bmult}_shards",
          len(root._sharded_hw.shards) if root._sharded_hw else 1, "groups")
    # canonical factor-cache effectiveness across the mapping run
    t.add("factor_cache_hits", root.factor_cache_hits, "hits")
    t.add("factor_cache_misses", root.factor_cache_misses, "misses")
    # the fused-walk target is < 2 s (typical: ~1.8 s on a quiet 1 vCPU;
    # the sequential walk took ~14.5 s); the hard wall sits at 3 s so
    # host-level noise can't fail a healthy build, and the >20%
    # mapped-tasks/sec gate below stays the sensitive detector
    if not smoke and not map_s < 3.0:
        raise AssertionError(
            f"mult=128 mapping took {map_s:.2f}s (wall: 3s, target <2s — "
            "the fused wave-batched walk has regressed)")
    if not smoke and not completion_ms < 120.0:
        raise AssertionError(
            f"mult=128 weak-scaling completion {completion_ms:.1f}ms fell "
            "off the ~55ms plateau (budget: <120ms incl. noise)")

    # --- mult=256: the run group sharding makes tractable ------------------
    # (a 3300-device fleet; the pre-sharding fused walk blows past any
    # interactive budget here — the absolute wall is the acceptance gate)
    if not smoke:
        del root, session, wcfg, stats, comp, tbb
        gc.collect()
        smult = 256
        ec, sc = mining_counts(smult)
        tbs = build_testbed(edge_counts=ec, server_counts=sc)
        tbs.graph.compiled()                 # snapshot outside the map timer
        sroot = build_orchestrators(tbs.graph, heye_traverser(tbs.graph))
        ssn = SchedulerSession(tbs.graph, sroot)
        from repro.core import mining_workload as _mw
        scfg = _mw(tbs, n_sensors=12 * smult, n_readings=1)
        n_stasks = len(list(scfg))
        t0 = time.perf_counter()
        ssn.submit(scfg)
        ssn.map_pending()
        smap_s = time.perf_counter() - t0
        t.add(f"x{smult}_map_s", smap_s, "s",
              devices=sum(ec.values()) + sum(sc.values()))
        t.add(f"x{smult}_map_tasks_per_sec", n_stasks / smap_s, "tasks/s",
              tasks=n_stasks)
        t.add(f"x{smult}_shards",
              len(sroot._sharded_hw.shards) if sroot._sharded_hw else 1,
              "groups")
        assert not ssn.unmapped, "mult=256 frontier left tasks unmapped"
        # absolute gate: whole-fleet mapping at mult=256 stays interactive
        # (typical ~7.7 s on a quiet 1 vCPU; 1.5x headroom for host noise,
        # with the >20% tasks/sec gate as the sensitive detector)
        if not smap_s < 12.0:
            raise AssertionError(
                f"mult=256 mapping took {smap_s:.2f}s (wall: 12s — the "
                "group-sharded walk has regressed)")

        # --- bandwidth-volatile wireless-edge scenario ---------------------
        # (mult=64 informational, mult=128 gated: absolute wall + the >20%
        # tasks/sec gate below; route_holder_copies == 0 is asserted inside)
        del sroot, ssn, scfg, tbs
        gc.collect()
        _bwchurn(t, mult=64)
        _bwchurn(t, mult=128)
        # typical ~5.9 s on a quiet 1 vCPU (8 waves x churn + map + per-call
        # overheads); 2x headroom for host noise, with the >20% tasks/sec
        # gate below as the sensitive detector
        bw_wall = t.get("x128_bwchurn_map_s")
        if not bw_wall < 12.0:
            raise AssertionError(
                f"mult=128 bandwidth-churn run took {bw_wall:.2f}s "
                "(wall: 12s, target <6s — the overlay delta path has "
                "regressed)")

    gates = {
        "des_events_per_sec": {"floor_ratio": 0.8},
        "des_speedup": {"abs_min": 3.0},
        "x128_map_tasks_per_sec": {"floor_ratio": 0.8},
        "x128_map_s": {"abs_max_s": 3.0},
        "x256_map_tasks_per_sec": {"floor_ratio": 0.8},
        "x256_map_s": {"abs_max_s": 12.0},
        "weak_mining_x128_completion": {"abs_max_ms": 120.0},
        "x128_snapshot_build_s": {"abs_max_s": 2.0},
        "x128_bwchurn_map_s": {"abs_max_s": 12.0},
        "x128_bwchurn_tasks_per_sec": {"floor_ratio": 0.8},
        "x128_route_holder_copies": {"abs_max": 0},
    }
    extra_meta = None
    if not smoke:
        # satellite counters: route-table copy/build behaviour of the
        # mult=128 runs, surfaced in meta for baseline diffs
        extra_meta = {
            "route_holder_copies": int(t.get("x128_route_holder_copies")),
            "route_overlay_copies": int(t.get("x128_route_overlay_copies")),
            "route_row_builds": int(t.get("x128_route_rows_built")),
        }
    write_payload(t, _JSON, smoke, gates, extra_meta)
    if check and not smoke:
        speedup_ok = t.get("des_speedup") >= 3.0
        fail_gates(t, [
            check_gate(t, baseline, "des_events_per_sec", floor_ratio=0.8),
            None if speedup_ok else (
                f"REGRESSION: des_speedup {t.get('des_speedup'):.2f}x "
                "< 3x over the seed heapq loop"),
            check_gate(t, baseline, "x128_map_tasks_per_sec",
                       floor_ratio=0.8),
            check_gate(t, baseline, "x256_map_tasks_per_sec",
                       floor_ratio=0.8,
                       note="group-sharded walk at mult=256"),
            check_gate(t, baseline, "x128_bwchurn_tasks_per_sec",
                       floor_ratio=0.8,
                       note="bandwidth-churn overlay path at mult=128"),
        ])
    return t


if __name__ == "__main__":
    args = sys.argv[1:]
    if "--churn-smoke" in args:
        churn_smoke()
        sys.exit(0)
    run(smoke="--smoke" in args, check="--check" in args).print_csv()
