"""DES timeline-engine throughput: array-native vs the seed heapq loop.

Measures the struct-of-arrays ``TimelineEngine`` (core/timeline.py)
against the seed's per-job heapq event loop (kept verbatim as
``Traverser.traverse_reference``) on the Fig. 13 mining topology at
mult=8 under an **oversubscribed burst**: every sensor fires at once at
many times the nominal sensor:device ratio, the regime where the seed
loop's per-member completion pushes and per-event Python settles
dominate (and where fleet-sized timelines live).  Parity is asserted at
1e-9 before anything is timed.

Also records what the lazy route-table work bought: full snapshot
build time at mult=128 (the ROADMAP blocker was ~6 s at mult=64 for the
eager all-pairs build) plus the route-rows-built counter.

Emits ``BENCH_des.json``; ``--check`` fails (exit 1) when the array
engine's events/sec regresses >20% vs the checked-in baseline;
``--smoke`` runs a seconds-scale variant for CI.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (SchedulerSession, build_orchestrators, build_testbed,
                        ground_truth_traverser, heye_traverser)

from .common import Table
from .scaling import mining_counts

_JSON = Path(__file__).resolve().parent.parent / "BENCH_des.json"


def _workload(mult: int, n_sensors: int):
    from repro.core import mining_workload
    ec, sc = mining_counts(mult)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    cfg = mining_workload(tb, n_sensors=n_sensors, n_readings=1)
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    session = SchedulerSession(tb.graph, root)
    session.submit(cfg)
    session.map_pending()
    return tb, cfg, dict(session.mapping)


def _time_des(traverser_fn, cfg, mapping, reference: bool):
    trav = traverser_fn()
    t0 = time.perf_counter()
    tl = (trav.traverse_reference(cfg, mapping) if reference
          else trav.traverse(cfg, mapping))
    return time.perf_counter() - t0, tl


def run(smoke: bool = False, check: bool = False) -> Table:
    t = Table("des", "array-native DES vs seed heapq event loop")
    baseline = json.loads(_JSON.read_text()) if _JSON.exists() else None

    # --- mult=8 oversubscribed burst (smoke: mult=2) -----------------------
    mult = 2 if smoke else 8
    n_sensors = 288 * mult               # 24x the Fig. 13 nominal ratio
    tb, cfg, mapping = _workload(mult, n_sensors)

    # parity gate before timing means anything (prediction + ground truth)
    heye = lambda: heye_traverser(tb.graph)                      # noqa: E731
    truth = lambda: ground_truth_traverser(tb.graph, 0)          # noqa: E731
    for label, mk in (("heye", heye), ("truth", truth)):
        ref_tl = mk().traverse_reference(cfg, mapping)
        arr_tl = mk().traverse(cfg, mapping)
        err = max(abs(ref_tl.finish[k] - arr_tl.finish[k])
                  for k in ref_tl.finish)
        if err > 1e-9:
            raise AssertionError(f"{label} DES parity broke: {err:.3e}")

    # --- timed runs: the H-EYE predictor DES (deterministic) ---------------
    ref_s, ref_tl = _time_des(heye, cfg, mapping, reference=True)
    arr_s, arr_tl = _time_des(heye, cfg, mapping, reference=False)
    n_tasks = len(list(cfg))
    t.add("des_seed_heapq_s", ref_s, "s", tasks=n_tasks,
          events=ref_tl.n_events)
    t.add("des_array_s", arr_s, "s", tasks=n_tasks, events=arr_tl.n_events)
    t.add("des_events_per_sec", arr_tl.n_events / arr_s, "ev/s")
    t.add("des_tasks_per_sec", n_tasks / arr_s, "tasks/s")
    t.add("des_speedup", ref_s / arr_s, "x")
    # the noisy ground-truth engine (rng draws break eta ties -> smaller
    # flush batches; reported, not gated)
    tref_s, _ = _time_des(truth, cfg, mapping, reference=True)
    tarr_s, _ = _time_des(truth, cfg, mapping, reference=False)
    t.add("des_truth_speedup", tref_s / tarr_s, "x")

    # --- lazy snapshot build at mult=128 (the old all-pairs blocker) -------
    bmult = 16 if smoke else 128
    ec, sc = mining_counts(bmult)
    t0 = time.perf_counter()
    tbb = build_testbed(edge_counts=ec, server_counts=sc)
    build_tb = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp = tbb.graph.compiled()
    build_s = time.perf_counter() - t0
    t.add(f"x{bmult}_snapshot_build_s", build_s, "s",
          pus=len(comp.pu_names), testbed_s=round(build_tb, 2))
    if not smoke and build_s > 2.0:
        raise AssertionError(
            f"mult=128 snapshot build took {build_s:.2f}s (budget: 2s)")

    # --- the Fig. 13 weak-scaling row itself at mult=128 -------------------
    # (the acceptance claim: the run *completes*, and completion stays on
    # the ~55 ms plateau the x1..x64 rows sit on)
    from repro.core import mining_workload
    root = build_orchestrators(tbb.graph, heye_traverser(tbb.graph))
    session = SchedulerSession(tbb.graph, root,
                               truth=ground_truth_traverser(tbb.graph, 0))
    wcfg = mining_workload(tbb, n_sensors=12 * bmult, n_readings=1)
    t0 = time.perf_counter()
    session.submit(wcfg)
    session.map_pending()
    map_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    stats = session.execute()
    exec_s = time.perf_counter() - t0
    per: dict = {}
    for task in wcfg:
        key = (task.attrs["sensor"], round(task.release_time, 6))
        per[key] = max(per.get(key, 0.0), stats.timeline.latency(task))
    completion_ms = float(np.mean(list(per.values()))) * 1e3
    t.add(f"weak_mining_x{bmult}_completion", completion_ms, "ms",
          devices=sum(ec.values()) + sum(sc.values()),
          tasks=len(list(wcfg)))
    t.add(f"x{bmult}_map_s", map_s, "s")
    t.add(f"x{bmult}_exec_s", exec_s, "s")
    t.add(f"x{bmult}_route_rows_built", tbb.graph.route_row_builds,
          "rows", routable=len(comp.routable_names))
    if not smoke and not completion_ms < 120.0:
        raise AssertionError(
            f"mult=128 weak-scaling completion {completion_ms:.1f}ms fell "
            "off the ~55ms plateau (budget: <120ms incl. noise)")

    payload = {
        "figure": t.figure,
        "smoke": smoke,
        "rows": {r.name: {"value": r.value, "unit": r.unit, **r.extra}
                 for r in t.rows},
    }
    if not smoke:
        _JSON.write_text(json.dumps(payload, indent=2) + "\n")
    if check and baseline is not None and not smoke:
        old = baseline["rows"].get("des_events_per_sec", {}).get("value")
        new = t.get("des_events_per_sec")
        if old is not None and new < 0.8 * old:
            t.print_csv()
            print(f"REGRESSION: des_events_per_sec {new:.0f} < 80% of "
                  f"baseline {old:.0f}")
            sys.exit(1)
        if t.get("des_speedup") < 3.0:
            t.print_csv()
            print(f"REGRESSION: des_speedup {t.get('des_speedup'):.2f}x "
                  "< 3x over the seed heapq loop")
            sys.exit(1)
    return t


if __name__ == "__main__":
    args = sys.argv[1:]
    run(smoke="--smoke" in args, check="--check" in args).print_csv()
