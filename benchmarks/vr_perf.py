"""Fig. 11 reproduction on the VR application:

(a) per-device pipeline (frame) latency under H-EYE vs ACE/LaTS —
    improvement % and bottleneck identification;
(b) minimum number of shared servers that holds the target FPS;
(c) QoS failure per frame across edge:server ratios.

QoS is frame-level, per the paper's metric ("how many frames are processed
later than the latency requirement").
"""
from __future__ import annotations

import numpy as np

from repro.core import Runtime, build_testbed, vr_workload
from repro.core.topology import EDGE_FPS
from repro.core.workloads import vr_frame_latencies, vr_frame_qos_failure

from .common import Table, make_policy

FIVE_EDGES = {"orin_agx": 1, "xavier_agx": 1, "orin_nano": 1, "xavier_nx": 2}


def _run_vr(edge_counts, server_counts, policy_name, n_frames=12, seed=0,
            fps_scale=1.0):
    tb = build_testbed(edge_counts=edge_counts, server_counts=server_counts)
    fps = {e: EDGE_FPS[tb.edge_kind[e]] * fps_scale for e in tb.edges}
    cfg = vr_workload(tb, n_frames=n_frames, fps_override=fps)
    pol = make_policy(policy_name, tb)
    stats = Runtime(tb.graph, seed=seed).run(cfg, pol)
    return tb, cfg, stats


def _per_edge_means(cfg, stats):
    lats = vr_frame_latencies(cfg, stats.timeline)
    per = {}
    for (edge, _), v in lats.items():
        per.setdefault(edge, []).append(v)
    return {e: float(np.mean(v)) for e, v in per.items()}


def run() -> Table:
    t = Table("fig11", "VR: latency vs baselines, min servers, QoS scaling")

    # ---- (a) five edges, three servers: H-EYE vs ACE vs LaTS -------------
    frame_lat, qos = {}, {}
    for pol in ("heye", "ace", "lats"):
        tb, cfg, stats = _run_vr(FIVE_EDGES, {"server1": 1, "server2": 1,
                                              "server3": 1}, pol)
        frame_lat[pol] = _per_edge_means(cfg, stats)
        qos[pol] = vr_frame_qos_failure(cfg, stats.timeline)
        t.add(f"mean_frame_latency_{pol}",
              float(np.mean(list(frame_lat[pol].values()))) * 1e3, "ms")
        t.add(f"frame_qos_failure_{pol}", qos[pol] * 100, "%")
    improvements = []
    for e in frame_lat["heye"]:
        imp = (frame_lat["ace"][e] - frame_lat["heye"][e]) \
            / frame_lat["ace"][e] * 100
        improvements.append(imp)
        t.add(f"improvement_vs_ace_{e}", imp, "%")
    t.add("improvement_max", max(improvements), "%", paper=47.0)
    t.add("improvement_min", min(improvements), "%", paper=11.0)

    # bottleneck identification: which side contributes the contention +
    # queueing inflation of each pipeline (the side whose extra capacity
    # would shorten frames — the paper deduces "adding an extra server
    # could enhance performance" from the same analysis).  The exact 3/2
    # split of the paper depends on their unlabeled Fig. 9 measurements;
    # with our digitized values the shared servers are the contention
    # locus for every pipeline.
    tb, cfg, stats = _run_vr(FIVE_EDGES, {"server1": 1, "server2": 1,
                                          "server3": 1}, "heye")
    tl = stats.timeline
    server_btl = 0
    for e in tb.edges:
        infl = {"edge": 0.0, "server": 0.0}
        for task in cfg:
            if task.origin != e:
                continue
            inflation = ((tl.finish[task.uid] - tl.start[task.uid])
                         - tl.standalone[task.uid]
                         + tl.queue_wait.get(task.uid, 0.0))
            dev = tb.graph.device_of(stats.mapping[task.uid]).name
            infl["server" if dev in tb.servers else "edge"] += max(0., inflation)
        side = "server" if infl["server"] > infl["edge"] else "edge"
        server_btl += side == "server"
        t.add(f"bottleneck_{e}", 1.0 if side == "server" else 0.0,
              "is_server")
    t.add("n_server_bottlenecks", server_btl, "devices", paper=3)

    # ---- (b) minimum servers holding target FPS --------------------------
    min_servers = None
    for n_srv, sc in ((2, {"server1": 1, "server2": 1}),
                      (3, {"server1": 1, "server2": 1, "server3": 1}),
                      (4, {"server1": 2, "server2": 1, "server3": 1})):
        tb, cfg, stats = _run_vr(FIVE_EDGES, sc, "heye")
        fail = vr_frame_qos_failure(cfg, stats.timeline)
        t.add(f"frame_qos_failure_{n_srv}servers", fail * 100, "%")
        if fail <= 0.05 and min_servers is None:
            min_servers = n_srv
    t.add("min_servers_for_fps", min_servers or -1, "servers", paper=3)

    # ---- (c) QoS failure vs edge:server ratio -----------------------------
    for n_edges, n_srv in ((2, 1), (4, 1), (4, 2), (8, 2), (8, 4)):
        ec = {"orin_agx": n_edges // 2, "orin_nano": n_edges - n_edges // 2}
        sc = {"server1": (n_srv + 1) // 2, "server2": n_srv // 2}
        sc = {k: v for k, v in sc.items() if v}
        tb, cfg, stats = _run_vr(ec, sc, "heye", n_frames=8)
        t.add(f"frame_qos_fail_{n_edges}e_{n_srv}s",
              vr_frame_qos_failure(cfg, stats.timeline) * 100, "%",
              ratio=round(n_edges / n_srv, 1))
    return t


if __name__ == "__main__":
    run().print_csv()
