"""Online serving continuum throughput: ServeLoop co-simulation gates.

Drives seeded open-loop traffic (a Poisson tenant + a diurnal tenant,
rates scaled with ``mult``) through the session-resident timeline on the
Fig. 13 mining topology at mult=8 and mult=64 (smoke: mult=2).  Each run
asserts the zero-rebuild guarantee (``engine_opens == 1``) and records

* sustained co-simulation throughput (``wall_rps`` — requests processed
  per wall-clock second, the gated metric),
* tail latency (p50/p99/p999, simulated time — deterministic per seed),
* per-tenant SLA attainment (a reject counts as a miss) and
  rejected/deferred counts.

Emits ``BENCH_serve.json``; ``--check`` fails (exit 1) when ``wall_rps``
at either scale regresses >20% vs the checked-in baseline, when p99
drifts >20% (it is seed-deterministic, so drift means the engine's event
order changed), or when SLA attainment drops >2 points; ``--smoke`` runs
a seconds-scale variant for CI.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import os

from repro.core import (DiurnalArrivals, PoissonArrivals, ServeLoop,
                        TenantSpec, build_orchestrators, build_testbed,
                        ground_truth_traverser, heye_traverser,
                        single_task_request)
from repro.serve.admission import AdaptiveWindow, AdmissionController

from .common import Table, check_gate, fail_gates, write_payload
from .scaling import mining_counts

_JSON = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

# ~115 * mult offered rps over a horizon that shrinks with mult, so every
# scale serves a comparable ~1.1k-request stream and wall_rps isolates
# per-request co-simulation cost (bigger fleet, same request count)
_MINING_RATE = 75.0
_VISION_BASE, _VISION_PEAK = 20.0, 60.0
_HORIZON = 10.0
# absolute co-simulation throughput floor at the largest scale: the
# session-resident walk state keeps steady-state serving O(changed
# devices), worth >=3x the cold-walk baseline on the reference machine
_X64_WALL_RPS_FLOOR = 200.0


def _serve_once(mult: int, batch_window=0.0):
    ec, sc = mining_counts(mult)
    tb = build_testbed(edge_counts=ec, server_counts=sc)
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    horizon = _HORIZON / mult
    tenants = [
        TenantSpec("mining",
                   PoissonArrivals(rate=_MINING_RATE * mult, seed=11),
                   single_task_request("svm", origin=tb.edges[0], sla=0.10),
                   sla=0.10),
        TenantSpec("vision",
                   DiurnalArrivals(base_rate=_VISION_BASE * mult,
                                   peak_rate=_VISION_PEAK * mult,
                                   period=horizon, seed=12),
                   single_task_request("mlp", origin=tb.edges[1], sla=0.15),
                   sla=0.15),
    ]
    loop = ServeLoop(tb.graph, root, tenants,
                     truth=ground_truth_traverser(tb.graph, 0),
                     admission=AdmissionController(slack=4.0,
                                                   defer_delay=0.005,
                                                   max_defers=1),
                     batch_window=batch_window,
                     horizon=horizon)
    stats = loop.run()
    if stats.engine_opens != 1:
        raise AssertionError(
            f"x{mult}: {stats.engine_opens} TimelineEngine builds "
            "(the resident-timeline guarantee is exactly 1)")
    counters = {
        "route_holder_copies": tb.graph.route_holder_copies,
        "route_overlay_copies": tb.graph.route_overlay_copies,
        "route_overlay_compactions": tb.graph.route_overlay_compactions,
        "route_row_builds": tb.graph.route_row_builds,
    }
    return stats, counters


def _assert_fastpath_parity(mult: int) -> None:
    """Whole-run equivalence of the serving fast path against the cold
    per-wave walk (``REPRO_SERVE_FASTPATH=0``): verdicts, reject reasons
    and completion times must agree to 1e-9."""
    fast, _ = _serve_once(mult)
    old = os.environ.get("REPRO_SERVE_FASTPATH")
    os.environ["REPRO_SERVE_FASTPATH"] = "0"
    try:
        cold, _ = _serve_once(mult)
    finally:
        if old is None:
            del os.environ["REPRO_SERVE_FASTPATH"]
        else:
            os.environ["REPRO_SERVE_FASTPATH"] = old
    if len(fast.requests) != len(cold.requests):
        raise AssertionError(
            f"fastpath parity x{mult}: {len(fast.requests)} requests vs "
            f"{len(cold.requests)} on the oracle path")
    import math
    for a, b in zip(fast.requests, cold.requests):
        if a.verdict != b.verdict or a.reject_reason != b.reject_reason:
            raise AssertionError(
                f"fastpath parity x{mult}: request {a.rid} "
                f"{a.verdict}/{a.reject_reason!r} vs "
                f"{b.verdict}/{b.reject_reason!r}")
        if math.isnan(a.finish) and math.isnan(b.finish):
            continue
        if abs(a.finish - b.finish) > 1e-9:
            raise AssertionError(
                f"fastpath parity x{mult}: request {a.rid} finish "
                f"{a.finish!r} vs {b.finish!r}")


def run(smoke: bool = False, check: bool = False) -> Table:
    t = Table("serve", "online serving continuum: resident-timeline loop")
    baseline = json.loads(_JSON.read_text()) if _JSON.exists() else None

    mults = [2] if smoke else [8, 64]
    counters: dict = {}
    last_stats = None
    for mult in mults:
        t0 = time.perf_counter()
        stats, counters = _serve_once(mult)
        last_stats = stats
        s = stats.summary()
        t.add(f"x{mult}_requests", s["requests"], "req",
              accepted=s["accepted"], rejected=s["rejected"],
              deferrals=s["deferrals"])
        t.add(f"x{mult}_wall_rps", s["wall_rps"], "req/s",
              wall_s=round(stats.wall_s, 3))
        t.add(f"x{mult}_served_rps", s["served_rps"], "req/s",
              offered_rps=round(s["offered_rps"], 1))
        t.add(f"x{mult}_p50_ms", s["p50_ms"], "ms")
        t.add(f"x{mult}_p99_ms", s["p99_ms"], "ms")
        t.add(f"x{mult}_p999_ms", s["p999_ms"], "ms")
        t.add(f"x{mult}_sla_attainment", s["sla_attainment"], "frac",
              **{f"sla_{k}": round(v, 4)
                 for k, v in s["sla_by_tenant"].items()})
        t.add(f"x{mult}_engine_opens", s["engine_opens"], "builds",
              n_events=s["n_events"], mapped_tasks=s["mapped_tasks"],
              total_s=round(time.perf_counter() - t0, 2))

    if smoke:
        # CI parity drill: the small-wave fast path must be whole-run
        # bit-equivalent to the cold per-wave walk
        _assert_fastpath_parity(2)
    else:
        # overload-adaptive coalescing at the largest scale (reported,
        # not gated: wave shapes are the point, wall varies with load)
        stats, _ = _serve_once(64, batch_window=AdaptiveWindow(
            max_window=0.002))
        s = stats.summary()
        hist = stats.wave_size_hist()
        t.add("x64_adaptive_wall_rps", s["wall_rps"], "req/s",
              wall_s=round(stats.wall_s, 3))
        t.add("x64_adaptive_p99_ms", s["p99_ms"], "ms",
              sla=round(s["sla_attainment"], 4))
        t.add("x64_adaptive_max_wave", max(hist), "req",
              waves=sum(hist.values()))

    gates = {f"x{mult}_{metric}": thr for mult in mults for metric, thr in (
        ("wall_rps", {"floor_ratio": 0.8}),
        ("p99_ms", {"ceil_ratio": 1.2}),
        ("sla_attainment", {"floor_delta": 0.02}),
    )}
    gates["x64_wall_rps_abs"] = {"floor_abs": _X64_WALL_RPS_FLOOR}
    # route-table copy/build counters plus the per-phase wall breakdown
    # and wave-size histogram of the largest gated run, surfaced in the
    # payload meta so baseline diffs show COW/fast-path behaviour changes
    extra_meta = {k: int(v) for k, v in counters.items()}
    if last_stats is not None:
        extra_meta["phase_wall"] = {
            k: round(v, 3) for k, v in last_stats.phase_wall.items()}
        extra_meta["wave_size_hist"] = {
            str(k): v for k, v in sorted(last_stats.wave_size_hist().items())}
    write_payload(t, _JSON, smoke, gates, extra_meta=extra_meta)
    if check and not smoke:
        msgs = [msg for mult in mults for msg in (
            check_gate(t, baseline, f"x{mult}_wall_rps", floor_ratio=0.8),
            check_gate(t, baseline, f"x{mult}_p99_ms", ceil_ratio=1.2,
                       note="seed-deterministic: the event order changed"),
            check_gate(t, baseline, f"x{mult}_sla_attainment",
                       floor_delta=0.02),
        )]
        # absolute floor on the flagship metric: the serving fast path
        # holds >=3x the PR 9 steady-state throughput regardless of
        # which baseline file is checked in
        rps = t.get("x64_wall_rps")
        if rps < _X64_WALL_RPS_FLOOR:
            msgs.append(
                f"x64_wall_rps={rps:.1f} below the absolute floor "
                f"{_X64_WALL_RPS_FLOOR} (serving fast path regressed)")
        fail_gates(t, msgs)
    return t


if __name__ == "__main__":
    args = sys.argv[1:]
    run(smoke="--smoke" in args, check="--check" in args).print_csv()
