"""Fig. 12 reproduction: dynamic adaptability.

(a/b) dynamic network bandwidth: throttle one edge's uplink from 10 Gb/s to
      1 Gb/s; H-EYE re-balances placements and keeps the frame QoS without
      reducing resolution (CloudVR's strategy, shown for contrast, shrinks
      the frame — modeled as task size reduction — as soon as comm no
      longer fits);
(c)   a new edge joining a running system is re-planned in milliseconds.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Churn, Runtime, build_testbed, vr_workload
from repro.core.workloads import vr_frame_qos_failure
from repro.core.topology import EDGE_FPS

from .common import Table, make_policy

Gb = 1e9 / 8
EDGES = {"orin_agx": 1, "xavier_agx": 1, "orin_nano": 1, "xavier_nx": 2}
SERVERS = {"server1": 1, "server2": 1, "server3": 1}


def run() -> Table:
    t = Table("fig12", "dynamic bandwidth + new edge joining")

    # ---- (a/b) bandwidth throttling on orin_agx ---------------------------
    for bw_gbps in (10.0, 7.5, 5.0, 2.5, 1.0):
        tb = build_testbed(edge_counts=EDGES, server_counts=SERVERS)
        target = tb.edges[0]                      # orin_agx
        tb.graph.apply_churn(Churn(bandwidth=[(f"link_{target}", bw_gbps * Gb)]))
        cfg = vr_workload(tb, n_frames=10)
        stats = Runtime(tb.graph, seed=0).run(cfg, make_policy("heye", tb))
        fail = vr_frame_qos_failure(cfg, stats.timeline)
        # resolution kept at 100%: H-EYE re-balances instead of shrinking
        t.add(f"heye_qos_fail_{bw_gbps}gbps", fail * 100, "%", resolution=100)
        # how much of the pipeline stayed on servers (re-balancing visible)
        remote = np.mean([tb.graph.device_of(stats.mapping[x.uid]).name
                          in tb.servers for x in cfg if x.origin == target])
        t.add(f"heye_remote_frac_{bw_gbps}gbps", float(remote) * 100, "%")

        # CloudVR-like: placement fixed (render/encode on server); when the
        # round trip no longer fits the render share, shrink the frame until
        # it does (resolution = task size scaling)
        tb2 = build_testbed(edge_counts=EDGES, server_counts=SERVERS)
        tb2.graph.apply_churn(
            Churn(bandwidth=[(f"link_{tb2.edges[0]}", bw_gbps * Gb)]))
        comm = tb2.graph.transfer_time(tb2.edges[0], tb2.servers[1], 250e3)
        period = 1.0 / EDGE_FPS["orin_agx"]
        budget = 0.33 * period                   # render+encode pipeline slice
        base = 6.5e-3 + 2.2e-3
        resolution = 100.0
        while (base * (resolution / 100)
               + comm * (resolution / 100)) > budget and resolution > 25:
            resolution -= 12.5                   # step down like CloudVR tiers
        t.add(f"cloudvr_resolution_{bw_gbps}gbps", resolution, "%")

    # ---- (c) new edge joins an active system -----------------------------
    for scale, (ec, sc) in enumerate((
            ({"orin_agx": 1, "orin_nano": 1}, {"server1": 1, "server2": 1}),
            ({"orin_agx": 2, "orin_nano": 2},
             {"server1": 1, "server2": 1, "server3": 1}),
            ({"orin_agx": 3, "orin_nano": 3},
             {"server1": 2, "server2": 2})), 1):
        tb = build_testbed(edge_counts=ec, server_counts=sc)
        cfg = vr_workload(tb, n_frames=6)
        pol = make_policy("heye", tb)
        stats = Runtime(tb.graph, seed=0).run(cfg, pol)
        before = vr_frame_qos_failure(cfg, stats.timeline)

        # a xavier_nx joins: extend the SAME graph + orc tree dynamically
        from repro.core.topology import build_edge_device
        from repro.core import build_orchestrators, heye_traverser
        t0 = time.time()
        build_edge_device(tb.graph, "newcomer", "xavier_nx",
                          parent="edge_cluster")
        tb.graph.add_edge("newcomer", "router", bandwidth=1e9,
                          latency=0.3e-3, name="link_newcomer")
        tb.edges.append("newcomer")
        tb.edge_kind["newcomer"] = "xavier_nx"
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        replan_ms = (time.time() - t0) * 1e3
        cfg2 = vr_workload(tb, n_frames=6)
        from repro.core import OrchestratorPolicy
        stats2 = Runtime(tb.graph, seed=0).run(cfg2, OrchestratorPolicy(root))
        after = vr_frame_qos_failure(cfg2, stats2.timeline)
        t.add(f"join_scale{scale}_qos_before", before * 100, "%")
        t.add(f"join_scale{scale}_qos_after", after * 100, "%")
        t.add(f"join_scale{scale}_replan", replan_ms, "ms")
    return t


if __name__ == "__main__":
    run().print_csv()
