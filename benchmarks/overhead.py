"""Fig. 14 reproduction: orchestrator scheduling overhead as the system
scales — overhead ratio (schedule time / task execution time) stays in the
low single-digit percents, dominated by communication (remote hops), not
local computation."""
from __future__ import annotations

import numpy as np

from repro.core import Runtime, build_testbed, mining_workload, vr_workload

from .common import Table, make_policy


def run() -> Table:
    t = Table("fig14", "orchestrator scheduling overhead")

    # mining at three scales
    for mult in (1, 2, 4):
        ec = {"orin_agx": mult, "xavier_agx": mult,
              "orin_nano": mult, "xavier_nx": mult}
        sc = {"server1": mult, "server2": mult}
        tb = build_testbed(edge_counts=ec, server_counts=sc)
        # enough sensors that edges saturate and readings escalate to servers
        cfg = mining_workload(tb, n_sensors=24 * mult, n_readings=3)
        stats = Runtime(tb.graph, seed=0).run(cfg, make_policy("heye", tb))
        ratio = stats.mean_overhead_ratio(cfg)
        t.add(f"mining_x{mult}_overhead", ratio * 100, "%", paper="<2")
        # communication share of the overhead (paper: >90% is communication)
        from repro.core import OrcConfig
        lqc = OrcConfig().local_query_cost
        comm_oh, total_oh = 0.0, 0.0
        for uid, oh in stats.overhead.items():
            q = stats.queries.get(uid, 0)
            local = q * lqc
            total_oh += oh
            comm_oh += max(0.0, oh - local)
        if total_oh > 0:
            t.add(f"mining_x{mult}_comm_share", comm_oh / total_oh * 100, "%",
                  paper=">90")

    # VR at two scales
    for mult in (1, 2):
        ec = {"orin_agx": mult, "xavier_agx": mult, "orin_nano": mult,
              "xavier_nx": 2 * mult}
        sc = {"server1": mult, "server2": mult, "server3": mult}
        tb = build_testbed(edge_counts=ec, server_counts=sc)
        cfg = vr_workload(tb, n_frames=6)
        stats = Runtime(tb.graph, seed=0).run(cfg, make_policy("heye", tb))
        t.add(f"vr_x{mult}_overhead", stats.mean_overhead_ratio(cfg) * 100,
              "%", paper="~4")
    return t


if __name__ == "__main__":
    run().print_csv()
