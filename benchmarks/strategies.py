"""Fig. 15 reproduction: alternative assignment strategies.

* default        — edge ORC -> parent hierarchy (Alg. 1)
* direct-server  — edges query the server cluster ORC directly, skipping
                   sibling edges (helps VR, hurts mining)
* sticky         — re-use the previously assigned PU for the same (origin,
                   kind) while its constraint still holds
* grouped        — all simultaneously-ready tasks of one origin assigned in
                   one batch (one overhead charge; de-grouped on failure)

Plus overhead vs load (generation rate scaled 0.75x / 1x / 1.25x).
"""
from __future__ import annotations

import numpy as np

from repro.core import (OrcConfig, Runtime, build_orchestrators,
                        build_testbed, heye_traverser, mining_workload,
                        vr_workload)
from repro.core.orchestrator import MapResult
from repro.core.simulator import OrchestratorPolicy
from repro.core.workloads import vr_frame_qos_failure

from .common import Table, mean_latency


class DirectServerPolicy(OrchestratorPolicy):
    """Bypass edge siblings: constraint-check own device, then go straight
    to the server cluster's ORC."""

    def __init__(self, root, tb):
        super().__init__(root)
        self.server_orc = next(o for o in root.iter_tree()
                               if o.group == "server_cluster")

    def __call__(self, task, now):
        orc = self.root.find_device_orc(task.origin)
        res = orc._traverse_children(task, now)
        if res is None:
            res = self.server_orc._traverse_children(task, now)
            if res is not None:
                res.hops += 1
                res.overhead += orc._hop_cost(self.server_orc)
        if res is None:
            # fall back to full search
            return orc.map_batch([task], now)[0]
        orc.ledger.add(task, res.pu, res.prediction, now)
        task.assigned_pu = res.pu
        return res


class StickyPolicy(OrchestratorPolicy):
    """Re-communicate with the PU used for the previous task of this kind."""

    def __init__(self, root):
        super().__init__(root)
        self.last: dict[tuple, str] = {}

    def __call__(self, task, now):
        key = (task.origin, task.kind)
        orc = self.root.find_device_orc(task.origin)
        if key in self.last:
            pu = self.last[key]
            ok, pred = orc._check_constraints(task, pu, now)
            if ok:
                orc.ledger.add(task, pu, pred, now)
                task.assigned_pu = pu
                return MapResult(pu=pu, prediction=pred, queries=1,
                                 overhead=orc.config.local_query_cost)
        res = orc.map_batch([task], now)[0]
        if res is not None:
            self.last[key] = res.pu
        return res


class GroupedPolicy(OrchestratorPolicy):
    """Tasks released at the same instant from one origin share one
    scheduling round trip (overhead charged once; paper: grouping helps
    mining, hurts VR when de-grouping kicks in)."""

    def __init__(self, root):
        super().__init__(root)
        self._batch: dict[tuple, int] = {}

    def __call__(self, task, now):
        orc = self.root.find_device_orc(task.origin)
        res = orc.map_batch([task], now)[0]
        if res is None:
            return None
        key = (task.origin, round(now, 9))
        first = key not in self._batch
        self._batch[key] = self._batch.get(key, 0) + 1
        if not first and res.hops > 0:
            # subsequent members of the batch ride the same message
            res.overhead = res.queries * orc.config.local_query_cost
        return res


def _policies(tb):
    def fresh_root():
        return build_orchestrators(tb.graph, heye_traverser(tb.graph))
    return {
        "default": OrchestratorPolicy(fresh_root()),
        "direct_server": DirectServerPolicy(fresh_root(), tb),
        "sticky": StickyPolicy(fresh_root()),
        "grouped": GroupedPolicy(fresh_root()),
    }


def run() -> Table:
    t = Table("fig15", "assignment strategies + overhead vs load")
    EC = {"orin_agx": 1, "xavier_agx": 1, "orin_nano": 1, "xavier_nx": 2}
    SC = {"server1": 1, "server2": 1, "server3": 1}

    # ---- strategy comparison, VR + mining ---------------------------------
    for app in ("vr", "mining"):
        for name in ("default", "direct_server", "sticky", "grouped"):
            tb = build_testbed(edge_counts=EC, server_counts=SC)
            pol = _policies(tb)[name]
            if app == "vr":
                cfg = vr_workload(tb, n_frames=8)
            else:
                cfg = mining_workload(tb, n_sensors=12, n_readings=3)
            stats = Runtime(tb.graph, seed=0).run(cfg, pol)
            t.add(f"{app}_{name}_latency", mean_latency(stats, cfg) * 1e3,
                  "ms")
            t.add(f"{app}_{name}_overhead",
                  stats.mean_overhead_ratio(cfg) * 100, "%")

    # ---- overhead vs load (generation rate) -------------------------------
    for label, hz in (("20hz", 20.0), ("10hz", 10.0), ("5hz", 5.0)):
        tb = build_testbed(edge_counts=EC, server_counts=SC)
        cfg = mining_workload(tb, n_sensors=12, n_readings=3, hz=hz)
        pol = _policies(tb)["default"]
        stats = Runtime(tb.graph, seed=0).run(cfg, pol)
        t.add(f"mining_load_{label}_overhead",
              stats.mean_overhead_ratio(cfg) * 100, "%")
    return t


if __name__ == "__main__":
    run().print_csv()
