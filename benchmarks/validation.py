"""Fig. 10 reproduction: prediction accuracy of H-EYE vs a contention-blind
ACE-like model against ground truth.

(a) max sensors under the 100 ms threshold on Orin Nano + server-1, with
    per-design prediction error;
(b) max deployable sensors as nodes scale (E1..E3 + servers 1,2),
    predicted vs actual.
"""
from __future__ import annotations

import numpy as np

from repro.core import (NoSlowdown, Runtime, Traverser, build_orchestrators,
                        build_testbed, heye_traverser, mining_workload,
                        OrchestratorPolicy)

from .common import Table


def _latency_under(tb, n_sensors: int, traverser, seed=0) -> float:
    """Mean reading latency for n_sensors scheduled by the H-EYE orchestrator
    but *predicted* by ``traverser`` (prediction experiment, §5.2)."""
    cfg = mining_workload(tb, n_sensors=n_sensors, n_readings=3)
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    pol = OrchestratorPolicy(root)
    rt = Runtime(tb.graph, seed=seed)
    stats = rt.run(cfg, pol)
    # prediction of the same frozen mapping by `traverser`
    pred_tl = traverser.traverse(cfg, stats.mapping)
    truth_tl = stats.timeline
    errs = [abs(pred_tl.latency(t) - truth_tl.latency(t)) / truth_tl.latency(t)
            for t in cfg if truth_tl.latency(t) > 0]
    return float(np.mean(errs))


def run() -> Table:
    t = Table("fig10", "model validation: H-EYE vs contention-blind (ACE)")

    # (a) Orin Nano + server-1, increasing sensors
    tb = build_testbed(edge_counts={"orin_nano": 1},
                       server_counts={"server1": 1})
    heye = heye_traverser(tb.graph)
    blind = Traverser(tb.graph, slowdown=NoSlowdown(tb.graph))
    errs_h, errs_a = [], []
    for n in (10, 20, 30, 40):
        e_h = _latency_under(tb, n, heye, seed=n)
        e_a = _latency_under(tb, n, blind, seed=n)
        errs_h.append(e_h)
        errs_a.append(e_a)
        t.add(f"err_heye_{n}sensors", e_h * 100, "%")
        t.add(f"err_ace_{n}sensors", e_a * 100, "%")
    t.add("mean_err_heye", float(np.mean(errs_h)) * 100, "%", paper=3.2)
    t.add("mean_err_ace", float(np.mean(errs_a)) * 100, "%", paper=27.4)

    # (b) capacity estimation as the system scales: how many sensors fit
    # under 100 ms?  (predicted by each model vs ground truth)
    def max_sensors(tb, predict_traverser, truth: bool, seed=1) -> int:
        best = 0
        for n in range(10, 121, 10):
            cfg = mining_workload(tb, n_sensors=n, n_readings=2)
            root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
            stats = Runtime(tb.graph, seed=seed).run(
                cfg, OrchestratorPolicy(root))
            tl = (stats.timeline if truth
                  else predict_traverser.traverse(cfg, stats.mapping))
            ok = all(tl.latency(x) <= 0.100 for x in cfg)
            if ok:
                best = n
            else:
                break
        return best

    scales = [({"orin_agx": 1}, {"server1": 1}),
              ({"orin_agx": 1, "xavier_agx": 1}, {"server1": 1, "server2": 1}),
              ({"orin_agx": 1, "xavier_agx": 1, "orin_nano": 1},
               {"server1": 1, "server2": 1})]
    accs = []
    for i, (ec, sc) in enumerate(scales, 1):
        tbs = build_testbed(edge_counts=ec, server_counts=sc)
        heye_s = heye_traverser(tbs.graph)
        blind_s = Traverser(tbs.graph, slowdown=NoSlowdown(tbs.graph))
        actual = max_sensors(tbs, None, truth=True)
        pred_h = max_sensors(tbs, heye_s, truth=False)
        pred_a = max_sensors(tbs, blind_s, truth=False)
        acc = 1 - abs(pred_h - actual) / max(actual, 1)
        accs.append(acc)
        t.add(f"max_sensors_actual_scale{i}", actual, "sensors")
        t.add(f"max_sensors_heye_scale{i}", pred_h, "sensors")
        t.add(f"max_sensors_ace_scale{i}", pred_a, "sensors")
    t.add("heye_capacity_accuracy", float(np.mean(accs)) * 100, "%",
          paper=98.0)
    return t


if __name__ == "__main__":
    run().print_csv()
