"""Fig. 2 reproduction: shared-resource contention factors on an Orin-AGX
class SoC — the calibration anchors of the slowdown model.  Core-level PUs
are exposed so the L2 (same cluster) vs L3 (cross cluster) split is visible,
exactly as the paper measures it."""
from __future__ import annotations

from repro.core import DecoupledSlowdown, HWGraph, Node, NodeKind, heye_params
from repro.core.topology import build_edge_device, make_task

from .common import Table

# paper's measured relative speeds (Fig. 2)
PAPER = {"cpu_l2": 0.91, "cpu_l3": 0.87, "gpu_mt": 0.66,
         "gpu_dla_dram": 0.68, "cpu_gpu_llc": 0.89}


def run() -> Table:
    t = Table("fig2", "contention factors on Orin AGX (model vs paper)")
    g = HWGraph()
    g.add_node(Node("fleet", NodeKind.GROUP, attrs={"orc_level": "root"}))
    build_edge_device(g, "orin", "orin_agx", parent="fleet", core_level=True)
    sd = DecoupledSlowdown(g, heye_params())

    def rel_speed(kind_a, pu_a, kind_b, pu_b):
        f = sd.factor(make_task(kind_a), f"orin.{pu_a}",
                      [(make_task(kind_b), f"orin.{pu_b}")])
        return 1.0 / f

    cases = {
        # two MM threads on cores of ONE cluster -> contend at the private L2
        "cpu_l2": rel_speed("mm", "cpu0_core0", "mm", "cpu0_core1"),
        # cores of different clusters -> meet at the L3
        "cpu_l3": rel_speed("mm", "cpu0_core0", "mm", "cpu1_core0"),
        # two DNNs multi-tenant on the GPU
        "gpu_mt": rel_speed("dnn", "gpu", "dnn", "gpu"),
        # GPU + DLA share DRAM-class memory
        "gpu_dla_dram": rel_speed("dnn", "dla", "dnn", "gpu"),
        # CPU + GPU share the 4 MB LLC
        "cpu_gpu_llc": rel_speed("mm", "cpu0", "mm", "gpu"),
    }
    for name, speed in cases.items():
        t.add(name, speed, "rel_speed", paper=PAPER[name],
              err_pct=round(abs(speed - PAPER[name]) / PAPER[name] * 100, 2))
    return t


if __name__ == "__main__":
    run().print_csv()
