"""The paper's mining application end to end (§4.2): smart drill-bit
sensors stream force data at 10 Hz; SVM/KNN/MLP must classify the rock type
within 100 ms; H-EYE keeps the deadline as sensors scale, where
contention-blind baselines silently oversubscribe.

    PYTHONPATH=src python examples/edge_cloud_mining.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (AcePolicy, NoSlowdown, OrchestratorPolicy, Runtime,
                        Traverser, build_orchestrators, build_testbed,
                        heye_traverser, mining_workload)

tb = build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                   server_counts={"server1": 1})
print("system:", tb.graph.summary())
print("edges:", tb.edges, "| servers:", tb.servers)

for n_sensors in (10, 20, 30, 40):
    row = {}
    for policy_name in ("heye", "ace"):
        tbx = build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                            server_counts={"server1": 1})
        cfg = mining_workload(tbx, n_sensors=n_sensors, n_readings=3)
        if policy_name == "heye":
            pol = OrchestratorPolicy(
                build_orchestrators(tbx.graph, heye_traverser(tbx.graph)))
        else:
            pol = AcePolicy(tbx.graph, Traverser(
                tbx.graph, slowdown=NoSlowdown(tbx.graph)))
        stats = Runtime(tbx.graph, seed=0).run(cfg, pol)
        # completion = slowest of the 3 ML tasks per reading
        per_reading: dict = {}
        for t in cfg:
            k = (t.attrs["sensor"], round(t.release_time, 6))
            per_reading[k] = max(per_reading.get(k, 0.0),
                                 stats.timeline.latency(t))
        comp = np.mean(list(per_reading.values()))
        misses = np.mean([v > 0.100 for v in per_reading.values()])
        row[policy_name] = (comp * 1e3, misses * 100)
    print(f"{n_sensors:3d} sensors | H-EYE {row['heye'][0]:6.1f} ms "
          f"({row['heye'][1]:4.1f}% late) | contention-blind "
          f"{row['ace'][0]:6.1f} ms ({row['ace'][1]:4.1f}% late)")

print("\nH-EYE keeps readings under the 100 ms deadline by accounting for "
      "shared-resource slowdown;\nthe blind baseline oversubscribes the "
      "fast PUs and misses deadlines as sensors scale.")
