"""Multi-tenant serving on a TPU fleet, driven by the online ServeLoop.

    PYTHONPATH=src python examples/serve_fleet.py             # online loop
    PYTHONPATH=src python examples/serve_fleet.py --offline   # old batch flow

The paper's mechanism, transplanted to the hardware-adaptation target:
request streams with latency SLOs arrive at a two-pod fleet; each pod-level
ORC only sees its own hosts (resource segregation), the fleet ORC only sees
pod aggregates.

**Online (default):** two tenants' open-loop streams (steady Poisson +
a diurnal burst) flow through ``ServeLoop`` — one session-resident
``TimelineEngine`` serves the whole run, every admission wave is mapped
against *current* occupancy, the admission controller defers bursts and
rejects projected SLO misses, and the report is tail latency + per-tenant
SLA attainment (docs/serving.md).

**Offline (--offline):** the original place-then-execute comparison —
one whole wave in a single ``map_batch`` call, then a host failure
(mark_dead -> incremental snapshot delta, no recompile) triggering a
batched re-map via the FT manager (the dynamic-adaptability path of §5.4).

Either way, one stream is then actually executed with the
continuous-batching token engine.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (DiurnalArrivals, PoissonArrivals, ServeLoop, Task,
                        TaskGraph, TenantSpec, build_orchestrators,
                        heye_traverser)
from repro.core.predict import CallableModel
from repro.core.topology import build_tpu_fleet
from repro.ft.manager import FTManager
from repro.models import ParallelCtx, build_model
from repro.serve.admission import AdmissionController
from repro.serve.engine import Request, ServeEngine

OFFLINE = "--offline" in sys.argv[1:]

# --- fleet + performance model ----------------------------------------------
tb = build_tpu_fleet(n_pods=2, hosts_per_pod=2, chips_per_host=4)
g = tb.graph
EST_MS = 18.0      # profiled decode-step time for one stream on one chip
model = CallableModel(fn=lambda t, pu, unit: EST_MS * 1e-3 * t.size)
for chip in g.pus():
    chip.model = model
    chip.max_tenancy = 3
trav = heye_traverser(g)
root = build_orchestrators(g, trav)
print("fleet:", g.summary())


def stream(origin_host, deadline=0.050):
    t = Task(kind="stream", deadline=deadline, usage={"pu": 1.0, "mem": 0.7})
    t.origin = origin_host
    return t


if not OFFLINE:
    # --- online: open-loop tenant streams through the resident timeline -----
    def stream_request(origin_host, deadline):
        def make(rid, t):
            cfg = TaskGraph(f"stream#{rid}")
            task = stream(origin_host, deadline)
            task.release_time = t
            cfg.add(task)
            return cfg
        return make

    HORIZON = 2.0
    tenants = [
        TenantSpec("steady", PoissonArrivals(rate=500.0, seed=1),
                   stream_request("pod0.host0", 0.050), sla=0.050),
        TenantSpec("bursty",
                   DiurnalArrivals(base_rate=50.0, peak_rate=1500.0,
                                   period=HORIZON, seed=2),
                   stream_request("pod1.host0", 0.080), sla=0.080),
    ]
    loop = ServeLoop(g, root, tenants,
                     admission=AdmissionController(slack=1.5,
                                                   defer_delay=0.01,
                                                   max_defers=3),
                     horizon=HORIZON)
    stats = loop.run()
    s = stats.summary()
    print(f"served {s['requests']} requests over {HORIZON:.0f}s sim "
          f"({s['offered_rps']:.0f} offered rps) with "
          f"{s['engine_opens']} engine build: "
          f"{s['accepted']} accepted, {s['rejected']} rejected "
          f"({s['reject_reasons']}), {s['deferrals']} deferrals")
    print(f"tail latency: p50 {s['p50_ms']:.1f}ms  p99 {s['p99_ms']:.1f}ms  "
          f"p999 {s['p999_ms']:.1f}ms")
    for ten, att in s["sla_by_tenant"].items():
        print(f"  SLA attainment[{ten}]: {att:.3f}")
else:
    # --- offline: place a whole admission wave (one map_batch call) ---------
    N = 28     # pod0 holds 8 chips x 3 tenants = 24; the rest spill to pod1
    wave = [stream("pod0.host0") for _ in range(N)]
    results = root.map_batch(wave, now=0.0, route=True)
    by_chip: dict[str, int] = {}
    for res in results:
        by_chip[res.pu] = by_chip.get(res.pu, 0) + 1
    print(f"placed {N} streams on {len(by_chip)} chips in one batch "
          f"(max {max(by_chip.values())} tenants/chip; SLO-bounded)")
    cross_pod = sum(1 for res in results if res and "pod1" in res.pu)
    print(f"{cross_pod} streams escalated to pod1 via the fleet ORC "
          "(pod0's ORC never saw pod1's internals)")

    # --- a host fails: batched re-map of its streams ------------------------
    ft = FTManager(g)
    victims = [t for t, res in zip(wave, results)
               if res and "pod0.host0" in res.pu]
    ft.on_failure(["pod0.host0"])       # mark_dead -> incremental delta patch
    for t in victims:
        root.ledger.remove(t)
        t.origin = "pod0.host1"
    re_placed = ft.remap(root, victims, now=0.0)
    print(f"host failure: {len(victims)} streams re-mapped in one batch "
          f"(snapshot deltas: {g.delta_count}, full recompiles: "
          f"{g.recompile_count}), new chips:",
          sorted({res.pu for res in re_placed}))

# --- actually run one stream with continuous batching ------------------------
cfg = get_config("gemma3-1b").smoke()
lm = build_model(cfg, ParallelCtx(compute_dtype=jnp.float32))
params = lm.init(jax.random.key(0))
eng = ServeEngine(lm, params, max_slots=4, max_len=48)
rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=3).astype(np.int32),
                max_new=6) for i in range(8)]
done = eng.run(reqs)
print(f"engine: {len(done)} requests served, "
      f"{sum(len(r.out) for r in done)} tokens generated "
      f"({eng.admitted_total} slot admissions, "
      f"{eng.slot_rejections} slot-exhaustion refusals)")
