"""Quickstart: the H-EYE public API in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a small edge-cloud system, models it with the HW-GRAPH, predicts
task performance with the Traverser (contention included), maps task
batches with the hierarchical Orchestrator, and runs one VR pipeline end
to end through a SchedulerSession.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (SchedulerSession, build_orchestrators, build_testbed,
                        heye_traverser, ground_truth_traverser, vr_workload)
from repro.core.topology import make_task
from repro.core.workloads import vr_frame_latencies, vr_frame_qos_failure

# --- 1. a diversely scaled edge-cloud system (HW-GRAPH, paper §3.3) --------
tb = build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                   server_counts={"server1": 1, "server2": 1})
g = tb.graph
print("HW-GRAPH:", g.summary())

# the graph answers structural questions algorithmically:
edge = tb.edges[0]
print(f"shared resources of {edge}.dla and {edge}.pva:",
      g.shared_resources(f"{edge}.dla", f"{edge}.pva"))
print(f"compute path of {edge}.gpu:", g.nodes[f"{edge}.gpu"].get_compute_path())

# --- 2. performance prediction with contention (Traverser, §3.4) -----------
trav = heye_traverser(g)
task = make_task("dnn", origin=edge)
alone = trav.predict_task(task, f"{edge}.gpu", active=[])
busy = trav.predict_task(task, f"{edge}.gpu",
                         active=[(make_task("dnn"), f"{edge}.gpu")])
print(f"dnn on {edge}.gpu: alone {alone.total * 1e3:.1f} ms, "
      f"next to another dnn {busy.total * 1e3:.1f} ms "
      f"(slowdown {busy.factor:.2f}x)")

# --- 3. batch-first task mapping (Orchestrator, §3.5 Alg. 1) ----------------
# a whole frontier of ready tasks maps in ONE call; for a single task,
# map a one-element frontier: map_batch([task], now)[0]
root = build_orchestrators(g, trav)
frontier = [make_task("render", origin=tb.edges[1], deadline=0.020,
                      input_bytes=4e3),
            make_task("pose_pred", origin=tb.edges[1], deadline=0.010),
            make_task("dnn", origin=tb.edges[0], deadline=0.100)]
for t, res in zip(frontier, root.map_batch(frontier, now=0.0, route=True)):
    print(f"{t.kind} from {t.origin} -> {res.pu} "
          f"(predicted {res.prediction.total * 1e3:.1f} ms, "
          f"{res.hops} ORC hops, {res.overhead * 1e6:.0f} us overhead)")

# --- 4. a full application run (VR pipeline, §4.1) --------------------------
# SchedulerSession drives dependency-frontier waves through map_batch and
# then executes the frozen mapping on the ground-truth engine
session = SchedulerSession(g, build_orchestrators(g, heye_traverser(g)),
                           truth=ground_truth_traverser(g, seed=0))
cfg = vr_workload(tb, n_frames=8)
stats = session.run(cfg)
lats = vr_frame_latencies(cfg, stats.timeline)
print(f"VR: {len(lats)} frames, mean latency "
      f"{np.mean(list(lats.values())) * 1e3:.1f} ms, "
      f"late frames {vr_frame_qos_failure(cfg, stats.timeline) * 100:.1f}%, "
      f"scheduling overhead {stats.mean_overhead_ratio(cfg) * 100:.2f}%")
