"""Quickstart: the H-EYE public API in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a small edge-cloud system, models it with the HW-GRAPH, predicts
task performance with the Traverser (contention included), maps tasks with
the hierarchical Orchestrator, and runs one VR pipeline end to end.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (Runtime, build_orchestrators, build_testbed,
                        heye_traverser, OrchestratorPolicy, vr_workload)
from repro.core.topology import make_task
from repro.core.workloads import vr_frame_latencies, vr_frame_qos_failure

# --- 1. a diversely scaled edge-cloud system (HW-GRAPH, paper §3.3) --------
tb = build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                   server_counts={"server1": 1, "server2": 1})
g = tb.graph
print("HW-GRAPH:", g.summary())

# the graph answers structural questions algorithmically:
edge = tb.edges[0]
print(f"shared resources of {edge}.dla and {edge}.pva:",
      g.shared_resources(f"{edge}.dla", f"{edge}.pva"))
print(f"compute path of {edge}.gpu:", g.nodes[f"{edge}.gpu"].get_compute_path())

# --- 2. performance prediction with contention (Traverser, §3.4) -----------
trav = heye_traverser(g)
task = make_task("dnn", origin=edge)
alone = trav.predict_task(task, f"{edge}.gpu", active=[])
busy = trav.predict_task(task, f"{edge}.gpu",
                         active=[(make_task("dnn"), f"{edge}.gpu")])
print(f"dnn on {edge}.gpu: alone {alone.total * 1e3:.1f} ms, "
      f"next to another dnn {busy.total * 1e3:.1f} ms "
      f"(slowdown {busy.factor:.2f}x)")

# --- 3. hierarchical task mapping (Orchestrator, §3.5 Alg. 1) --------------
root = build_orchestrators(g, trav)
render = make_task("render", origin=tb.edges[1], deadline=0.020,
                   input_bytes=4e3)
res = root.find_device_orc(tb.edges[1]).map_task(render)
print(f"render (20 ms deadline) from {tb.edges[1]} -> {res.pu} "
      f"(predicted {res.prediction.total * 1e3:.1f} ms, "
      f"{res.hops} ORC hops, {res.overhead * 1e6:.0f} us overhead)")

# --- 4. a full application run (VR pipeline, §4.1) --------------------------
cfg = vr_workload(tb, n_frames=8)
stats = Runtime(g, seed=0).run(cfg, OrchestratorPolicy(root))
lats = vr_frame_latencies(cfg, stats.timeline)
print(f"VR: {len(lats)} frames, mean latency "
      f"{np.mean(list(lats.values())) * 1e3:.1f} ms, "
      f"late frames {vr_frame_qos_failure(cfg, stats.timeline) * 100:.1f}%, "
      f"scheduling overhead {stats.mean_overhead_ratio(cfg) * 100:.2f}%")
