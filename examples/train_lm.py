"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-sized

Uses the real framework path: config registry -> Model -> data pipeline ->
jitted train step (microbatched, remat) -> AdamW -> async checkpoints ->
resume.  The ~100M model is a scaled gemma3 family member defined through
the same ModelConfig machinery as the assigned architectures.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, synthetic_batches
from repro.models import ParallelCtx, build_model
from repro.optim import OptConfig
from repro.train.step import init_train_state, make_train_step
from repro.checkpoint import latest_step, restore


def lm_100m():
    """~100M params: gemma3-style 5:1 local/global interleave."""
    return get_config("gemma3-1b").scaled(
        name="lm-100m", n_layers=6, d_model=640, n_heads=8, n_kv=2,
        head_dim=80, d_ff=2560, vocab=32768, window=256)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = get_config("gemma3-1b").smoke() if args.tiny else lm_100m()
    steps = args.steps or (30 if args.tiny else 200)
    batch, seq = (8, 64) if args.tiny else (4, 256)

    model = build_model(cfg, ParallelCtx(compute_dtype=jnp.float32,
                                         remat="block"))
    n_params = cfg.param_count()
    print(f"[lm] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{steps} steps @ batch {batch} x seq {seq}")

    opt = OptConfig(lr=3e-3, warmup_steps=max(steps // 10, 5),
                    decay_steps=steps)
    state = init_train_state(model, jax.random.key(0), opt)
    start = 0
    if latest_step(args.ckpt) is not None:
        start = latest_step(args.ckpt)
        state = restore(args.ckpt, state)
        print(f"[lm] resumed from checkpoint step {start}")

    step_fn = jax.jit(make_train_step(model, opt, microbatches=2),
                      donate_argnums=(0,))
    data = Prefetcher(synthetic_batches(
        DataConfig(batch=batch, seq=seq, vocab=cfg.vocab, seed=start)), depth=2)

    from repro.checkpoint import AsyncSaver
    saver = AsyncSaver()
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        b = next(data)
        state, metrics = step_fn(state, {k: jnp.asarray(v)
                                         for k, v in b.items()})
        losses.append(float(metrics["loss"]))
        if (step + 1) % 20 == 0:
            dt = (time.time() - t0) / (step + 1 - start)
            print(f"[lm] step {step + 1:4d}  loss {losses[-1]:.4f}  "
                  f"{dt * 1e3:6.1f} ms/step  "
                  f"{batch * seq / dt:8.0f} tok/s", flush=True)
        if (step + 1) % 100 == 0:
            saver.save(state, args.ckpt, step + 1)
    saver.wait()
    data.close()
    drop = losses[0] - np.mean(losses[-10:])
    print(f"[lm] loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"(drop {drop:.3f}) in {time.time() - t0:.0f}s")
    assert drop > 0.2, "training did not learn"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
