"""Integration tests reproducing the paper's core claims at test scale:
contention-aware prediction beats contention-blind baselines (§5.2),
the orchestrator improves latency/QoS over ACE/LaTS (§5.3), and the
workload generators match the paper's applications (§4)."""
import numpy as np
import pytest

from repro.core import (AcePolicy, LatsPolicy, NoSlowdown, OrchestratorPolicy,
                        Runtime, Traverser, build_orchestrators,
                        build_testbed, heye_traverser, mining_workload,
                        vr_workload)
from repro.core.task import TaskGraph
from repro.core.topology import make_task
from repro.core.workloads import (MINING_TASKS, VR_PINNED, VR_TASKS,
                                  vr_frame_latencies)


def _fresh(n_sensors=14, n_readings=4):
    tb = build_testbed(edge_counts={"orin_nano": 1, "xavier_nx": 1},
                       server_counts={"server1": 1})
    cfg = mining_workload(tb, n_sensors=n_sensors, n_readings=n_readings)
    return tb, cfg


def test_heye_prediction_beats_blind_model():
    """§5.2 in miniature: on a contended schedule, H-EYE's Traverser
    predicts ground-truth latency far better than a contention-blind model."""
    tb, cfg = _fresh()
    # contended mapping: round-robin over every capable PU (1-3 co-runners,
    # the regime of the paper's Fig. 10 validation)
    pus = [p.name for p in tb.graph.pus()
           if p.model.supports(list(cfg)[0], p)]
    mapping = {t.uid: pus[i % len(pus)] for i, t in enumerate(cfg)}
    truth = Runtime(tb.graph, seed=1).truth.traverse(cfg, mapping)
    heye_tl = heye_traverser(tb.graph).traverse(cfg, mapping)
    blind_tl = Traverser(tb.graph, slowdown=NoSlowdown(tb.graph)).traverse(
        cfg, mapping)

    def err(tl):
        errs = []
        for t in cfg:
            a = truth.latency(t)
            p = tl.latency(t)
            if a > 0:
                errs.append(abs(p - a) / a)
        return float(np.mean(errs))

    e_heye, e_blind = err(heye_tl), err(blind_tl)
    assert e_heye < e_blind * 0.5, (e_heye, e_blind)
    assert e_heye < 0.10                      # paper: 3.2% avg (noise-limited)
    assert e_blind > 0.15                     # paper: ACE 27.4%


def test_orchestrator_beats_baselines_on_qos():
    """§5.3 in miniature: under load, H-EYE's contention-aware mapping has
    no more QoS failures than contention-blind ACE/LaTS and achieves
    lower mean latency."""
    results = {}
    for name in ("heye", "ace", "lats"):
        tb, cfg = _fresh(n_sensors=16, n_readings=5)
        rt = Runtime(tb.graph, seed=0)
        if name == "heye":
            pol = OrchestratorPolicy(
                build_orchestrators(tb.graph, heye_traverser(tb.graph)))
        elif name == "ace":
            pol = AcePolicy(tb.graph,
                            Traverser(tb.graph, slowdown=NoSlowdown(tb.graph)))
        else:
            pol = LatsPolicy(tb.graph,
                             Traverser(tb.graph, slowdown=NoSlowdown(tb.graph)))
        stats = rt.run(cfg, pol)
        lat = np.mean([stats.timeline.latency(t) for t in cfg])
        results[name] = (stats.qos_failure_rate(cfg), float(lat))
    q_heye, l_heye = results["heye"]
    assert q_heye <= min(results["ace"][0], results["lats"][0]) + 1e-9
    assert l_heye <= 1.05 * min(results["ace"][1], results["lats"][1])


def test_orchestrator_overhead_small():
    """Fig. 14: scheduling overhead stays in the low single-digit percent."""
    tb, cfg = _fresh(n_sensors=10, n_readings=5)
    rt = Runtime(tb.graph, seed=0)
    pol = OrchestratorPolicy(
        build_orchestrators(tb.graph, heye_traverser(tb.graph)))
    stats = rt.run(cfg, pol)
    assert stats.mean_overhead_ratio(cfg) < 0.08


def test_vr_workload_structure():
    tb = build_testbed()
    cfg = vr_workload(tb, n_frames=2)
    per_frame = len(VR_TASKS)
    assert len(cfg) == len(tb.edges) * 2 * per_frame
    for t in cfg:
        assert t.deadline is not None and t.deadline > 0
        if t.kind in VR_PINNED:
            assert t.attrs["pinned"]
    # frame deadline shares sum to the frame period
    frame0 = [t for t in cfg if t.origin == tb.edges[0]
              and t.attrs["frame"] == 0]
    from repro.core.topology import EDGE_FPS
    period = 1.0 / EDGE_FPS[tb.edge_kind[tb.edges[0]]]
    assert sum(t.deadline for t in frame0) == pytest.approx(period, rel=1e-6)


def test_vr_pipeline_end_to_end():
    tb = build_testbed(edge_counts={"orin_agx": 1},
                       server_counts={"server1": 1, "server2": 1})
    cfg = vr_workload(tb, n_frames=3)
    rt = Runtime(tb.graph, seed=0)
    pol = OrchestratorPolicy(
        build_orchestrators(tb.graph, heye_traverser(tb.graph)))
    stats = rt.run(cfg, pol)
    lats = vr_frame_latencies(cfg, stats.timeline)
    assert len(lats) == 3
    # with a server available, rendering must be offloaded (edge GPU cannot
    # hold 30 FPS: 38 ms standalone > 33 ms period)
    render_pus = {stats.mapping[t.uid] for t in cfg if t.kind == "render"}
    assert any(tb.graph.device_of(p).name in tb.servers for p in render_pus)


def test_mining_workload_structure():
    tb = build_testbed()
    cfg = mining_workload(tb, n_sensors=6, n_readings=2)
    assert len(cfg) == 6 * 2 * len(MINING_TASKS)
    for t in cfg:
        assert t.deadline == pytest.approx(0.100)
        assert not list(cfg.preds(t))      # all independent (parallel ML)
