"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs forward / train / prefill+decode on CPU,
asserting shapes and finiteness.  The prefill->decode consistency check is
the strongest cache-correctness test: teacher-forced decode logits must
match the training forward at every position, for every cache type
(global KV, local rolling window, RG-LRU state, RWKV state, cross-attn)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import ParallelCtx, build_model

ARCHS = sorted(all_configs())
CTX = ParallelCtx(compute_dtype=jnp.float32, flash_threshold=1 << 30)


def _batch(cfg, key, B=2, S=24):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            ks[1], (B, cfg.n_patches, cfg.d_model)) * 0.02
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.src_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch, key):
    cfg = all_configs()[arch].smoke()
    model = build_model(cfg, CTX)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits, aux = model.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, key):
    from repro.optim import OptConfig
    from repro.train.step import init_train_state, make_train_step
    cfg = all_configs()[arch].smoke()
    model = build_model(cfg, CTX)
    state = init_train_state(model, key, OptConfig(warmup_steps=1))
    batch = _batch(cfg, key)
    batch["labels"] = batch["tokens"]
    step = make_train_step(model, OptConfig(warmup_steps=1))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(state["params"]), jax.tree.leaves(state2["params"])))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, key):
    """Teacher-forced decode must reproduce the forward logits:
    prefill(tokens[:p]) then decode_step over tokens[p:] == forward logits."""
    cfg = all_configs()[arch].smoke()
    if cfg.frontend == "vision":
        pytest.skip("vlm decode starts from text-only cache; covered below")
    if cfg.n_experts > 0:
        # capacity drops legitimately differ between grouped prefill and
        # per-token decode; raise capacity so this test isolates the caches
        cfg = cfg.scaled(capacity_factor=16.0)
    model = build_model(cfg, CTX)
    params = model.init(key)
    B, S, p = 2, 16, 8
    batch = _batch(cfg, key, B=B, S=S)
    full_logits, _ = model.forward(params, batch)

    cache = model.init_cache(B, S, dtype=jnp.float32)
    pre = {k: (v[:, :p] if k == "tokens" else v) for k, v in batch.items()}
    logits_p, cache = model.prefill(params, pre, cache)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, p - 1]),
                               atol=2e-3, rtol=2e-3)
    for t in range(p, S):
        tok = batch["tokens"][:, t:t + 1]
        pos = jnp.full((B,), t, jnp.int32)
        logits_t, cache = model.decode_step(params, cache, tok, pos)
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3,
            err_msg=f"{arch}: decode step t={t} diverged from forward")


def test_local_window_rolling_cache(key):
    """Decode beyond the window size exercises the rolling buffer."""
    cfg = all_configs()["gemma3-1b"].smoke().scaled(window=8)
    model = build_model(cfg, CTX)
    params = model.init(key)
    B, S, p = 1, 32, 4     # S >> window
    batch = _batch(cfg, key, B=B, S=S)
    full_logits, _ = model.forward(params, batch)
    cache = model.init_cache(B, S, dtype=jnp.float32)
    pre = {"tokens": batch["tokens"][:, :p]}
    _, cache = model.prefill(params, pre, cache)
    for t in range(p, S):
        logits_t, cache = model.decode_step(
            params, cache, batch["tokens"][:, t:t + 1],
            jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3, err_msg=f"rolled window diverged at t={t}")


def test_prefill_longer_than_window(key):
    """Prefill length > window: the rolling buffer must hold the LAST window
    tokens in rolled order."""
    cfg = all_configs()["gemma3-1b"].smoke().scaled(window=8)
    model = build_model(cfg, CTX)
    params = model.init(key)
    B, S, p = 1, 32, 20    # p > window=8
    batch = _batch(cfg, key, B=B, S=S)
    full_logits, _ = model.forward(params, batch)
    cache = model.init_cache(B, S, dtype=jnp.float32)
    _, cache = model.prefill(params, {"tokens": batch["tokens"][:, :p]}, cache)
    for t in range(p, S):
        logits_t, cache = model.decode_step(
            params, cache, batch["tokens"][:, t:t + 1],
            jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3)


def test_vlm_patch_fusion(key):
    """phi3-vision: patch embeddings overwrite the first n_patches slots."""
    cfg = all_configs()["phi-3-vision-4.2b"].smoke()
    model = build_model(cfg, CTX)
    params = model.init(key)
    batch = _batch(cfg, key, B=1, S=16)
    logits1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    logits2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(logits1 - logits2))) > 1e-6
    # without patches the model still runs (text-only)
    logits3, _ = model.forward(params, {"tokens": batch["tokens"]})
    assert np.all(np.isfinite(np.asarray(logits3)))


def test_encdec_cross_attention_depends_on_frames(key):
    cfg = all_configs()["whisper-large-v3"].smoke()
    model = build_model(cfg, CTX)
    params = model.init(key)
    batch = _batch(cfg, key, B=1, S=12)
    logits1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["frames"] = batch["frames"] * -1.0
    logits2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(logits1 - logits2))) > 1e-6


def test_use_kernels_path_matches_jnp(key):
    """ctx.use_kernels=True routes through the Pallas kernels (interpret
    mode on CPU) and must agree with the pure-jnp path."""
    cfg = all_configs()["gemma2-2b"].smoke().scaled(window=16)
    m_jnp = build_model(cfg, CTX)
    m_ker = build_model(cfg, ParallelCtx(compute_dtype=jnp.float32,
                                         use_kernels=True))
    params = m_jnp.init(key)
    batch = _batch(cfg, key, B=1, S=32)
    l1, _ = m_jnp.forward(params, batch)
    l2, _ = m_ker.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=5e-3, rtol=5e-3)


def test_param_count_matches_init(key):
    """Analytic param_count (used for MODEL_FLOPS) ~ actual leaf count."""
    for arch in ("gemma3-1b", "rwkv6-1.6b", "granite-moe-1b-a400m"):
        cfg = all_configs()[arch].smoke()
        model = build_model(cfg, CTX)
        params = model.init(key)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.05, (arch, actual, predicted)
