"""Array-native DES timeline engine: 1e-9 parity with the seed heapq
loop on the paper-figure workloads (Fig. 13 mining, Fig. 14/VR chains),
including mid-run topology churn and zero-duration event pileups, plus
oracle sweeps for the rate-advance / segment-min kernels."""
import itertools

import numpy as np
import pytest

import repro.core.task as task_mod
from repro.core import (SchedulerSession, Task, TaskGraph, Traverser,
                        build_orchestrators, build_testbed,
                        ground_truth_traverser, heye_traverser,
                        mining_workload, vr_workload)
from repro.core.timeline import TimelineEngine
from repro.core.topology import make_task

TOL = 1e-9


def _testbed(mult=1):
    return build_testbed(
        edge_counts={"orin_agx": 2 * mult, "xavier_agx": mult,
                     "orin_nano": mult, "xavier_nx": mult},
        server_counts={"server1": 1, "server2": 1})


def _mapped(workload_fn, seed_uid=400_000, mult=1):
    """Two identical (testbed, cfg, mapping) copies so each engine runs
    on untouched state; mapping comes from a real session drive."""
    out = []
    for _ in range(2):
        task_mod._task_counter = itertools.count(seed_uid)
        tb = _testbed(mult)
        cfg = workload_fn(tb)
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        s = SchedulerSession(tb.graph, root)
        s.submit(cfg)
        s.map_pending()
        out.append((tb, cfg, dict(s.mapping)))
    return out


def _assert_parity(tl_ref, tl_arr, tol=TOL):
    assert set(tl_ref.finish) == set(tl_arr.finish)
    for k in tl_ref.finish:
        assert tl_ref.finish[k] == pytest.approx(tl_arr.finish[k],
                                                 abs=tol, rel=tol), k
    for k in tl_ref.start:
        assert tl_ref.start[k] == pytest.approx(tl_arr.start[k],
                                                abs=tol, rel=tol), k
    for k in tl_ref.queue_wait:
        assert tl_ref.queue_wait[k] == pytest.approx(
            tl_arr.queue_wait.get(k, 0.0), abs=tol, rel=tol), k
    for k in tl_ref.comm:
        assert tl_ref.comm[k] == pytest.approx(tl_arr.comm.get(k, 0.0),
                                               abs=tol, rel=tol), k
    assert tl_ref.n_intervals == tl_arr.n_intervals


# ---------------------------------------------------------------------------
# parity on the paper-figure workloads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noise_seed", [None, 0, 7])
def test_mining_parity(noise_seed):
    """Fig. 13 mining workload: prediction engine and noisy ground truth
    both match the seed event loop (ground truth draws per-task work
    noise at job start — the stream order must survive batching)."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: mining_workload(tb, n_sensors=18, n_readings=2))
    assert m1 == m2
    mk1 = (heye_traverser(tb1.graph) if noise_seed is None
           else ground_truth_traverser(tb1.graph, noise_seed))
    mk2 = (heye_traverser(tb2.graph) if noise_seed is None
           else ground_truth_traverser(tb2.graph, noise_seed))
    _assert_parity(mk1.traverse_reference(cfg1, m1),
                   mk2.traverse(cfg2, m2))


@pytest.mark.parametrize("noise_seed", [None, 3])
def test_vr_parity(noise_seed):
    """VR frame chains (Fig. 7/14 style): serial dependencies, pinned
    stages, cross-device transfers with latency tails."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: vr_workload(tb, n_frames=5), seed_uid=410_000)
    assert m1 == m2
    mk1 = (heye_traverser(tb1.graph) if noise_seed is None
           else ground_truth_traverser(tb1.graph, noise_seed))
    mk2 = (heye_traverser(tb2.graph) if noise_seed is None
           else ground_truth_traverser(tb2.graph, noise_seed))
    _assert_parity(mk1.traverse_reference(cfg1, m1),
                   mk2.traverse(cfg2, m2))


def test_oversubscribed_parity_with_queueing():
    """Tenancy queues + link sharing at 3x load: the regime where
    completion-tie ordering is observable through the noise stream."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: mining_workload(tb, n_sensors=60, n_readings=2),
        seed_uid=420_000)
    _assert_parity(
        ground_truth_traverser(tb1.graph, 1).traverse_reference(cfg1, m1),
        ground_truth_traverser(tb2.graph, 1).traverse(cfg2, m2))


def test_engine_is_default_traverse_path():
    """Traverser.traverse runs on the TimelineEngine (noise-free and
    per-task-noise models); only an rng-bearing *slowdown* model routes
    to the reference loop."""
    tb = _testbed()
    cfg = TaskGraph()
    t = make_task("dnn", origin=tb.edges[0])
    cfg.add(t)
    trav = heye_traverser(tb.graph)
    tl = TimelineEngine(trav, cfg, {t.uid: f"{tb.edges[0]}.gpu"}).run()
    tl2 = trav.traverse(cfg, {t.uid: f"{tb.edges[0]}.gpu"})
    assert tl.finish[t.uid] == tl2.finish[t.uid]


# ---------------------------------------------------------------------------
# churn: mark_dead / set_bandwidth mid-run
# ---------------------------------------------------------------------------
def _churn_pair(seed_uid, fns):
    """Identical runs on both engines with interventions; ``fns`` maps a
    testbed to (t, fn) pairs."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: mining_workload(tb, n_sensors=24, n_readings=2),
        seed_uid=seed_uid)
    tl_ref = ground_truth_traverser(tb1.graph, 2).traverse_reference(
        cfg1, m1, interventions=fns(tb1))
    tl_arr = ground_truth_traverser(tb2.graph, 2).traverse(
        cfg2, m2, interventions=fns(tb2))
    return tl_ref, tl_arr


def test_churn_set_bandwidth_mid_run():
    """A link degrades 100x mid-run: in-flight transfers reprice at the
    intervention instant, identically in both engines."""
    def fns(tb):
        return [(0.02, lambda: tb.graph.set_bandwidth(
            f"link_{tb.edges[0]}", 1e6)),
            (0.15, lambda: tb.graph.set_bandwidth(
                f"link_{tb.edges[0]}", 1e9))]
    tl_ref, tl_arr = _churn_pair(430_000, fns)
    _assert_parity(tl_ref, tl_arr)


def test_churn_mark_dead_mid_run():
    """A device dies (and revives) mid-run: running jobs keep their
    rates until the churn boundary reprices them against the patched
    snapshot; both engines see the same patched factors."""
    def fns(tb):
        e = tb.edges[1]
        return [(0.03, lambda: tb.graph.mark_dead(e)),
                (0.12, lambda: tb.graph.mark_alive(e))]
    tl_ref, tl_arr = _churn_pair(440_000, fns)
    _assert_parity(tl_ref, tl_arr)


def test_churn_route_frozen_before_transit_death():
    """A transit node dies before a late task's first transfer: both
    engines froze the route at traverse start (pre-churn), so the
    transfer still runs the original path instead of one engine lazily
    resolving against the dead graph."""
    def build(seed_uid=445_000):
        task_mod._task_counter = itertools.count(seed_uid)
        tb = _testbed()
        cfg = TaskGraph()
        t = make_task("render", origin=tb.edges[0], input_bytes=1e6,
                      release_time=0.05)
        cfg.add(t)
        return tb, cfg, {t.uid: f"{tb.servers[0]}.gpu"}, t.uid
    tb1, cfg1, m1, uid = build()
    tb2, cfg2, m2, _ = build()
    fns = lambda tb: [(0.01, lambda: tb.graph.mark_dead("edge_cluster"))]
    tl_ref = heye_traverser(tb1.graph).traverse_reference(
        cfg1, m1, interventions=fns(tb1))
    tl_arr = heye_traverser(tb2.graph).traverse(
        cfg2, m2, interventions=fns(tb2))
    assert tl_ref.finish[uid] == pytest.approx(tl_arr.finish[uid], abs=TOL)


def test_churn_bandwidth_affects_transfers():
    """Sanity beyond parity: throttling the uplink mid-transfer actually
    delays the consumer vs the unthrottled run."""
    tb1 = _testbed()
    tb2 = _testbed()
    for tb in (tb1, tb2):
        pass
    def run(tb, throttle):
        cfg = TaskGraph()
        t = make_task("render", origin=tb.edges[0], input_bytes=8e6)
        cfg.add(t)
        mapping = {t.uid: f"{tb.servers[0]}.gpu"}
        iv = ([(1e-4, lambda: tb.graph.set_bandwidth(
            f"link_{tb.edges[0]}", 5e5))] if throttle else [])
        tl = heye_traverser(tb.graph).traverse(cfg, mapping,
                                               interventions=iv)
        return tl.finish[t.uid]
    assert run(tb1, True) > 2.0 * run(tb2, False)


# ---------------------------------------------------------------------------
# zero-duration pileups at a shared timestamp
# ---------------------------------------------------------------------------
def test_zero_duration_pileup_shared_timestamp():
    """A chain of zero-work tasks plus real tasks all releasing at one
    instant: the flush->drain rounds must converge at that timestamp and
    match the seed loop event-for-event."""
    def build(seed_uid):
        task_mod._task_counter = itertools.count(seed_uid)
        tb = _testbed()
        e = tb.edges[0]
        cfg = TaskGraph()
        prev = None
        zs = []
        for i in range(4):        # zero-duration chain
            z = Task(kind="zero", origin=e, release_time=0.01)
            z.attrs["standalone_s"] = 0.0
            cfg.add(z, deps=[prev] if prev else [])
            zs.append(z)
            prev = z
        reals = [make_task("dnn", origin=e, release_time=0.01)
                 for _ in range(3)]
        for r in reals:
            cfg.add(r)
        mapping = {z.uid: f"{e}.cpu0" for z in zs}
        mapping.update({r.uid: f"{e}.gpu" for r in reals})
        return tb, cfg, mapping

    from repro.core.predict import CallableModel
    tb1, cfg1, m1 = build(450_000)
    tb2, cfg2, m2 = build(450_000)
    for tb in (tb1, tb2):
        zero_model = CallableModel(
            fn=lambda t, pu, unit: t.attrs.get("standalone_s", 1e-3))
        for pu in tb.graph.pus():
            pu.model = zero_model
    tl_ref = heye_traverser(tb1.graph).traverse_reference(cfg1, m1)
    tl_arr = heye_traverser(tb2.graph).traverse(cfg2, m2)
    _assert_parity(tl_ref, tl_arr)
    # the chain really collapsed onto one instant
    zs = [t for t in cfg2 if t.kind == "zero"]
    for z in zs:
        assert tl_arr.finish[z.uid] == pytest.approx(0.01, abs=1e-12)


# ---------------------------------------------------------------------------
# background jobs + API details
# ---------------------------------------------------------------------------
def test_background_projection_matches_reference():
    tb1, tb2 = _testbed(), _testbed()
    def run(tb, ref):
        task_mod._task_counter = itertools.count(455_000)
        cfg = TaskGraph()
        a = make_task("dnn", origin=tb.edges[0])
        cfg.add(a)
        bg = make_task("render", origin=tb.edges[0])
        trav = heye_traverser(tb.graph)
        args = (cfg, {a.uid: f"{tb.edges[0]}.gpu"},
                [(bg, f"{tb.edges[0]}.gpu", 0.5)])
        tl = (trav.traverse_reference(*args) if ref
              else trav.traverse(*args))
        return a.uid, bg.uid, tl
    ua, ub, tl_ref = run(tb1, True)
    _, _, tl_arr = run(tb2, False)
    assert tl_ref.finish[ua] == pytest.approx(tl_arr.finish[ua], abs=TOL)
    assert tl_ref.finish[ub] == pytest.approx(tl_arr.finish[ub], abs=TOL)


def test_missing_mapping_raises():
    tb = _testbed()
    cfg = TaskGraph()
    cfg.add(make_task("mm"))
    with pytest.raises(KeyError):
        heye_traverser(tb.graph).traverse(cfg, {})


def test_n_events_counted():
    (tb1, cfg1, m1), _ = _mapped(
        lambda tb: mining_workload(tb, n_sensors=6, n_readings=1),
        seed_uid=460_000)
    tl = heye_traverser(tb1.graph).traverse(cfg1, m1)
    assert tl.n_events >= len(list(cfg1))     # every task at least releases


# ---------------------------------------------------------------------------
# kernels: numpy oracles + interpret-mode Pallas sweeps
# ---------------------------------------------------------------------------
def test_rate_advance_oracle_matches_engine_inline():
    from repro.core.timeline import _rate_advance_np
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    W = rng.uniform(0, 10, 64)
    rate = rng.uniform(0.1, 3.0, 64)
    rate[::5] = 0.0
    rate[3] = np.inf
    t_last = rng.uniform(0, 1, 64)
    t_last[3] = 1.25
    w1, e1 = _rate_advance_np(W, rate, t_last, 1.25)
    w2, e2 = ref.rate_advance_ref(W, rate, t_last, 1.25)
    np.testing.assert_array_equal(w1, w2)
    np.testing.assert_array_equal(e1, e2)
    assert w1[3] == 0.0                       # inf-rate x zero-dt corner


def test_segment_min_oracle():
    from repro.kernels import ref
    vals = np.array([5.0, 2.0, 7.0, 1.0, 9.0])
    counts = np.array([2, 0, 3])
    out = ref.segment_min_ref(vals, counts)
    np.testing.assert_array_equal(out, [2.0, np.inf, 1.0])


def test_timeline_kernels_interpret_sweep():
    jax = pytest.importorskip("jax")
    from repro.kernels import ref
    from repro.kernels import timeline_kernel as tk
    rng = np.random.default_rng(1)
    for n in (1, 7, 128, 300):
        W = rng.uniform(0, 100, n)
        rate = rng.uniform(0.01, 5.0, n)
        rate[:: max(1, n // 3)] = 0.0
        t_last = rng.uniform(0, 2, n)
        w_ref, e_ref = ref.rate_advance_ref(W, rate, t_last, 2.5)
        w_k, e_k = tk.rate_advance_pallas(W, rate, t_last, 2.5)
        np.testing.assert_allclose(w_k, w_ref, rtol=2e-5, atol=1e-5)
        fin = np.isfinite(e_ref)
        assert (np.isfinite(e_k) == fin).all()
        np.testing.assert_allclose(e_k[fin], e_ref[fin], rtol=2e-5,
                                   atol=1e-5)
    for S in (1, 9, 257):
        counts = rng.integers(0, 5, S)
        vals = rng.uniform(1, 50, int(counts.sum()))
        want = ref.segment_min_ref(vals, counts)
        got = tk.segment_min_pallas(vals, counts)
        fin = np.isfinite(want)
        assert (np.isfinite(got) == fin).all()
        np.testing.assert_allclose(got[fin], want[fin], rtol=1e-6)


def test_forced_kernel_mode_runs_engine():
    """REPRO_TIMELINE_KERNEL=pallas routes the engine's settles through
    the interpret-mode kernel (fp32: looser tolerance)."""
    pytest.importorskip("jax")
    import repro.core.timeline as tmod
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: mining_workload(tb, n_sensors=4, n_readings=1),
        seed_uid=470_000)
    tl_ref = heye_traverser(tb1.graph).traverse(cfg1, m1)
    old = (tmod._RATE_ADVANCE, tmod._SEGMENT_MIN)
    try:
        from repro.kernels import timeline_kernel as tk
        tmod._RATE_ADVANCE = tk.rate_advance_forced
        tmod._SEGMENT_MIN = tk.segment_min_forced
        tl_k = heye_traverser(tb2.graph).traverse(cfg2, m2)
    finally:
        tmod._RATE_ADVANCE, tmod._SEGMENT_MIN = old
    for k in tl_ref.finish:
        assert tl_ref.finish[k] == pytest.approx(tl_k.finish[k], rel=1e-3)
