"""Batch-first scheduling API: parity + behavior suites.

* ``map_batch`` over a frontier must yield the *same* assignments,
  predictions and overhead accounting as N sequential ``map_task`` calls
  (tolerance 1e-9) — including when commits land on devices later tasks
  score (the optimistic-rescore path).
* ``CompiledHWGraph.apply_delta`` must match a full recompile under
  mark_dead / mark_alive / set_bandwidth churn, on both the edge testbed
  (tree routing) and the TPU fleet (host-ring transit routes), without
  ever triggering a full rebuild.
* ``SchedulerSession`` drives dependency-frontier waves with exact
  producer->consumer provenance, and its sequential mode reproduces the
  seed ``Runtime.run`` semantics.
"""
import itertools

import numpy as np
import pytest

from repro.core import (ActiveLedger, OrchestratorPolicy, Runtime,
                        SchedulerSession, build_orchestrators, build_testbed,
                        ground_truth_traverser, heye_traverser,
                        mining_workload, vr_workload)
from repro.core.compiled import CompiledHWGraph
from repro.core.topology import build_tpu_fleet, make_task
import repro.core.task as task_mod

TOL = 1e-9


def _testbed(mult=1):
    return build_testbed(
        edge_counts={"orin_agx": 2 * mult, "xavier_agx": mult,
                     "orin_nano": mult, "xavier_nx": mult},
        server_counts={"server1": 1, "server2": 1})


def _frontier(tb, n=36, seed_uid=50_000):
    """A mixed frontier: local-feasible ML tasks (several per device, so
    commits dirty later siblings) plus escalating renders."""
    task_mod._task_counter = itertools.count(seed_uid)
    tasks = []
    for i in range(n):
        e = tb.edges[i % len(tb.edges)]
        kind = ("svm", "knn", "mlp")[i % 3]
        tasks.append(make_task(kind, origin=e, deadline=0.1,
                               input_bytes=64e3, output_bytes=1e3))
    for i in range(5):
        e = tb.edges[i % len(tb.edges)]
        tasks.append(make_task("render", origin=e, deadline=0.03,
                               input_bytes=4e3))
    return tasks


# ---------------------------------------------------------------------------
# map_batch vs sequential one-element batches
# ---------------------------------------------------------------------------
def test_map_batch_matches_sequential_map_task():
    tb1, tb2 = _testbed(), _testbed()
    w1, w2 = _frontier(tb1), _frontier(tb2)
    root1 = build_orchestrators(tb1.graph, heye_traverser(tb1.graph))
    root2 = build_orchestrators(tb2.graph, heye_traverser(tb2.graph))
    seq = [root1._entry_orc(t).map_batch([t], 0.0)[0] for t in w1]
    bat = root2.map_batch(w2, 0.0, route=True)
    assert len(seq) == len(bat)
    for i, (a, b) in enumerate(zip(seq, bat)):
        assert (a is None) == (b is None), i
        if a is None:
            continue
        assert a.pu == b.pu, i
        assert a.prediction.total == pytest.approx(b.prediction.total,
                                                   abs=TOL, rel=TOL)
        assert a.prediction.factor == pytest.approx(b.prediction.factor,
                                                    abs=TOL, rel=TOL)
        assert a.overhead == pytest.approx(b.overhead, abs=TOL, rel=TOL)
        assert (a.queries, a.hops) == (b.queries, b.hops), i
    # the ledgers end in the same state
    assert {t.uid: t.assigned_pu for t in w1} == \
        {t.uid: t.assigned_pu for t in w2}


def test_map_batch_same_device_cascade_parity():
    """Many tasks of one origin device: every later task must see the
    earlier commits (the dirty-rescore path), exactly as sequential."""
    tb1, tb2 = _testbed(), _testbed()
    e1, e2 = tb1.edges[0], tb2.edges[0]
    task_mod._task_counter = itertools.count(60_000)
    w1 = [make_task(("dnn", "svm", "mlp", "knn")[i % 4], origin=e1,
                    deadline=0.2) for i in range(12)]
    task_mod._task_counter = itertools.count(60_000)
    w2 = [make_task(("dnn", "svm", "mlp", "knn")[i % 4], origin=e2,
                    deadline=0.2) for i in range(12)]
    root1 = build_orchestrators(tb1.graph, heye_traverser(tb1.graph))
    root2 = build_orchestrators(tb2.graph, heye_traverser(tb2.graph))
    seq = [root1.find_device_orc(e1).map_batch([t], 0.0)[0] for t in w1]
    bat = root2.find_device_orc(e2).map_batch(w2, 0.0)
    assert [r.pu.split(".")[-1] for r in seq] == \
        [r.pu.split(".")[-1] for r in bat]
    for a, b in zip(seq, bat):
        assert a.prediction.total == pytest.approx(b.prediction.total,
                                                   abs=TOL, rel=TOL)
    # the cascade actually spread load (not all on one PU)
    assert len({r.pu for r in bat}) > 1


def test_map_batch_commit_false_leaves_ledger_untouched():
    tb = _testbed()
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    w = _frontier(tb, n=9)
    res = root.map_batch(w, 0.0, commit=False, route=True)
    assert all(r is not None for r in res)
    assert len(root.ledger) == 0
    assert all(t.assigned_pu is None for t in w)


def test_map_task_removed():
    """The ``map_task`` shim (deprecated since PR 3) is gone: the public
    mapping surface is ``map_batch`` + ``SchedulerSession``."""
    tb = _testbed()
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    assert not hasattr(root, "map_task")
    # one-element batches cover the old single-task call shape
    t = make_task("dnn", origin=tb.edges[0], deadline=1.0)
    res = root.find_device_orc(tb.edges[0]).map_batch([t])[0]
    assert res is not None and t.assigned_pu == res.pu
    assert root.ledger.count(res.pu) == 1


# ---------------------------------------------------------------------------
# struct-of-arrays ActiveLedger
# ---------------------------------------------------------------------------
def test_soa_ledger_compat_views():
    tb = _testbed()
    g = tb.graph
    trav = heye_traverser(g)
    led = ActiveLedger()
    e = tb.edges[0]
    ts = [make_task("dnn", origin=e, deadline=0.5) for _ in range(4)]
    for i, t in enumerate(ts):
        pu = f"{e}.gpu" if i % 2 == 0 else f"{e}.dla"
        led.add(t, pu, trav.predict_task(t, pu, []), now=0.0)
    assert len(led) == 4
    assert led.count(f"{e}.gpu") == 2
    by_pu = led.by_pu
    assert sorted(by_pu) == sorted({f"{e}.gpu", f"{e}.dla"})
    on_dev = led.on_device(g, f"{e}.gpu")
    assert len(on_dev) == 4
    assert {x.task.uid for x in on_dev} == {t.uid for t in ts}
    view = led.device_view(g.compiled(), e)
    assert len(view) == 4
    np.testing.assert_array_equal(np.sort(view.uid),
                                  np.sort([t.uid for t in ts]))
    led.remove(ts[0])
    assert led.count(f"{e}.gpu") == 1
    led.prune(now=1e9)
    assert len(led) == 0 and led.count(f"{e}.dla") == 0


def test_soa_ledger_prune_keeps_future_entries():
    tb = _testbed()
    trav = heye_traverser(tb.graph)
    led = ActiveLedger()
    e = tb.edges[0]
    t = make_task("dnn", origin=e)
    entry = led.add(t, f"{e}.gpu", trav.predict_task(t, f"{e}.gpu", []), 0.0)
    led.prune(now=entry.est_finish * 0.5)
    assert led.count(f"{e}.gpu") == 1
    led.prune(now=entry.est_finish + 1.0)
    assert led.count(f"{e}.gpu") == 0


def test_factors_same_device_matches_scalar_reference():
    """Independent pin of the block-diagonal kernel against the scalar
    slowdown model (not via map_batch, which would be self-referential):
    candidates spread over several devices, actives on those devices and
    elsewhere."""
    from repro.core import DecoupledSlowdown, heye_params
    tb = _testbed()
    g = tb.graph
    comp = g.compiled()
    sd = DecoupledSlowdown(g, heye_params())
    task_mod._task_counter = itertools.count(80_000)
    # actives: several per device across three edges + a server
    active = []
    for e in tb.edges[:3]:
        for short in ("gpu", "dla", "cpu0"):
            active.append((make_task("dnn"), f"{e}.{short}"))
    active.append((make_task("knn"), f"{tb.servers[0]}.gpu"))
    newcomer = make_task("render", origin=tb.edges[0])
    cands = ([f"{tb.edges[0]}.{s}" for s in ("gpu", "vic", "cpu1")]
             + [f"{tb.edges[1]}.gpu", f"{tb.servers[0]}.gpu",
                f"{tb.servers[1]}.gpu"])
    # device-sorted active arrays, exactly as a ledger view would hand over
    Pa = np.array([comp.pu_index[p] for _, p in active])
    Da = comp.pu_dev_ord[Pa]
    order = np.argsort(Da, kind="stable")
    active = [active[i] for i in order]
    Pa, Da = Pa[order], Da[order]
    Ua = np.array([t.usage.get("pu", 1.0) for t, _ in active])
    Ma = np.minimum(np.array([t.usage.get("mem", 1.0) for t, _ in active]),
                    comp.mem_cap[Pa])
    uid_a = np.array([t.uid for t, _ in active])
    na = np.bincount(Da, minlength=len(comp.dev_ord_names))
    astart = np.cumsum(na) - na
    Pc = np.array([comp.pu_index[p] for p in cands])
    Dc = comp.pu_dev_ord[Pc]
    new_f, ci, ai, act_pf = sd.factors_same_device(
        comp, newcomer, Pc, Dc, Pa, Ua, Ma, uid_a, Da, astart, na)
    # scalar reference: newcomer amid the same-device actives only
    for c, pu in enumerate(cands):
        dev = comp.device_name(pu)
        local = [(t, p) for t, p in active if comp.device_name(p) == dev]
        assert new_f[c] == pytest.approx(sd.factor(newcomer, pu, local),
                                         abs=TOL, rel=TOL), pu
    # pair factors: each same-device active if the newcomer joins
    for k in range(len(ci)):
        c, a = int(ci[k]), int(ai[k])
        t, p = active[a]
        dev = comp.device_name(cands[c])
        local = [(t2, p2) for t2, p2 in active
                 if comp.device_name(p2) == dev]
        want = sd.factor(t, p, local + [(newcomer, cands[c])])
        assert act_pf[k] == pytest.approx(want, abs=TOL, rel=TOL), (c, a)
    # every same-device (candidate, active) pair is present exactly once
    expect_pairs = sum(int(na[d]) for d in Dc)
    assert len(ci) == expect_pairs


# ---------------------------------------------------------------------------
# apply_delta vs full recompile
# ---------------------------------------------------------------------------
def _assert_snapshot_parity(g, devs, label):
    comp = g.compiled()
    fresh = CompiledHWGraph(g)
    np.testing.assert_array_equal(comp.pu_alive, fresh.pu_alive,
                                  err_msg=label)
    for s in devs:
        for d in devs:
            for nb in (0.0, 5e6):
                try:
                    a = comp.transfer_time(s, d, nb)
                except KeyError:
                    a = None
                try:
                    b = fresh.transfer_time(s, d, nb)
                except KeyError:
                    b = None
                assert (a is None) == (b is None), (label, s, d)
                if a is not None:
                    assert a == pytest.approx(b, abs=TOL, rel=TOL), \
                        (label, s, d)
    alive = [n for i, n in enumerate(comp.pu_names) if comp.pu_alive[i]]
    for a in alive[:24]:
        for b in alive[:24]:
            assert comp.nearest_common_resource(a, b) == \
                fresh.nearest_common_resource(a, b), (label, a, b)


def test_apply_delta_parity_testbed_churn():
    tb = build_testbed(edge_counts={"orin_agx": 2, "orin_nano": 1},
                       server_counts={"server1": 1, "server2": 1})
    g = tb.graph
    devs = tb.edges + tb.servers
    g.compiled()
    rebuilds0 = g.recompile_count
    e = tb.edges[0]
    for step, mutate in (
            ("dead pu", lambda: g.mark_dead(f"{e}.gpu")),
            ("alive pu", lambda: g.mark_alive(f"{e}.gpu")),
            ("dead device", lambda: g.mark_dead(e)),
            ("bandwidth", lambda: g.set_bandwidth(f"link_{tb.edges[1]}", 1e6)),
            ("alive device", lambda: g.mark_alive(e)),
            ("bandwidth back", lambda: g.set_bandwidth(f"link_{tb.edges[1]}",
                                                       1e9))):
        mutate()
        _assert_snapshot_parity(g, devs, step)
    assert g.recompile_count == rebuilds0          # deltas only
    assert g.delta_count >= 6


def test_apply_delta_parity_tpu_ring_transit():
    """Host-ring routes transit other hosts: killing one re-routes pairs
    that never touch it as an endpoint."""
    fl = build_tpu_fleet(n_pods=2, hosts_per_pod=4, chips_per_host=2)
    g = fl.graph
    hosts = [n.name for n in g.nodes.values()
             if n.attrs.get("orc_level") == "device"]
    g.compiled()
    rebuilds0 = g.recompile_count
    g.mark_dead("pod0.host1")
    _assert_snapshot_parity(g, hosts, "dead host")
    g.mark_dead("pod0.host2")
    _assert_snapshot_parity(g, hosts, "dead host2")
    g.mark_alive("pod0.host1")
    _assert_snapshot_parity(g, hosts, "alive host (other still dead)")
    g.mark_alive("pod0.host2")
    _assert_snapshot_parity(g, hosts, "alive host2")
    assert g.recompile_count == rebuilds0


def test_apply_delta_slowdown_factors_match_fresh():
    tb = build_testbed(edge_counts={"orin_agx": 2},
                       server_counts={"server1": 1})
    g = tb.graph
    g.compiled()
    g.mark_dead(tb.edges[1])
    g.mark_alive(tb.edges[1])
    from repro.core import DecoupledSlowdown, heye_params
    sd = DecoupledSlowdown(g, heye_params())
    e = tb.edges[1]
    pool = [(make_task("dnn"), f"{e}.gpu"), (make_task("dnn"), f"{e}.dla"),
            (make_task("svm"), f"{e}.cpu0")]
    got = sd.factor_batch(pool)
    # fresh recompile reference
    g._compiled = None
    sd2 = DecoupledSlowdown(g, heye_params())
    np.testing.assert_allclose(got, sd2.factor_batch(pool),
                               atol=TOL, rtol=TOL)


def test_mutation_before_first_compile_still_works():
    tb = build_testbed(edge_counts={"orin_agx": 1},
                       server_counts={"server1": 1})
    g = tb.graph
    g.mark_dead(tb.edges[0])               # no snapshot yet: no delta
    comp = g.compiled()
    assert not comp.pu_alive[comp.pu_index[f"{tb.edges[0]}.gpu"]]
    assert g.delta_count == 0 and g.recompile_count == 1


# ---------------------------------------------------------------------------
# SchedulerSession
# ---------------------------------------------------------------------------
def test_session_sequential_mode_matches_runtime():
    tb1, tb2 = _testbed(), _testbed()
    task_mod._task_counter = itertools.count(70_000)
    cfg1 = mining_workload(tb1, n_sensors=8, n_readings=2)
    task_mod._task_counter = itertools.count(70_000)
    cfg2 = mining_workload(tb2, n_sensors=8, n_readings=2)
    st1 = Runtime(tb1.graph, seed=0).run(
        cfg1, OrchestratorPolicy(
            build_orchestrators(tb1.graph, heye_traverser(tb1.graph))))
    sess = SchedulerSession(
        tb2.graph,
        OrchestratorPolicy(
            build_orchestrators(tb2.graph, heye_traverser(tb2.graph))),
        truth=ground_truth_traverser(tb2.graph, seed=0), frontier=False)
    st2 = sess.run(cfg2)
    assert st1.mapping == st2.mapping
    assert st1.timeline.makespan == pytest.approx(st2.timeline.makespan,
                                                  abs=TOL, rel=TOL)
    assert st1.overhead == st2.overhead


def test_session_frontier_respects_dependencies():
    tb = _testbed()
    cfg = vr_workload(tb, n_frames=3)
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    sess = SchedulerSession(tb.graph, root,
                            truth=ground_truth_traverser(tb.graph, seed=0))
    stats = sess.run(cfg)
    assert not stats.unmapped
    # producers were always placed before consumers: every non-root task
    # carries exact src_devices provenance
    for t in cfg:
        if cfg.preds(t):
            assert t.attrs.get("src_devices"), t
    for t in cfg:
        for p in cfg.preds(t):
            assert stats.timeline.start[t.uid] >= \
                stats.timeline.finish[p.uid] - TOL


def test_session_streaming_submit_and_churn():
    """Streaming batches across topology churn: mapping continues on
    delta-patched snapshots, never a full recompile."""
    tb = _testbed()
    g = tb.graph
    root = build_orchestrators(g, heye_traverser(g))
    sess = SchedulerSession(g, root,
                            truth=ground_truth_traverser(g, seed=0))
    sess.submit([make_task("svm", origin=e, deadline=0.2)
                 for e in tb.edges])
    sess.map_pending()
    rebuilds = g.recompile_count
    g.mark_dead(tb.edges[0])
    late = [make_task("knn", origin=tb.edges[1], deadline=0.2,
                      release_time=0.5) for _ in range(4)]
    sess.submit(late)
    sess.map_pending()
    g.mark_alive(tb.edges[0])
    assert g.recompile_count == rebuilds
    # nothing was placed on the dead edge
    for t in late:
        assert not sess.mapping[t.uid].startswith(tb.edges[0] + ".")
    stats = sess.execute()
    assert stats.timeline.makespan > 0


def test_session_frontier_waves_group_by_release():
    tb = _testbed()
    cfg = mining_workload(tb, n_sensors=6, n_readings=3)
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    sess = SchedulerSession(tb.graph, root)
    sess.submit(cfg)
    waves = list(sess._waves())
    # 3 readings -> 3 waves, each holding every sensor's 3 ML tasks
    assert len(waves) == 3
    assert all(len(w) == 18 for _, w in waves)
    nows = [now for now, _ in waves]
    assert nows == sorted(nows)


def test_runtime_frontier_flag_matches_policy_batching():
    """Runtime(frontier=True) drives map_batch waves; outcomes stay within
    QoS on a light workload."""
    tb = _testbed()
    cfg = mining_workload(tb, n_sensors=6, n_readings=2)
    pol = OrchestratorPolicy(
        build_orchestrators(tb.graph, heye_traverser(tb.graph)))
    stats = Runtime(tb.graph, seed=0).run(cfg, pol, frontier=True)
    assert stats.qos_failure_rate(cfg) < 0.05


# ---------------------------------------------------------------------------
# consolidated Churn delta-batch API
# ---------------------------------------------------------------------------
def test_churn_graph_direct_matches_old_entrypoints():
    """With no resident engine, ``session.churn`` mutates the graph
    exactly like the three legacy calls did — same eligibility masks,
    same delta-patched snapshot (never a rebuild)."""
    from repro.core import Churn
    tb1, tb2 = _testbed(), _testbed()
    e, lk = tb1.edges[1], f"link_{tb1.edges[0]}"
    s1 = SchedulerSession(tb1.graph, build_orchestrators(
        tb1.graph, heye_traverser(tb1.graph)))
    tb2.graph.compiled()                       # both snapshots built once
    n1, n2 = tb1.graph.recompile_count, tb2.graph.recompile_count
    with pytest.warns(DeprecationWarning):
        tb2.graph.mark_dead(e)
    with pytest.warns(DeprecationWarning):
        tb2.graph.set_bandwidth(lk, 1e6)
    s1.churn(Churn(dead=[e], bandwidth=[(lk, 1e6)]))
    assert not tb1.graph.nodes[e].alive
    # both paths absorbed the churn as deltas — no extra rebuilds
    assert tb1.graph.recompile_count == n1
    assert tb2.graph.recompile_count == n2
    c1, c2 = tb1.graph.compiled(), tb2.graph.compiled()
    assert np.array_equal(c1.pu_alive, c2.pu_alive)
    bws = [sorted((e.name, e.bandwidth) for adj in tb.graph._adj.values()
                  for _, e in adj) for tb in (tb1, tb2)]
    assert bws[0] == bws[1]
    # revival goes back through the same single entrypoint
    s1.churn(Churn(alive=[e]))
    assert tb1.graph.nodes[e].alive


def test_churn_scheduled_matches_callable_interventions():
    """A ``Churn`` scheduled at t on the resident timeline reprices at
    the same instant as the legacy ``interventions=[(t, fn)]`` plumbing:
    identical finish times, event-for-event."""
    from repro.core import Churn

    def drive(use_churn):
        task_mod._task_counter = itertools.count(70_000)
        tb = _testbed()
        s = SchedulerSession(tb.graph, build_orchestrators(
            tb.graph, heye_traverser(tb.graph)))
        s.submit(mining_workload(tb, n_sensors=12, n_readings=2))
        s.map_pending()
        e = tb.edges[1]
        if use_churn:
            s.open_timeline()
            s.churn(Churn(dead=[e]), at=0.03)
            s.churn(Churn(alive=[e]), at=0.12)
        else:
            s.open_timeline(interventions=[
                (0.03, lambda: tb.graph._mark_dead(e)),
                (0.12, lambda: tb.graph._mark_alive(e))])
        return s.finalize_online(drain=True)

    st_new, st_old = drive(True), drive(False)
    assert set(st_new.timeline.finish) == set(st_old.timeline.finish)
    for k, v in st_old.timeline.finish.items():
        assert st_new.timeline.finish[k] == pytest.approx(v, abs=TOL), k
    assert st_new.timeline.n_intervals == st_old.timeline.n_intervals


def test_churn_engine_resident_one_flush():
    """With an open engine and no ``at``, the delta lands at the current
    clock through ``TimelineEngine.apply_churn`` — one flush, visible to
    everything injected afterwards."""
    from repro.core import Churn
    task_mod._task_counter = itertools.count(72_000)
    tb = _testbed()
    s = SchedulerSession(tb.graph, build_orchestrators(
        tb.graph, heye_traverser(tb.graph)))
    s.open_timeline()
    e = tb.edges[0]
    s.churn(Churn(dead=[e]))
    assert not tb.graph.nodes[e].alive
    # a task from the dead edge must escalate off it
    t = make_task("render", origin=tb.edges[1], deadline=0.5)
    s.submit([t]); s.map_pending(); s.inject([t])
    st = s.finalize_online(drain=True)
    assert t.uid in st.timeline.finish
    assert not s.mapping[t.uid].startswith(e)


def test_churn_at_requires_engine():
    from repro.core import Churn
    tb = _testbed()
    s = SchedulerSession(tb.graph, build_orchestrators(
        tb.graph, heye_traverser(tb.graph)))
    with pytest.raises(RuntimeError, match="open_timeline"):
        s.churn(Churn(dead=[tb.edges[0]]), at=0.1)


def test_churn_dataclass_surface():
    """Churn normalizes to tuples, is truthy only when non-empty, and
    sizes as the number of individual mutations."""
    from repro.core import Churn
    c = Churn(dead=["a"], alive=["b"], bandwidth=[("l", 1e6)])
    assert c.dead == ("a",) and c.bandwidth == (("l", 1e6),)
    assert bool(c) and len(c) == 3
    assert not Churn() and len(Churn()) == 0


def test_legacy_churn_shims_warn():
    """mark_dead / mark_alive / set_bandwidth survive as deprecation
    shims that still mutate (one release of grace)."""
    tb = _testbed()
    e, lk = tb.edges[0], f"link_{tb.edges[0]}"
    with pytest.warns(DeprecationWarning, match="Churn"):
        tb.graph.mark_dead(e)
    assert not tb.graph.nodes[e].alive
    with pytest.warns(DeprecationWarning, match="Churn"):
        tb.graph.mark_alive(e)
    assert tb.graph.nodes[e].alive
    with pytest.warns(DeprecationWarning, match="Churn"):
        tb.graph.set_bandwidth(lk, 1e5)
    assert any(e.bandwidth == 1e5 for adj in tb.graph._adj.values()
               for _, e in adj if e.name == lk)
