"""Walk-kernel reduce parity (kernels/walk_kernel.py): the scalar
small-scan path, the vectorized numpy path, and the jitted jax path must
agree on winner/queries/hops exactly and on overhead to float tolerance,
across feasibility patterns including all-infeasible roots, key ties and
inf keys (unroutable comm)."""
import numpy as np
import pytest

from repro.kernels.walk_kernel import scan_reduce, scan_reduce_ref

LQC = 5e-6


def _spec_oracle(ok, key, pu_lo, pu_hi, leafcnt, nchild, hopsum, depth, lqc):
    """The documented closed forms, computed the obvious way."""
    cs = np.concatenate(([0], np.cumsum(ok.astype(np.int64))))
    feas = cs[pu_hi] > cs[pu_lo]
    if not feas[0]:
        return -1, 0, 0, 0.0
    ok_idx = np.flatnonzero(ok)
    w = int(ok_idx[np.argmin(key[ok_idx])])
    return (w, int(leafcnt[feas].sum()), int(nchild[feas].sum()),
            float((hopsum[feas] + lqc * leafcnt[feas] * (depth[feas] + 1.0))
                  .sum()))


def _random_plan(rng, n_pus, n_nodes, p_ok):
    ok = rng.random(n_pus) < p_ok
    key = rng.random(n_pus) * 1e-2
    key[rng.random(n_pus) < 0.1] = np.inf          # unroutable comm
    key[rng.random(n_pus) < 0.2] = 1e-3            # force exact ties
    lo = rng.integers(0, n_pus, n_nodes)
    hi = lo + rng.integers(0, n_pus // 2 + 1, n_nodes)
    np.clip(hi, None, n_pus, out=hi)
    lo[0], hi[0] = 0, n_pus                        # node 0 is the scan root
    return (ok, key, lo.astype(np.int64), hi.astype(np.int64),
            rng.integers(0, 5, n_nodes), rng.integers(0, 4, n_nodes),
            rng.random(n_nodes) * 1e-4, rng.integers(0, 4, n_nodes)
            .astype(np.float64))


@pytest.mark.parametrize("n_pus,n_nodes", [
    (3, 2),        # device scan: scalar path
    (40, 11),      # cluster scan: scalar path
    (200, 31),     # fleet scan: vectorized path
])
@pytest.mark.parametrize("p_ok", [0.0, 0.05, 0.5, 1.0])
def test_scalar_and_array_paths_match_spec(n_pus, n_nodes, p_ok):
    rng = np.random.default_rng(n_pus * 7 + int(p_ok * 10))
    for _ in range(20):
        plan = _random_plan(rng, n_pus, n_nodes, p_ok)
        got = scan_reduce_ref(*plan, LQC)
        want = _spec_oracle(*plan, LQC)
        assert got[:3] == want[:3]
        assert got[3] == pytest.approx(want[3], rel=1e-9, abs=1e-15)


def test_jax_path_matches_ref(monkeypatch):
    jax = pytest.importorskip("jax")
    del jax
    monkeypatch.setenv("REPRO_WALK_KERNEL", "jax")
    rng = np.random.default_rng(0)
    for n_pus, n_nodes in [(6, 3), (200, 31)]:
        for p_ok in (0.0, 0.4, 1.0):
            plan = _random_plan(rng, n_pus, n_nodes, p_ok)
            got = scan_reduce(*plan, LQC)
            monkeypatch.setenv("REPRO_WALK_KERNEL", "ref")
            want = scan_reduce(*plan, LQC)
            monkeypatch.setenv("REPRO_WALK_KERNEL", "jax")
            assert got[:3] == want[:3]
            # jitted reduce may run f32 without jax_enable_x64
            assert got[3] == pytest.approx(want[3], rel=1e-5, abs=1e-9)
