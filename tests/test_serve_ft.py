"""Serving engine (continuous batching) + fault-tolerance manager tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import ParallelCtx, build_model
from repro.serve.engine import Request, ServeEngine

CTX = ParallelCtx(compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def served():
    cfg = all_configs()["gemma3-1b"].smoke()
    model = build_model(cfg, CTX)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new, max_len=64):
    """Sequential greedy decode via repeated full forward (oracle)."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = model.forward(params, {
            "tokens": jnp.asarray([toks], jnp.int32)})
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_sequential_decode(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, max_slots=2, max_len=64)
    prompts = [np.array([5, 9, 2], np.int32), np.array([7, 1], np.int32),
               np.array([3, 3, 3, 3], np.int32)]
    reqs = [Request(rid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    done = eng.run(reqs)
    assert len(done) == 3 and all(r.done for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        want = _greedy_reference(model, params, list(prompts[r.rid]), 5)
        assert r.out[:5] == want[:5], (r.rid, r.out, want)


def test_engine_slot_recycling(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, max_slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=np.array([i + 1], np.int32), max_new=3)
            for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert len(eng.free) == 2 and not eng.active


def test_admit_matches_admit_many_telemetry(served):
    """The one-request ``admit`` shim reports slot exhaustion through the
    identical claim/telemetry path as ``admit_many``."""
    cfg, model, params = served
    eng = ServeEngine(model, params, max_slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=np.array([i + 1], np.int32), max_new=2)
            for i in range(4)]
    assert eng.admit(reqs[0]) is True
    assert eng.admitted_total == 1 and eng.slot_rejections == 0
    assert [r.rid for r in eng.last_admission.admitted] == [0]
    # batch path: one slot left, two requests -> one in, one reported out
    leftover = eng.admit_many(reqs[1:3])
    assert [r.rid for r in leftover] == [1]
    assert eng.admitted_total == 2 and eng.slot_rejections == 1
    assert [r.rid for r in eng.last_admission.rejected] == [2]
    # shim on a full pool: same counters + last_admission shape as the
    # batch path's leftover set
    assert eng.admit(reqs[3]) is False
    assert eng.slot_rejections == 2
    assert eng.last_admission.admitted == []
    assert [r.rid for r in eng.last_admission.rejected] == [3]
    assert reqs[3].slot == -1
    # drain; recycled slots admit again through the same path
    while eng.active:
        eng.step()
    assert eng.admit(reqs[3]) is True
    assert eng.admitted_total == 3


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_straggler_detection_patience():
    from repro.core.topology import build_tpu_fleet
    from repro.ft.manager import FTConfig, FTManager
    tb = build_tpu_fleet(n_pods=1, hosts_per_pod=4, chips_per_host=2)
    ft = FTManager(tb.graph, FTConfig(straggler_patience=2))
    hosts = ft.alive_hosts()
    times = {h: 1.0 for h in hosts}
    times[hosts[0]] = 3.0
    assert ft.report_step_times(times) == []           # strike 1
    assert ft.report_step_times(times) == [hosts[0]]   # strike 2 -> confirmed
    # recovery resets strikes
    ok = {h: 1.0 for h in hosts}
    ft.report_step_times(ok)
    assert ft.report_step_times(times) == []


def test_failure_and_elastic_rescale():
    from repro.core.topology import build_tpu_fleet
    from repro.ft.manager import FTManager
    tb = build_tpu_fleet(n_pods=1, hosts_per_pod=4, chips_per_host=8)
    ft = FTManager(tb.graph)
    assert ft.alive_chips() == 32
    plan = ft.on_failure([ft.alive_hosts()[0]])
    assert ft.alive_chips() == 24
    dp, tp = plan.mesh_shape
    assert dp * tp <= 24
    assert 24 % tp == 0
    assert plan.lost_hosts and plan.restore_step == 0
    # node joins back (paper §5.4.2)
    plan2 = ft.on_join(plan.lost_hosts[0])
    assert ft.alive_chips() == 32
    assert np.prod(plan2.mesh_shape) >= np.prod(plan.mesh_shape)


def test_checkpoint_cadence(tmp_path):
    from repro.core.topology import build_tpu_fleet
    from repro.ft.manager import FTConfig, FTManager
    tb = build_tpu_fleet(n_pods=1, hosts_per_pod=2, chips_per_host=2)
    ft = FTManager(tb.graph, FTConfig(checkpoint_every=10),
                   ckpt_dir=str(tmp_path))
    state = {"w": jnp.ones((4,))}
    assert not ft.maybe_checkpoint(state, step=5)
    assert ft.maybe_checkpoint(state, step=10)
    ft.saver.wait()
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 10
    assert ft.last_committed == 10


def test_recovery_plan_no_chips_raises():
    from repro.core.topology import build_tpu_fleet
    from repro.ft.manager import FTManager
    tb = build_tpu_fleet(n_pods=1, hosts_per_pod=1, chips_per_host=2)
    ft = FTManager(tb.graph)
    with pytest.raises(RuntimeError):
        ft.on_failure(ft.alive_hosts())
