"""Checkpoint store: roundtrip, commit markers, async save, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncSaver, latest_step, restore, save


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"params": {"w": jax.random.normal(k1, (8, 4)),
                       "layers": ({"a": jnp.ones((3,))},
                                  {"a": jnp.zeros((3,))})},
            "opt": {"step": jnp.array(7, jnp.int32),
                    "m": jax.random.normal(k2, (8, 4)).astype(jnp.bfloat16)}}


def test_roundtrip(tmp_path, key):
    tree = _tree(key)
    path = save(tree, str(tmp_path), step=3)
    assert os.path.exists(os.path.join(path, "DONE"))
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_uncommitted_invisible(tmp_path, key):
    tree = _tree(key)
    save(tree, str(tmp_path), step=1)
    save(tree, str(tmp_path), step=5)
    assert latest_step(str(tmp_path)) == 5
    # fake an interrupted save: directory without DONE
    os.makedirs(os.path.join(str(tmp_path), "step_00000009"))
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), tree)          # restores 5, not 9
    assert out is not None


def test_restore_missing_raises(tmp_path, key):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), _tree(key))


def test_restore_missing_leaf_raises(tmp_path, key):
    tree = _tree(key)
    save(tree, str(tmp_path), step=0)
    bigger = dict(tree)
    bigger["extra"] = jnp.zeros(())
    with pytest.raises(KeyError):
        restore(str(tmp_path), bigger)


def test_async_save(tmp_path, key):
    tree = _tree(key)
    saver = AsyncSaver()
    saver.save(tree, str(tmp_path), step=2)
    saver.wait()
    assert latest_step(str(tmp_path)) == 2
    # second save overlaps with the first's join
    saver.save(tree, str(tmp_path), step=4)
    saver.wait()
    assert latest_step(str(tmp_path)) == 4


def test_elastic_restore_resharding(tmp_path, key):
    """sharding_fn re-places leaves on restore (elastic restart onto a
    different mesh); on one device this exercises the API path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    tree = _tree(key)
    save(tree, str(tmp_path), step=0)
    mesh = make_host_mesh()
    calls = []

    def sharding_fn(path, leaf):
        calls.append(path)
        return NamedSharding(mesh, P())

    out = restore(str(tmp_path), tree, sharding_fn=sharding_fn)
    assert len(calls) == len(jax.tree.leaves(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_overwrite_same_step(tmp_path, key):
    tree = _tree(key)
    save(tree, str(tmp_path), step=1)
    tree2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, tree)
    save(tree2, str(tmp_path), step=1)
    out = restore(str(tmp_path), tree, step=1)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree2["params"]["w"]))
