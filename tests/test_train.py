"""Training-step tests: gradient accumulation exactness, AdamW reference,
clipping, schedule, and loss-goes-down integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import ParallelCtx, build_model
from repro.optim import (OptConfig, adamw_update, clip_by_global_norm,
                         global_norm, init_opt_state, schedule)
from repro.train.step import (cross_entropy, init_train_state, make_loss_fn,
                              make_train_step)

CTX = ParallelCtx(compute_dtype=jnp.float32)


def _setup(key, arch="gemma3-1b"):
    cfg = all_configs()[arch].smoke()
    model = build_model(cfg, CTX)
    state = init_train_state(model, key, OptConfig())
    toks = jax.random.randint(jax.random.key(9), (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    return model, state, batch


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 10))
    labels = jnp.array([[1, 2, -1, -1]])
    # uniform logits -> nll = log(10) on the 2 unmasked positions
    assert float(cross_entropy(logits, labels)) == pytest.approx(
        np.log(10.0), rel=1e-6)
    all_masked = jnp.full((1, 4), -1)
    assert float(cross_entropy(logits, all_masked)) == 0.0


def test_microbatch_accumulation_matches_full(key):
    """mb=1 and mb=4 must produce the same parameter update (fp32 exact up
    to reduction-order noise)."""
    model, state, batch = _setup(key)
    s1, m1 = make_train_step(model, OptConfig())(state, batch)
    state2 = init_train_state(model, key, OptConfig())
    s4, m4 = make_train_step(model, OptConfig(), microbatches=4)(state2, batch)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)


def test_adamw_matches_reference(key):
    """One AdamW step against a hand-rolled numpy reference."""
    cfg = OptConfig(lr=1e-2, warmup_steps=1, weight_decay=0.1, grad_clip=1e9)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3])}
    state = init_opt_state(params, cfg)
    new_p, new_s, metrics = adamw_update(params, grads, state, cfg)
    lr = float(schedule(jnp.array(1), cfg))
    g = np.array([0.1, 0.2, -0.3])
    m = 0.1 * g
    v = 0.05 * g ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    w = np.array([1.0, -2.0, 3.0])
    want = w - lr * (mh / (np.sqrt(vh) + cfg.eps) + 0.1 * w)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(new_s["step"]) == 1


def test_grad_clipping():
    grads = {"a": jnp.full((10,), 3.0)}
    clipped, gnorm = clip_by_global_norm(grads, 1.0)
    assert float(gnorm) == pytest.approx(np.sqrt(90.0), rel=1e-6)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01)}
    kept, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(kept["a"]), 0.01, rtol=1e-6)


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    s = lambda t: float(schedule(jnp.array(t), cfg))
    assert s(5) == pytest.approx(0.5, rel=1e-6)          # mid-warmup
    assert s(10) == pytest.approx(1.0, rel=1e-6)         # peak
    assert s(100) == pytest.approx(0.1, rel=1e-4)        # floor
    assert s(55) > s(90) > s(100) - 1e-9                 # monotone decay


def test_loss_decreases_over_steps(key):
    """30 steps on structured synthetic data must reduce loss markedly."""
    from repro.data.pipeline import DataConfig, synthetic_batches
    cfg = all_configs()["gemma3-1b"].smoke()
    model = build_model(cfg, CTX)
    opt = OptConfig(lr=3e-3, warmup_steps=5, decay_steps=50)
    state = init_train_state(model, key, opt)
    step = jax.jit(make_train_step(model, opt))
    it = synthetic_batches(DataConfig(batch=8, seq=32, vocab=cfg.vocab))
    losses = []
    for i, batch in zip(range(40), it):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in batch.items()})
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25, losses[::8]
    assert all(np.isfinite(l) for l in losses)


def test_bf16_accum_dtype_close_to_f32(key):
    model, state, batch = _setup(key)
    s32, _ = make_train_step(model, OptConfig(), microbatches=2)(state, batch)
    state2 = init_train_state(model, key, OptConfig())
    s16, _ = make_train_step(model, OptConfig(), microbatches=2,
                             accum_dtype=jnp.bfloat16)(state2, batch)
    # updates agree loosely (bf16 has ~3 decimal digits)
    for a, b in zip(jax.tree.leaves(s32["params"]), jax.tree.leaves(s16["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-2, rtol=5e-2)


def test_grad_compression_error_feedback():
    """int8 compression with error feedback: per-round error is bounded and
    feedback carries the residual (second round compensates the first)."""
    from repro.optim import compress_grads, compressed_bytes, init_error
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 0.1,
                          jnp.float32)}
    err = init_error(g)
    total_in, total_out = np.zeros(1000), np.zeros(1000)
    for _ in range(4):
        deq, err = compress_grads(g, err)
        total_in += np.asarray(g["w"])
        total_out += np.asarray(deq["w"])
    # cumulative transmitted mass tracks cumulative true mass within residual
    resid = np.abs(total_in - (total_out + np.asarray(err["w"])))
    assert resid.max() < 1e-5
    assert compressed_bytes(g) < 4 * 1000    # ~4x smaller than fp32
