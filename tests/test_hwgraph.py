"""HW-GRAPH unit tests (paper §3.3): topology queries, compute paths,
shared-resource discovery, dynamic adaptability."""
import pytest

from repro.core import (HWGraph, Node, NodeKind, ProcessingUnit, Unit,
                        build_edge_device, build_server, build_testbed)
from repro.core.topology import build_tpu_fleet, make_task, vr_mining_profile


def test_add_and_query_nodes():
    g = HWGraph()
    g.add_node(Node("root", NodeKind.GROUP, attrs={"orc_level": "root"}))
    g.add_node(Node("dev", NodeKind.GROUP, parent="root",
                    attrs={"orc_level": "device"}))
    pu = g.add_node(ProcessingUnit("dev.cpu", parent="dev"))
    assert "dev.cpu" in g
    assert g.parent_of("dev.cpu").name == "dev"
    assert g.children_of("root")[0].name == "dev"
    assert g.pus() == [pu]
    assert g.pus(under="dev") == [pu]


def test_duplicate_node_rejected():
    g = HWGraph()
    g.add_node(Node("a", NodeKind.STORAGE))
    with pytest.raises(ValueError):
        g.add_node(Node("a", NodeKind.STORAGE))


def test_edge_requires_known_nodes():
    g = HWGraph()
    g.add_node(Node("a", NodeKind.STORAGE))
    with pytest.raises(KeyError):
        g.add_edge("a", "missing")


def test_compute_path_reaches_dram():
    g = HWGraph()
    g.add_node(Node("soc", NodeKind.GROUP, attrs={"orc_level": "device"}))
    build = lambda n, k, rc=None: g.add_node(
        Node(n, k, parent="soc", attrs={"rclass": rc} if rc else {}))
    build("dram", NodeKind.STORAGE, "dram")
    build("l2", NodeKind.STORAGE, "l2")
    pu = g.add_node(ProcessingUnit("cpu", parent="soc"))
    g.add_edge("cpu", "l2", latency=1e-9)
    g.add_edge("l2", "dram", latency=1e-8)
    assert pu.get_compute_path() == ["l2", "dram"]


def test_shared_resources_dla_pva_meet_at_sram(testbed):
    """The paper's Fig. 4 example: DLA and PVA share SRAM (+DRAM behind it)."""
    g = testbed.graph
    e = testbed.edges[0]
    shared = g.shared_resources(f"{e}.dla", f"{e}.pva")
    assert f"{e}.sram" in shared
    # cross-cluster CPUs meet at L3, not at either L2
    shared_cpu = g.shared_resources(f"{e}.cpu0", f"{e}.cpu1")
    assert f"{e}.l3" in shared_cpu
    assert f"{e}.l2_0" not in shared_cpu and f"{e}.l2_1" not in shared_cpu


def test_nearest_shared_orders_cache_levels(testbed):
    from repro.core import DecoupledSlowdown
    g = testbed.graph
    e = testbed.edges[0]
    sd = DecoupledSlowdown(g)
    # same-device CPU+GPU meet at the LLC before DRAM
    hit = sd.nearest_shared(f"{e}.cpu0", f"{e}.gpu")
    assert g.nodes[hit].attrs["rclass"] == "llc"
    # different devices share nothing
    e2 = testbed.edges[1]
    assert sd.nearest_shared(f"{e}.cpu0", f"{e2}.cpu0") is None


def test_transfer_time_bottleneck_and_latency(testbed):
    g = testbed.graph
    e, s = testbed.edges[0], testbed.servers[0]
    t0 = g.transfer_time(e, s, 0.0)
    t1 = g.transfer_time(e, s, 1e6)
    assert t1 > t0 > 0.0
    assert g.transfer_time(e, e, 1e9) == 0.0


def test_mark_dead_excludes_subtree(testbed):
    from repro.core import build_testbed
    tb = build_testbed()
    g = tb.graph
    e = tb.edges[0]
    n_before = len(g.pus())
    g.mark_dead(e)
    assert all(not p.name.startswith(e + ".") for p in g.pus())
    g.mark_alive(e)
    assert len(g.pus()) == n_before


def test_set_bandwidth_dynamic(testbed):
    from repro.core import build_testbed
    tb = build_testbed()
    g = tb.graph
    e = tb.edges[0]
    before = g.transfer_time(e, tb.servers[0], 10e6)
    g.set_bandwidth(f"link_{e}", 1e6)   # throttle the edge's uplink
    after = g.transfer_time(e, tb.servers[0], 10e6)
    assert after > before
    with pytest.raises(KeyError):
        g.set_bandwidth("no_such_link", 1.0)


def test_predict_requires_model():
    g = HWGraph()
    g.add_node(Node("d", NodeKind.GROUP, attrs={"orc_level": "device"}))
    pu = g.add_node(ProcessingUnit("d.x", parent="d"))
    with pytest.raises(ValueError):
        pu.predict(make_task("mm"))


def test_profiled_model_predicts_seconds(testbed):
    g = testbed.graph
    e = testbed.edges[0]
    pu = g.nodes[f"{e}.gpu"]
    t = pu.predict(make_task("render"))
    assert 0.001 < t < 1.0
    with pytest.raises(ValueError):
        pu.predict(make_task("render"), Unit.JOULES)


def test_tpu_fleet_topology():
    tb = build_tpu_fleet(n_pods=2, hosts_per_pod=2, chips_per_host=4)
    g = tb.graph
    assert len(g.pus()) == 2 * 2 * 4
    chip = g.pus()[0]
    assert chip.attrs["peak_flops"] == 197e12
    # chips on different hosts of one pod are connected (host ring)
    p = g.path("pod0.host0", "pod0.host1")
    assert len(p) >= 2
    # cross-pod goes through the abstract DCN node
    hops = [n for n, _ in g.path("pod0.host0", "pod1.host0")]
    assert "dcn" in hops


# ---------------------------------------------------------------------------
# batched churn: bandwidth coalescing (last-writer-wins, one delta)
# ---------------------------------------------------------------------------
def test_apply_churn_coalesces_duplicate_bandwidth_entries():
    from repro.core import Churn, build_testbed
    tb = build_testbed()
    g = tb.graph
    e, s = tb.edges[0], tb.servers[0]
    link = f"link_{e}"
    comp = g.compiled()
    comp.transfer_time(e, s, 1e6)        # build a row crossing the link
    d0, o0 = g.delta_count, g.route_overlay_copies
    # three writes to the same link in one batch: only the last survives,
    # and the whole batch pays exactly one delta / one overlay copy
    g.apply_churn(Churn(bandwidth=((link, 1e6), (link, 9e9), (link, 2e6))))
    edge = next(a for adj in g._adj.values() for _, a in adj
                if a.name == link)
    assert edge.bandwidth == 2e6
    assert g.delta_count == d0 + 1
    assert g.route_overlay_copies == o0 + 1
    assert g.route_holder_copies == 0    # bandwidth never copies topology
    # the patched snapshot prices the final value, not an intermediate
    after = g.compiled().transfer_time(e, s, 10e6)
    assert after == pytest.approx(g.transfer_time(e, s, 10e6),
                                  abs=1e-9, rel=1e-9)


def test_apply_churn_bandwidth_batch_validates_all_names():
    from repro.core import Churn, build_testbed
    tb = build_testbed()
    g = tb.graph
    e = tb.edges[0]
    link = f"link_{e}"
    nominal = next(a.bandwidth for adj in g._adj.values() for _, a in adj
                   if a.name == link)
    with pytest.raises(KeyError):
        g.apply_churn(Churn(bandwidth=((link, 1e6),
                                       ("no_such_link", 1.0))))
    # a bad batch must leave the authoring layer untouched
    assert next(a.bandwidth for adj in g._adj.values() for _, a in adj
                if a.name == link) == nominal


def test_route_copy_counters_split_by_delta_kind():
    from repro.core import Churn, build_testbed
    tb = build_testbed()
    g = tb.graph
    e, s = tb.edges[0], tb.servers[0]
    comp = g.compiled()
    comp.transfer_time(e, s, 1e6)
    assert g.route_holder_copies == 0 and g.route_overlay_copies == 0
    g.apply_churn(Churn(bandwidth=((f"link_{e}", 5e6),)))
    assert (g.route_holder_copies, g.route_overlay_copies) == (0, 1)
    g.apply_churn(Churn(dead=(e,)))      # topology delta: holder copy
    assert g.route_holder_copies >= 1
