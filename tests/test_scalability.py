"""§3.5 scalability: virtual-ORC insertion keeps fanout bounded so a
MapTask escalation touches O(log n) ORCs, and the search still finds
feasible placements at fleet scale."""
import math

import pytest

from repro.core import (OrcConfig, Runtime, build_orchestrators,
                        build_testbed, heye_traverser, mining_workload,
                        OrchestratorPolicy)
from repro.core.topology import make_task


def _flat_fleet(n_edges: int):
    return build_testbed(edge_counts={"orin_agx": n_edges},
                         server_counts={"server1": 2})


def test_virtual_orcs_bound_fanout():
    tb = _flat_fleet(40)
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph),
                               max_fanout=4)
    for orc in root.iter_tree():
        assert len(orc.children) <= 4, orc.group
    # every device is still reachable exactly once
    devices = [o.group for o in root.iter_tree() if o.is_device_orc()]
    assert sorted(devices) == sorted(tb.edges + tb.servers)


def test_virtual_orcs_preserve_mapping():
    tb = _flat_fleet(12)
    flat_root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    deep_root = build_orchestrators(tb.graph, heye_traverser(tb.graph),
                                    max_fanout=3)
    t1 = make_task("render", origin=tb.edges[0], deadline=0.030,
                   input_bytes=4e3)
    t2 = make_task("render", origin=tb.edges[0], deadline=0.030,
                   input_bytes=4e3)
    r_flat = flat_root.find_device_orc(tb.edges[0]).map_batch([t1])[0]
    r_deep = deep_root.find_device_orc(tb.edges[0]).map_batch([t2])[0]
    assert r_flat is not None and r_deep is not None
    # both find a server-grade PU meeting the deadline
    assert tb.graph.device_of(r_flat.pu).name in tb.servers
    assert tb.graph.device_of(r_deep.pu).name in tb.servers


def test_escalation_depth_logarithmic():
    """The ORC-tree depth (escalation path length) grows like log(n)."""
    depths = {}
    for n in (8, 64):
        tb = _flat_fleet(n)
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph),
                                   max_fanout=4)

        def depth(orc):
            if not orc.children:
                return 1
            return 1 + max(depth(c) for c in orc.children)

        depths[n] = depth(root)
    # 8x more devices must cost at most +2 levels at fanout 4
    assert depths[64] <= depths[8] + 2
    assert depths[64] >= depths[8]


def test_fleet_scale_end_to_end():
    """64 edges + 8 servers, 200+ tasks: mapping succeeds, QoS holds."""
    tb = build_testbed(edge_counts={"orin_agx": 32, "orin_nano": 32},
                       server_counts={"server1": 4, "server2": 4})
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph),
                               max_fanout=8)
    cfg = mining_workload(tb, n_sensors=80, n_readings=1)
    stats = Runtime(tb.graph, seed=0).run(cfg, OrchestratorPolicy(root))
    assert stats.qos_failure_rate(cfg) < 0.05
    assert stats.mean_overhead_ratio(cfg) < 0.05
