"""MoE routing invariants (hypothesis property tests) + implementation
equivalence (einsum GShard vs scatter/gather) + dense-reference agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import all_configs
from repro.models import ParallelCtx
from repro.models.moe import (_route, init_moe, moe_layer_einsum,
                              moe_layer_scatter)

CTX = ParallelCtx(compute_dtype=jnp.float32)


def _cfg(E=4, k=2, cf=1.25, g=16, act="silu"):
    return all_configs()["granite-moe-1b-a400m"].smoke().scaled(
        n_experts=E, top_k=k, capacity_factor=cf, moe_group=g, act=act)


def test_route_normalized(key):
    logits = jax.random.normal(key, (3, 8, 6))
    vals, idx = _route(logits, 3)
    np.testing.assert_allclose(np.asarray(vals.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.max()) < 6 and int(idx.min()) >= 0
    # top-1 has the largest gate
    assert np.all(np.asarray(vals[..., 0]) >= np.asarray(vals[..., 1]) - 1e-7)


@pytest.mark.parametrize("impl", [moe_layer_einsum, moe_layer_scatter])
def test_impl_matches_dense_reference(key, impl):
    """With capacity high enough that nothing drops, the layer must equal a
    dense per-token evaluation of the top-k experts."""
    cfg = _cfg(E=4, k=2, cf=16.0, g=8)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(3), (2, 8, cfg.d_model))
    out, _ = impl(p, x, cfg, CTX)
    xf = np.asarray(x.reshape(-1, cfg.d_model))
    logits = xf @ np.asarray(p["router"])
    vals, idx = _route(jnp.asarray(logits), cfg.top_k)
    ref = np.zeros_like(xf)
    act = jax.nn.silu
    for t in range(xf.shape[0]):
        for s in range(cfg.top_k):
            e = int(idx[t, s])
            h = np.asarray(act(jnp.asarray(xf[t] @ np.asarray(p["wg"][e])))) \
                * (xf[t] @ np.asarray(p["wu"][e]))
            ref[t] += float(vals[t, s]) * (h @ np.asarray(p["wd"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), ref,
                               atol=1e-4, rtol=1e-4)


def test_einsum_and_scatter_agree(key):
    """Both dispatch implementations share routing semantics exactly —
    including capacity drops."""
    cfg = _cfg(E=4, k=2, cf=0.5, g=16)      # tight capacity: drops happen
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(4), (2, 16, cfg.d_model))
    o1, a1 = moe_layer_einsum(p, x, cfg, CTX)
    o2, a2 = moe_layer_scatter(p, x, cfg, CTX)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(E=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 3),
       cf=st.floats(0.25, 4.0),
       seed=st.integers(0, 2**31 - 1))
def test_capacity_bound_property(E, k, cf, seed):
    """No expert ever receives more than C tokens; dropped token-slots
    contribute zero.  Verified through the scatter impl's internals."""
    k = min(k, E)
    cfg = _cfg(E=E, k=k, cf=cf, g=16)
    key = jax.random.key(seed)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (1, 16, cfg.d_model))
    out, aux = moe_layer_scatter(p, x, cfg, CTX)
    assert np.all(np.isfinite(np.asarray(out)))
    # E[aux] >= 1 with equality at perfect balance; finite-sample noise
    # can dip a few percent below
    assert float(aux) >= 0.85
    # independently recompute routing and check the capacity invariant
    import math
    g = 16
    C = max(1, math.ceil(g * cf * k / E))
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    _, idx = _route(logits, k)
    counts = np.zeros(E, np.int64)
    kept = 0
    for t in range(16):
        for s in range(k):
            e = int(idx[t, s])
            if counts[e] < C:
                counts[e] += 1
                kept += 1
    assert counts.max() <= C
    # einsum impl agrees under the same tight capacity
    o2, _ = moe_layer_einsum(p, x, cfg, CTX)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)


def test_aux_loss_balanced_router_is_one(key):
    """A perfectly uniform router gives aux ~= 1 (E * E * (1/E) * (1/E))."""
    cfg = _cfg(E=4, k=1, g=16)
    p = init_moe(key, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform probs
    x = jax.random.normal(jax.random.key(5), (4, 16, cfg.d_model))
    _, aux = moe_layer_einsum(p, x, cfg, CTX)
    # ties in top-1 pick expert 0 deterministically -> frac concentrates, but
    # probs_mean stays uniform: aux = E * sum(1/E * frac) = 1
    assert float(aux) == pytest.approx(1.0, abs=1e-3)


def test_moe_group_divides_tokens():
    """group not dividing tokens falls back to a power-of-two divisor."""
    cfg = _cfg(E=2, k=1, g=24)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out, _ = moe_layer_einsum(p, x, cfg, CTX)     # 32 tokens, g=24 -> g=12? no: halves to 8... just must not crash
    assert out.shape == x.shape
