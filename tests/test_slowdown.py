"""Decoupled slowdown model (paper §3.4 + Fig. 2 calibration)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (DecoupledSlowdown, NoSlowdown, SlowdownParams,
                        build_testbed, heye_params, truth_params)
from repro.core.topology import make_task


@pytest.fixture(scope="module")
def tb():
    return build_testbed(edge_counts={"orin_agx": 1},
                         server_counts={"server1": 1})


def _factor(tb, kind_a, pu_a, kind_b, pu_b, params=None):
    sd = DecoupledSlowdown(tb.graph, params or heye_params())
    ta, tb_ = make_task(kind_a), make_task(kind_b)
    return sd.factor(ta, pu_a, [(tb_, pu_b)])


def test_fig2_multitenant_gpu(tb):
    """Two DNNs on one GPU -> 0.66x standalone speed (factor ~1.52)."""
    e = tb.edges[0]
    f = _factor(tb, "dnn", f"{e}.gpu", "dnn", f"{e}.gpu")
    assert abs(1.0 / f - 0.66) < 0.03


def test_fig2_cpu_gpu_llc(tb):
    """MM on CPU + MM on GPU via shared LLC -> ~0.89x."""
    e = tb.edges[0]
    f = _factor(tb, "mm", f"{e}.cpu0", "mm", f"{e}.gpu")
    assert abs(1.0 / f - 0.89) < 0.03


def test_fig2_dla_gpu_like_dram(tb):
    """GPU + DLA contend via DRAM-class shared memory -> ~0.68x."""
    e = tb.edges[0]
    f = _factor(tb, "dnn", f"{e}.dla", "dnn", f"{e}.gpu")
    assert abs(1.0 / f - 0.68) < 0.05


def test_l2_vs_l3_ordering(tb):
    """Same-cluster (L2) contention is milder than cross-cluster (L3):
    0.91x vs 0.87x (Fig. 2)."""
    e = tb.edges[0]
    same = _factor(tb, "mm", f"{e}.cpu0", "mm", f"{e}.cpu0")  # multi-tenant
    # cross-cluster: two CPU clusters meet at L3
    cross = _factor(tb, "mm", f"{e}.cpu0", "mm", f"{e}.cpu1")
    assert cross > 1.0
    # VIC has private storage: a GPU co-runner must not slow it down via memory
    vic = _factor(tb, "reproject", f"{e}.vic", "render", f"{e}.gpu")
    assert vic < cross


def test_different_devices_no_slowdown(tb2=None):
    tb = build_testbed(edge_counts={"orin_agx": 2},
                       server_counts={"server1": 1})
    e0, e1 = tb.edges[0], tb.edges[1]
    f = _factor(tb, "mm", f"{e0}.gpu", "mm", f"{e1}.gpu")
    assert f == 1.0


def test_noslowdown_is_identity(tb):
    e = tb.edges[0]
    ns = NoSlowdown(tb.graph)
    assert ns.factor(make_task("dnn"), f"{e}.gpu",
                     [(make_task("dnn"), f"{e}.gpu")]) == 1.0


def test_superlinear_curvature(tb):
    """The profiled curvature (superlinear kappa) only shows above one
    co-runner: at x=2 the slowdown exceeds 2x the x=1 increment."""
    e = tb.edges[0]
    sd_kind = "dnn"
    from repro.core import DecoupledSlowdown
    sd = DecoupledSlowdown(tb.graph, SlowdownParams())
    t = make_task(sd_kind)
    f1 = sd.factor(t, f"{e}.gpu", [(make_task(sd_kind), f"{e}.gpu")])
    f2 = sd.factor(t, f"{e}.gpu", [(make_task(sd_kind), f"{e}.gpu"),
                                   (make_task(sd_kind), f"{e}.gpu")])
    assert (f2 - 1.0) > 2.0 * (f1 - 1.0)   # curvature, not linearity
    flat = DecoupledSlowdown(tb.graph, SlowdownParams(superlinear=0.0))
    g2 = flat.factor(t, f"{e}.gpu", [(make_task(sd_kind), f"{e}.gpu"),
                                     (make_task(sd_kind), f"{e}.gpu")])
    assert f2 > g2


@settings(max_examples=50, deadline=None)
@given(n_corunners=st.integers(0, 6),
       usage=st.floats(0.1, 1.0))
def test_factor_properties(n_corunners, usage):
    """factor >= 1 always; monotone non-decreasing in co-runner count."""
    tb = build_testbed(edge_counts={"orin_agx": 1},
                       server_counts={"server1": 1})
    e = tb.edges[0]
    sd = DecoupledSlowdown(tb.graph)
    t = make_task("mm")
    t.usage["mem"] = usage
    fs = []
    for n in range(n_corunners + 1):
        co = [(make_task("mm"), f"{e}.gpu") for _ in range(n)]
        fs.append(sd.factor(t, f"{e}.cpu0", co))
    assert all(f >= 1.0 for f in fs)
    assert all(b >= a - 1e-12 for a, b in zip(fs, fs[1:]))


def test_noise_reproducible(tb):
    e = tb.edges[0]
    p = truth_params()
    f1 = DecoupledSlowdown(tb.graph, p, np.random.default_rng(7)).factor(
        make_task("knn"), f"{e}.gpu", [(make_task("knn"), f"{e}.gpu")])
    f2 = DecoupledSlowdown(tb.graph, p, np.random.default_rng(7)).factor(
        make_task("knn"), f"{e}.gpu", [(make_task("knn"), f"{e}.gpu")])
    assert f1 == f2 and f1 >= 1.0


# ---------------------------------------------------------------------------
# small-pool scalar fast path (the light-load DES kernel-overhead floor)
# ---------------------------------------------------------------------------

def _ledger_cols(tb, n, seed):
    comp = tb.graph.compiled()
    rng = np.random.default_rng(seed)
    P = rng.integers(0, len(comp.pu_names), n).astype(np.int64)
    U = rng.uniform(0.05, 0.9, n)
    mem = rng.uniform(0.05, 0.9, n)
    return comp, P, U, mem, np.arange(n, dtype=np.int64)


def test_small_pool_dispatch_boundary(tb, monkeypatch):
    """Pools at or below _SMALL_POOL_MAX take the scalar loop (pairs take
    the dedicated pair path); one past the boundary takes the array path."""
    from repro.core.slowdown import _SMALL_POOL_MAX
    sd = DecoupledSlowdown(tb.graph, heye_params())
    calls = []
    for name in ("_factor_pair", "_factor_small", "_factor_batch_arrays"):
        orig = getattr(sd, name)
        monkeypatch.setattr(
            sd, name,
            lambda *a, _o=orig, _n=name, **k: (calls.append(_n), _o(*a, **k))[1])
    for n, want in [(2, "_factor_pair"),
                    (_SMALL_POOL_MAX, "_factor_small"),
                    (_SMALL_POOL_MAX + 1, "_factor_batch_arrays")]:
        calls.clear()
        _, P, U, mem, uid = _ledger_cols(tb, n, seed=n)
        sd.factor_batch_idx(P, U, mem, uid)
        assert calls == [want], (n, calls)


def test_small_pool_crossover_bit_equal(tb):
    """Across the dispatch crossover the scalar/pair paths are bit-identical
    to the array path (same accumulation orders — see _factor_small)."""
    from repro.core.slowdown import _SMALL_POOL_MAX
    sd = DecoupledSlowdown(tb.graph, heye_params())
    for n in range(2, _SMALL_POOL_MAX + 3):
        for seed in range(4):
            comp, P, U, mem, uid = _ledger_cols(tb, n, seed=17 * n + seed)
            got = sd.factor_batch_idx(P, U, mem, uid)
            M = np.minimum(mem, comp.mem_cap[P])
            want = sd._factor_batch_arrays(comp, P, U, M, uid, distinct=True)
            assert got.tolist() == want.tolist()
            assert np.all(got >= 1.0)


def test_factor_caches_rebase_across_bandwidth_delta():
    """A bandwidth-only ``apply_churn`` yields a kin snapshot (all factor
    columns shared by identity): the per-snapshot beta tables and the
    canonical factor cache carry over verbatim instead of rebuilding.  A
    fresh full compile (new columns, no kinship) still rebuilds both."""
    from repro.core import Churn
    from repro.core.compiled import CompiledHWGraph
    tbx = build_testbed(edge_counts={"orin_agx": 1},
                        server_counts={"server1": 1})
    g = tbx.graph
    sd = DecoupledSlowdown(g, heye_params())
    comp = g.compiled()
    tables = sd._tables(comp)
    canon = sd._canon_cache_dict(comp)
    canon["probe"] = 1.0
    g.apply_churn(Churn(bandwidth=((f"link_{tbx.edges[0]}", 2e6),)))
    comp2 = g.compiled()
    assert comp2 is not comp                 # delta clone: a new snapshot
    assert sd._factor_kin(comp, comp2)       # ...sharing every factor column
    assert sd._tables(comp2) is tables       # rebased, not rebuilt
    d2 = sd._canon_cache_dict(comp2)
    assert d2 is canon and d2["probe"] == 1.0
    fresh = CompiledHWGraph(g)               # full rebuild: no kinship
    assert not sd._factor_kin(comp2, fresh)
    assert sd._tables(fresh) is not tables
    assert "probe" not in sd._canon_cache_dict(fresh)
