"""Online serving continuum: resident-timeline parity with the offline
batch path (1e-9), seeded arrival-stream determinism, admission-control
verdicts, ledger reconciliation, and tail-metric reporting."""
import itertools
from itertools import groupby

import numpy as np
import pytest

import repro.core.task as task_mod
from repro.core import (ClosedLoopClients, DiurnalArrivals, PoissonArrivals,
                        SchedulerSession, ServeLoop, TaskGraph, TenantSpec,
                        build_orchestrators, build_testbed,
                        ground_truth_traverser, heye_traverser,
                        mining_workload, single_task_request, vr_workload)
from repro.core.timeline import TimelineEngine
from repro.core.topology import make_task
from repro.serve.admission import (AdaptiveWindow, AdmissionController,
                                   Decision, Verdict, admit_all)

TOL = 1e-9


def _testbed(mult=1):
    return build_testbed(
        edge_counts={"orin_agx": 2 * mult, "xavier_agx": mult,
                     "orin_nano": mult, "xavier_nx": mult},
        server_counts={"server1": 1, "server2": 1})


def _mapped(workload_fn, seed_uid, mult=1):
    """Two identical (testbed, cfg, mapping) copies so each engine runs
    on untouched state; mapping comes from a real session drive."""
    out = []
    for _ in range(2):
        task_mod._task_counter = itertools.count(seed_uid)
        tb = _testbed(mult)
        cfg = workload_fn(tb)
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        s = SchedulerSession(tb.graph, root)
        s.submit(cfg)
        s.map_pending()
        out.append((tb, cfg, dict(s.mapping)))
    return out


def _assert_parity(tl_ref, tl_arr, tol=TOL):
    assert set(tl_ref.finish) == set(tl_arr.finish)
    for k in tl_ref.finish:
        assert tl_ref.finish[k] == pytest.approx(tl_arr.finish[k],
                                                 abs=tol, rel=tol), k
    for k in tl_ref.start:
        assert tl_ref.start[k] == pytest.approx(tl_arr.start[k],
                                                abs=tol, rel=tol), k
    for k in tl_ref.queue_wait:
        assert tl_ref.queue_wait[k] == pytest.approx(
            tl_arr.queue_wait.get(k, 0.0), abs=tol, rel=tol), k
    for k in tl_ref.comm:
        assert tl_ref.comm[k] == pytest.approx(tl_arr.comm.get(k, 0.0),
                                               abs=tol, rel=tol), k


# ---------------------------------------------------------------------------
# online-vs-offline parity (the acceptance bar)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noise_seed", [None, 0])
def test_upfront_resident_parity_mining(noise_seed):
    """Fig. 13 config: the full workload submitted upfront through a
    resident engine reproduces the seed heapq loop to 1e-9 (prediction
    and noisy-ground-truth models)."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: mining_workload(tb, n_sensors=18, n_readings=2),
        seed_uid=600_000)
    mk1 = (heye_traverser(tb1.graph) if noise_seed is None
           else ground_truth_traverser(tb1.graph, noise_seed))
    mk2 = (heye_traverser(tb2.graph) if noise_seed is None
           else ground_truth_traverser(tb2.graph, noise_seed))
    tl_ref = mk1.traverse_reference(cfg1, m1)
    eng = TimelineEngine.open(mk2, cfg=cfg2, mapping=dict(m2))
    tl_on = eng.advance().timeline()
    _assert_parity(tl_ref, tl_on)


@pytest.mark.parametrize("noise_seed", [None, 3])
def test_upfront_resident_parity_vr(noise_seed):
    """Fig. 14-style VR chains: serial deps and cross-device transfers
    through the resident path."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: vr_workload(tb, n_frames=5), seed_uid=610_000)
    mk1 = (heye_traverser(tb1.graph) if noise_seed is None
           else ground_truth_traverser(tb1.graph, noise_seed))
    mk2 = (heye_traverser(tb2.graph) if noise_seed is None
           else ground_truth_traverser(tb2.graph, noise_seed))
    _assert_parity(mk1.traverse_reference(cfg1, m1),
                   TimelineEngine.open(mk2, cfg=cfg2,
                                       mapping=dict(m2)).advance().timeline())


def test_wave_injection_parity():
    """Injecting the workload wave-by-wave (advance to just before each
    release instant, then inject that release cohort) is event-for-event
    identical to the one-shot run — the live-traffic core claim."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: mining_workload(tb, n_sensors=18, n_readings=3),
        seed_uid=620_000)
    tl_ref = ground_truth_traverser(tb1.graph, 1).traverse_reference(cfg1, m1)
    eng = TimelineEngine.open(ground_truth_traverser(tb2.graph, 1),
                              mapping=dict(m2))
    eng.cfg = cfg2          # dependency edges resolve against the graph
    tasks = sorted(cfg2, key=lambda t: (t.release_time, t.uid))
    for rel, grp in groupby(tasks, key=lambda t: t.release_time):
        eng.advance(np.nextafter(rel, -np.inf))
        eng.inject(list(grp))
    tl_on = eng.advance().timeline()
    _assert_parity(tl_ref, tl_on)


@pytest.mark.parametrize("kind", ["bandwidth", "dead"])
def test_resident_churn_parity(kind):
    """mark_dead / set_bandwidth mid-stream: `schedule` on a resident
    engine matches `traverse(..., interventions=...)` while work is
    injected wave-by-wave around the churn instant."""
    (tb1, cfg1, m1), (tb2, cfg2, m2) = _mapped(
        lambda tb: mining_workload(tb, n_sensors=24, n_readings=2),
        seed_uid=630_000)

    def fns(tb):
        if kind == "bandwidth":
            return [(0.02, lambda: tb.graph.set_bandwidth(
                        f"link_{tb.edges[0]}", 1e6)),
                    (0.15, lambda: tb.graph.set_bandwidth(
                        f"link_{tb.edges[0]}", 1e9))]
        e = tb.edges[1]
        return [(0.03, lambda: tb.graph.mark_dead(e)),
                (0.12, lambda: tb.graph.mark_alive(e))]

    tl_ref = ground_truth_traverser(tb1.graph, 2).traverse_reference(
        cfg1, m1, interventions=fns(tb1))
    eng = TimelineEngine.open(ground_truth_traverser(tb2.graph, 2),
                              mapping=dict(m2))
    eng.cfg = cfg2
    for t, fn in fns(tb2):
        eng.schedule(t, fn)
    tasks = sorted(cfg2, key=lambda t: (t.release_time, t.uid))
    for rel, grp in groupby(tasks, key=lambda t: t.release_time):
        eng.advance(np.nextafter(rel, -np.inf))
        eng.inject(list(grp))
    _assert_parity(tl_ref, eng.advance().timeline())


def test_session_finalize_online_matches_execute():
    """The session-level wiring: open_timeline after mapping, drain, and
    the RunStats match the offline execute() to 1e-9 (overhead columns
    included).  Twin sessions so each path consumes a fresh noise
    stream."""
    def drive(online):
        task_mod._task_counter = itertools.count(640_000)
        tb = _testbed()
        cfg = mining_workload(tb, n_sensors=12, n_readings=2)
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        s = SchedulerSession(tb.graph, root,
                             truth=ground_truth_traverser(tb.graph, 0))
        s.submit(cfg)
        s.map_pending()
        if not online:
            return s, s.execute()
        s.open_timeline()
        return s, s.finalize_online()

    s_off, off = drive(online=False)
    s_on, on = drive(online=True)
    assert s_on.engine_opens == 1
    _assert_parity(off.timeline, on.timeline)
    assert on.overhead == off.overhead
    assert on.mapping == off.mapping


# ---------------------------------------------------------------------------
# resident-engine API contracts
# ---------------------------------------------------------------------------
def test_inject_into_past_raises():
    tb = _testbed()
    eng = TimelineEngine.open(heye_traverser(tb.graph))
    eng.advance(0.5)
    late = make_task("dnn", origin=tb.edges[0], release_time=0.1)
    eng.cfg.add(late)
    with pytest.raises(ValueError):
        eng.inject([late], mapping={late.uid: f"{tb.edges[0]}.gpu"})


def test_drain_finished_and_finish_of():
    tb = _testbed()
    eng = TimelineEngine.open(heye_traverser(tb.graph))
    t1 = make_task("dnn", origin=tb.edges[0], release_time=0.0)
    t2 = make_task("dnn", origin=tb.edges[0], release_time=10.0)
    for t in (t1, t2):
        eng.cfg.add(t)
    eng.inject([t1, t2], mapping={t.uid: f"{tb.edges[0]}.gpu"
                                  for t in (t1, t2)})
    assert np.isnan(eng.finish_of(t1.uid))
    eng.advance(5.0)
    done = eng.drain_finished()
    assert [t.uid for t in done] == [t1.uid]
    assert eng.drain_finished() == []               # cursor moved
    assert eng.finish_of(t1.uid) > 0.0
    assert np.isnan(eng.finish_of(t2.uid))          # not yet released
    eng.advance()
    assert [t.uid for t in eng.drain_finished()] == [t2.uid]
    # partial snapshots never raised mid-run; the final one is complete
    assert set(eng.timeline().finish) == {t1.uid, t2.uid}


def test_timeline_partial_mid_run():
    tb = _testbed()
    eng = TimelineEngine.open(heye_traverser(tb.graph))
    t1 = make_task("dnn", origin=tb.edges[0], release_time=0.0)
    t2 = make_task("dnn", origin=tb.edges[0], release_time=10.0)
    for t in (t1, t2):
        eng.cfg.add(t)
    eng.inject([t1, t2], mapping={t.uid: f"{tb.edges[0]}.gpu"
                                  for t in (t1, t2)})
    eng.advance(5.0)
    snap = eng.timeline(partial=True)
    assert t1.uid in snap.finish and t2.uid not in snap.finish
    with pytest.raises(RuntimeError):
        eng.timeline()                              # t2 still pending


def test_noisy_slowdown_model_rejected_for_resident():
    from repro.core import DecoupledSlowdown, Traverser, truth_params
    tb = _testbed()
    noisy = Traverser(tb.graph, DecoupledSlowdown(
        tb.graph, truth_params(), rng=np.random.default_rng(0)))
    with pytest.raises(ValueError):
        TimelineEngine.open(noisy)


# ---------------------------------------------------------------------------
# arrival processes: determinism + shape
# ---------------------------------------------------------------------------
def test_poisson_stream_deterministic():
    a = PoissonArrivals(rate=500.0, seed=42)
    b = PoissonArrivals(rate=500.0, seed=42)
    ta, tb_ = a.times(2.0), b.times(2.0)
    np.testing.assert_array_equal(ta, tb_)
    np.testing.assert_array_equal(ta, a.times(2.0))    # re-entrant
    assert (np.diff(ta) > 0).all() and ta[-1] < 2.0
    # rate sanity: ~1000 arrivals over 2 s at 500 rps
    assert 800 < len(ta) < 1200
    assert len(PoissonArrivals(rate=500.0, seed=7).times(2.0)) != 0
    assert not np.array_equal(PoissonArrivals(rate=500.0, seed=7).times(2.0),
                              ta)


def test_diurnal_stream_deterministic_and_rate_shaped():
    d1 = DiurnalArrivals(base_rate=50.0, peak_rate=500.0, period=2.0,
                         seed=3, phase=0.0)
    d2 = DiurnalArrivals(base_rate=50.0, peak_rate=500.0, period=2.0,
                         seed=3, phase=0.0)
    t1 = d1.times(2.0)
    np.testing.assert_array_equal(t1, d2.times(2.0))
    assert (np.diff(t1) > 0).all()
    # phase 0: trough at t=0, peak at t=period/2 — the peak half must
    # carry clearly more arrivals than the trough quarters
    q = np.histogram(t1, bins=4, range=(0.0, 2.0))[0]
    assert q[1] + q[2] > 2.0 * (q[0] + q[3])
    assert float(d1.rate(0.0)) == pytest.approx(50.0)
    assert float(d1.rate(1.0)) == pytest.approx(500.0)


def test_serve_loop_replays_identically():
    """Same seeds, same testbed -> byte-identical serving outcomes."""
    def once():
        task_mod._task_counter = itertools.count(650_000)
        tb = _testbed()
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        tenants = [TenantSpec(
            "m", PoissonArrivals(rate=300, seed=5),
            single_task_request("svm", origin=tb.edges[0], sla=0.1),
            sla=0.1)]
        loop = ServeLoop(tb.graph, root, tenants,
                         truth=ground_truth_traverser(tb.graph, 0),
                         admission=admit_all(), horizon=0.25)
        st = loop.run()
        return ([r.verdict for r in st.requests],
                [r.latency for r in st.accepted])
    v1, l1 = once()
    v2, l2 = once()
    assert v1 == v2 and l1 == l2


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
class _FixedArrivals:
    """Test arrivals: explicit instants."""

    def __init__(self, instants):
        self.instants = np.asarray(instants, dtype=np.float64)

    def times(self, horizon):
        return self.instants[self.instants < horizon]


def _one_request_loop(tb, admission, sla, arrivals=(0.01,),
                      max_inflight=None):
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    tenants = [TenantSpec(
        "t0", _FixedArrivals(arrivals),
        single_task_request("svm", origin=tb.edges[0], sla=sla), sla=sla,
        max_inflight=max_inflight)]
    return ServeLoop(tb.graph, root, tenants,
                     truth=ground_truth_traverser(tb.graph, 0),
                     admission=admission, horizon=1.0)


def test_admission_projected_sla_reject():
    """A deadline far below any projected completion is refused up front
    with the projected_sla reason (or infeasible, if the walk itself
    refuses), and the ledger holds no belief for it afterwards."""
    task_mod._task_counter = itertools.count(660_000)
    tb = _testbed()
    loop = _one_request_loop(tb, AdmissionController(slack=1.0), sla=1e-7)
    st = loop.run()
    assert len(st.requests) == 1
    (req,) = st.requests
    assert req.verdict == "rejected"
    assert req.reject_reason in ("projected_sla", "infeasible")
    assert st.sla_attainment() == {"t0": 0.0}    # a reject is a miss
    assert len(loop.session.policy.ledger) == 0
    assert len(loop.session.cfg) == 0            # withdrawn from the CFG


def test_admission_defer_then_reject():
    """max_inflight=0 quota: each attempt defers until max_defers is
    exhausted, then rejects with the quota reason."""
    task_mod._task_counter = itertools.count(665_000)
    tb = _testbed()
    loop = _one_request_loop(
        tb, AdmissionController(defer_delay=0.01, max_defers=2),
        sla=0.5, max_inflight=0)
    st = loop.run()
    (req,) = st.requests
    assert req.verdict == "rejected"
    assert req.reject_reason == "inflight_cap"
    assert req.defers == 2 and st.deferrals == 2


def test_admission_defer_then_accept():
    """A deferred request retries later and is admitted once inflight
    drops; its latency includes the defer wait."""
    task_mod._task_counter = itertools.count(670_000)
    tb = _testbed()
    # two arrivals, cap 1: the second defers while the first runs
    loop = _one_request_loop(
        tb, AdmissionController(slack=float("inf"), defer_delay=0.2,
                                max_defers=10),
        sla=None, arrivals=(0.01, 0.011), max_inflight=1)
    st = loop.run()
    assert [r.verdict for r in st.requests] == ["accepted", "accepted"]
    second = st.requests[1]
    assert second.defers >= 1
    assert second.latency > 0.2 * second.defers     # waited out the defers
    assert st.engine_opens == 1


def test_admit_all_controller():
    task_mod._task_counter = itertools.count(675_000)
    tb = _testbed()
    loop = _one_request_loop(tb, admit_all(), sla=1e-7)   # absurd SLA
    st = loop.run()
    assert st.requests[0].verdict == "accepted"           # mapped => in
    assert st.sla_attainment() == {"t0": 0.0}             # but missed


def test_decision_constructors():
    assert Decision.accept().verdict is Verdict.ACCEPT
    d = Decision.defer("quota", retry_at=1.5)
    assert d.verdict is Verdict.DEFER and d.retry_at == 1.5
    assert Decision.reject("x").reason == "x"


# ---------------------------------------------------------------------------
# end-to-end loop + reporting
# ---------------------------------------------------------------------------
def test_serve_loop_end_to_end_multi_tenant():
    task_mod._task_counter = itertools.count(680_000)
    tb = _testbed()
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    tenants = [
        TenantSpec("mining", PoissonArrivals(rate=300, seed=1),
                   single_task_request("svm", origin=tb.edges[0], sla=0.1),
                   sla=0.1),
        TenantSpec("vision", DiurnalArrivals(base_rate=80, peak_rate=240,
                                             period=0.25, seed=2),
                   single_task_request("mlp", origin=tb.edges[1], sla=0.15),
                   sla=0.15),
    ]
    loop = ServeLoop(tb.graph, root, tenants,
                     truth=ground_truth_traverser(tb.graph, 0),
                     admission=AdmissionController(slack=3.0),
                     horizon=0.25)
    st = loop.run()
    s = st.summary()
    assert s["engine_opens"] == 1                   # zero rebuilds
    assert s["requests"] == s["accepted"] + s["rejected"]
    assert s["requests"] > 20
    # every accepted request finished once the loop drained
    assert all(r.finish == r.finish for r in st.accepted)
    # tail ordering + shared percentile definitions
    assert s["p50_ms"] <= s["p99_ms"] <= s["p999_ms"]
    for ten, att in st.sla_attainment().items():
        assert 0.0 <= att <= 1.0
    per = st.latency_percentiles_by_tenant()
    assert set(per) == {"mining", "vision"}
    # inflight accounting returned to zero
    assert all(v == 0 for v in loop._inflight.values())
    # tenant stamps landed on the tasks
    assert all(t.attrs["tenant"] == r.tenant
               for r in st.accepted for t in r.tasks)


def test_serve_loop_with_mid_run_churn():
    """Topology churn under live traffic: the loop keeps serving across
    a mark_dead/mark_alive cycle with zero engine rebuilds."""
    task_mod._task_counter = itertools.count(690_000)
    tb = _testbed()
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    e = tb.edges[1]
    tenants = [TenantSpec(
        "m", PoissonArrivals(rate=200, seed=9),
        single_task_request("svm", origin=tb.edges[0], sla=0.2), sla=0.2)]
    loop = ServeLoop(tb.graph, root, tenants,
                     truth=ground_truth_traverser(tb.graph, 0),
                     admission=admit_all(), horizon=0.3,
                     interventions=[(0.1, lambda: tb.graph.mark_dead(e)),
                                    (0.2, lambda: tb.graph.mark_alive(e))])
    st = loop.run()
    assert st.engine_opens == 1
    assert len(st.accepted) > 10


# ---------------------------------------------------------------------------
# offline tail metrics (RunStats) + session withdraw + ledger retire
# ---------------------------------------------------------------------------
def test_runstats_latency_percentiles_and_tenants():
    task_mod._task_counter = itertools.count(700_000)
    tb = _testbed()
    cfg = mining_workload(tb, n_sensors=12, n_readings=2)
    for i, t in enumerate(cfg):
        t.attrs["tenant"] = f"g{i % 2}"
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    s = SchedulerSession(tb.graph, root,
                         truth=ground_truth_traverser(tb.graph, 0))
    stats = s.run(cfg)
    pct = stats.latency_percentiles(cfg)
    assert set(pct) == {50.0, 99.0, 99.9}
    assert pct[50.0] <= pct[99.0] <= pct[99.9]
    lats = stats.latencies(cfg)
    assert len(lats) == len(list(cfg))
    assert pct[99.9] <= max(lats) + 1e-12
    per = stats.latency_percentiles_by_tenant(cfg)
    assert set(per) == {"g0", "g1"}
    att = stats.sla_attainment(cfg)
    assert set(att) == {"g0", "g1"}
    for v in att.values():
        assert 0.0 <= v <= 1.0


def test_percentiles_helper_empty_and_exact():
    from repro.core.session import percentiles
    out = percentiles([])
    assert all(np.isnan(v) for v in out.values())
    out = percentiles([1.0, 2.0, 3.0], qs=(0.0, 50.0, 100.0))
    assert out[0.0] == 1.0 and out[50.0] == 2.0 and out[100.0] == 3.0


def test_session_withdraw_restores_state():
    task_mod._task_counter = itertools.count(710_000)
    tb = _testbed()
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    s = SchedulerSession(tb.graph, root)
    g = TaskGraph("req")
    t = make_task("svm", origin=tb.edges[0], release_time=0.05)
    g.add(t)
    rel0 = t.release_time
    s.submit(g)
    res = s.map_pending(fallback=False)[t.uid]
    assert res is not None
    assert len(root.ledger) == 1
    s.withdraw(t)
    assert t.release_time == rel0              # overhead charge reverted
    assert t.assigned_pu is None
    assert len(root.ledger) == 0
    assert t.uid not in s.mapping and len(s.cfg) == 0
    # the same task can be resubmitted and mapped again
    g2 = TaskGraph("req2")
    g2.add(t)
    s.submit(g2)
    assert s.map_pending()[t.uid] is not None


def test_ledger_retire_batch():
    task_mod._task_counter = itertools.count(720_000)
    tb = _testbed()
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    s = SchedulerSession(tb.graph, root)
    cfg = mining_workload(tb, n_sensors=4, n_readings=1)
    s.submit(cfg)
    s.map_pending()
    uids = [t.uid for t in cfg]
    n0 = len(root.ledger)
    assert n0 == len(uids)
    killed = root.ledger.retire(uids[:5])
    assert killed == 5
    assert len(root.ledger) == n0 - 5
    assert root.ledger.retire([999_999_999]) == 0    # unknown: no-op
    assert root.ledger.retire([]) == 0


def test_taskgraph_remove_drops_edges():
    g = TaskGraph()
    a = make_task("svm")
    b = make_task("svm")
    g.add(a)
    g.add(b, deps=[a])
    g.remove(a)
    assert len(g) == 1 and g.preds(b) == []
    g.remove(b)
    assert len(g) == 0


# ---------------------------------------------------------------------------
# small-wave serving fast path: whole-run oracle parity
# ---------------------------------------------------------------------------
def _serve_run(seed_uid, interventions=None, slack=4.0, horizon=0.3,
               batch_window=0.0):
    task_mod._task_counter = itertools.count(seed_uid)
    tb = _testbed()
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    tenants = [
        TenantSpec("mining", PoissonArrivals(rate=250, seed=21),
                   single_task_request("svm", origin=tb.edges[0], sla=0.1),
                   sla=0.1),
        TenantSpec("vision", DiurnalArrivals(base_rate=60, peak_rate=180,
                                             period=horizon, seed=22),
                   single_task_request("mlp", origin=tb.edges[1], sla=0.15),
                   sla=0.15),
    ]
    iv = []
    if interventions is not None:
        iv = [(t, fn(tb)) for t, fn in interventions]
    loop = ServeLoop(tb.graph, root, tenants,
                     truth=ground_truth_traverser(tb.graph, 0),
                     admission=AdmissionController(slack=slack,
                                                   defer_delay=0.005,
                                                   max_defers=1),
                     batch_window=batch_window,
                     horizon=horizon, interventions=iv)
    return loop.run()


def _assert_request_parity(fast, cold, tol=TOL):
    assert len(fast.requests) == len(cold.requests)
    for a, b in zip(fast.requests, cold.requests):
        assert a.verdict == b.verdict, a.rid
        assert a.reject_reason == b.reject_reason, a.rid
        if np.isnan(a.finish) and np.isnan(b.finish):
            continue
        assert a.finish == pytest.approx(b.finish, abs=tol, rel=tol), a.rid


@pytest.mark.parametrize("slack", [4.0, 0.35])
def test_serve_fastpath_oracle_parity(monkeypatch, slack):
    """The session-resident fast path reproduces the cold per-wave walk
    request for request — verdicts, reject reasons and finish times to
    1e-9 — for both an all-accept mix and a tight-slack mix that
    exercises refusal and withdraw."""
    fast = _serve_run(730_000, slack=slack)
    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "0")
    cold = _serve_run(730_000, slack=slack)
    assert fast.requests and fast.engine_opens == 1
    if slack == 0.35:      # the tight mix must actually refuse something
        assert any(r.verdict == "rejected" for r in fast.requests)
    _assert_request_parity(fast, cold)


def test_serve_fastpath_parity_with_churn(monkeypatch):
    """Mid-run churn (death + revival under live traffic) invalidates
    exactly the persistent state it must: the fast path still matches
    the oracle walk whole-run."""
    iv = [(0.08, lambda tb: (lambda e=tb.edges[1]:
                             tb.graph.mark_dead(e))),
          (0.18, lambda tb: (lambda e=tb.edges[1]:
                             tb.graph.mark_alive(e)))]
    fast = _serve_run(740_000, interventions=iv)
    monkeypatch.setenv("REPRO_SERVE_FASTPATH", "0")
    cold = _serve_run(740_000, interventions=iv)
    assert fast.engine_opens == 1
    _assert_request_parity(fast, cold)


# ---------------------------------------------------------------------------
# overload-adaptive admission coalescing
# ---------------------------------------------------------------------------
def test_adaptive_window_math():
    w = AdaptiveWindow(max_window=0.01, depth_hi=10, proj_hi=2.0)
    assert w.window(0, 0.0) == 0.0                 # idle -> per-arrival
    assert w.window(0, 1.0) == 0.0                 # at-deadline: no pressure
    assert w.window(5, 0.0) == pytest.approx(0.005)
    assert w.window(10, 0.0) == pytest.approx(0.01)
    assert w.window(40, 0.0) == pytest.approx(0.01)    # capped
    assert w.window(0, 1.5) == pytest.approx(0.005)    # slowdown pressure
    assert w.window(0, 3.0) == pytest.approx(0.01)
    # max of the two pressures, not the sum
    assert w.window(5, 1.5) == pytest.approx(0.005)
    lo = AdaptiveWindow(max_window=0.01, min_window=0.002)
    assert lo.window(0, 0.0) == 0.002


def test_adaptive_window_loop_deterministic():
    """Adaptive coalescing keeps the loop deterministic: same seeds give
    identical wave boundaries and outcomes, and pressure actually widens
    waves beyond one request under load."""
    bw = AdaptiveWindow(max_window=0.01, depth_hi=4)
    a = _serve_run(750_000, batch_window=bw, slack=float("inf"))
    b = _serve_run(750_000, batch_window=bw, slack=float("inf"))
    assert a.wave_sizes == b.wave_sizes
    assert [r.verdict for r in a.requests] == \
        [r.verdict for r in b.requests]
    assert [r.finish for r in a.accepted] == [r.finish for r in b.accepted]
    assert max(a.wave_sizes) > 1           # pressure coalesced something
    # every arrival pops in exactly one wave; each deferral re-pops once
    assert sum(a.wave_sizes) == len(a.requests) + a.deferrals


# ---------------------------------------------------------------------------
# closed-loop clients
# ---------------------------------------------------------------------------
def test_closed_loop_clients_validation_and_streams():
    with pytest.raises(ValueError):
        ClosedLoopClients(clients=0, think_mean=0.1)
    with pytest.raises(ValueError):
        ClosedLoopClients(clients=2, think_mean=0.0)
    c = ClosedLoopClients(clients=8, think_mean=0.05, seed=3)
    first = c.initial_arrivals(10.0)
    assert len(first) == 8 and all(t >= 0.0 for t, _ in first)
    d1 = c.think(0)
    # re-seeding restores every substream: same first arrivals, same draws
    again = c.initial_arrivals(10.0)
    assert again == first
    assert c.think(0) == d1


def test_closed_loop_serving_deterministic_and_self_clocked():
    """A closed-loop population issues its next request only after the
    previous one completes (or is refused): two runs replay identically
    and per-client request streams never overlap in time."""
    def once():
        task_mod._task_counter = itertools.count(760_000)
        tb = _testbed()
        root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
        tenants = [TenantSpec(
            "cl", ClosedLoopClients(clients=6, think_mean=0.02, seed=7),
            single_task_request("svm", origin=tb.edges[0], sla=0.2),
            sla=0.2)]
        loop = ServeLoop(tb.graph, root, tenants,
                         truth=ground_truth_traverser(tb.graph, 0),
                         admission=admit_all(), horizon=0.4)
        return loop.run()
    a = once()
    b = once()
    assert len(a.requests) > 6              # completions spawned new ones
    assert a.engine_opens == 1
    assert [r.verdict for r in a.requests] == \
        [r.verdict for r in b.requests]
    assert [(r.client, r.arrival, r.finish) for r in a.requests] == \
        [(r.client, r.arrival, r.finish) for r in b.requests]
    # per client: next arrival only after the previous request resolved
    by_client: dict = {}
    for r in sorted(a.requests, key=lambda r: r.arrival):
        by_client.setdefault(r.client, []).append(r)
    for reqs in by_client.values():
        for prev, nxt in zip(reqs, reqs[1:]):
            bound = prev.finish if prev.finish == prev.finish \
                else prev.arrival
            assert nxt.arrival >= bound - TOL
