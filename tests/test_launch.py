"""Launch-layer tests: sharding rules, placement search, HLO analysis,
data pipeline.  These run on the single real CPU device (a (1,1) mesh) —
the 512-device path is exercised by launch/dryrun.py itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import all_configs, get_config
from repro.configs.shapes import SHAPES, cells, input_specs, shape_applicable
from repro.core.placement import (Plan, cache_bytes_total, candidate_plans,
                                  choose_plan, model_flops, predict_plan)
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.launch.sharding import _logical_for, _resolve, make_shardings


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_logical_rules():
    assert _logical_for("stack/rem/0/attn/wq", 2) == ("fsdp", "tp")
    assert _logical_for("stack/rem/0/attn/wo", 2) == ("tp", "fsdp")
    assert _logical_for("embed", 2) == ("tp", "fsdp")
    assert _logical_for("stack/blocks/0/moe/wg", 3) == ("tp", "fsdp", None)
    # stacked scan layers get a leading None
    assert _logical_for("stack/blocks/0/attn/wq", 3) == (None, "fsdp", "tp")
    # caches honor cache_mode
    assert _logical_for("blocks/0/attn/k", 4) == ("batch", None, None, None)
    assert _logical_for("blocks/0/attn/k", 4, "seq") == ("batch", "ctp", None, None)
    # 5-dim stacked cache pads a leading None
    assert _logical_for("blocks/0/attn/k", 5, "heads") == (
        None, "batch", None, "ctp", None)
    assert _logical_for("unknown/leaf", 3) == (None, None, None)


def test_resolve_divisibility_fallback():
    mesh = make_host_mesh()          # (1,1) on CPU: everything divides
    spec = _resolve(("fsdp", "tp"), (8, 8), mesh, "tp_fsdp", ("data",))
    assert isinstance(spec, P)
    # simulated larger mesh via a fake object
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 4)
    spec = _resolve(("fsdp", "tp"), (6, 8), FakeMesh, "tp_fsdp", ("data",))
    assert spec[0] is None          # 6 % 4 != 0 -> replicated
    assert spec[1] == "model"
    spec = _resolve(("batch", None), (8, 3), FakeMesh, "tp_fsdp", ("data",))
    assert spec[0] == "data"
    # ctp always maps to model regardless of policy
    spec = _resolve(("batch", "ctp", None, None), (8, 64, 2, 4),
                    FakeMesh, "fsdp_only", ("data",))
    assert spec[1] == "model"


def test_make_shardings_tree():
    mesh = make_host_mesh()
    tree = {"embed": jnp.zeros((16, 8)),
            "stack": {"rem": ({"mlp": {"wg": jnp.zeros((8, 32))}},)}}
    sh = make_shardings(tree, mesh)
    leaves = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves) == 2


# ---------------------------------------------------------------------------
# shapes / cells
# ---------------------------------------------------------------------------
def test_cells_cover_assignment():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40      # 10 archs x 4 shapes
    runnable = [c for c in all_cells if c[2]]
    assert len(runnable) == 32       # long_500k runs only for 2 archs
    skipped = [(a, s) for a, s, ok, _ in all_cells if not ok]
    assert all(s == "long_500k" for _, s in skipped)
    assert ("recurrentgemma-9b", "long_500k") not in skipped
    assert ("rwkv6-1.6b", "long_500k") not in skipped


def test_input_specs_modes():
    cfg = get_config("whisper-large-v3")
    tr = input_specs(cfg, SHAPES["train_4k"])
    assert set(tr) == {"tokens", "labels", "frames"}
    assert tr["tokens"].shape == (256, 4096)
    de = input_specs(cfg, SHAPES["decode_32k"])
    assert set(de) == {"tokens", "positions", "frames"}
    assert de["tokens"].shape == (128, 1)
    vl = input_specs(get_config("phi-3-vision-4.2b"), SHAPES["prefill_32k"])
    assert "patches" in vl


# ---------------------------------------------------------------------------
# placement search
# ---------------------------------------------------------------------------
def test_choose_plan_fits_most_cells():
    notes = []
    for arch in all_configs():
        cfg = get_config(arch)
        for sname in ("train_4k", "prefill_32k", "decode_32k"):
            plan, cost = choose_plan(cfg, SHAPES[sname], (16, 16),
                                     ("data", "model"))
            if plan.notes:
                notes.append((arch, sname))
    # only 400B-class cells may be structurally infeasible on one pod
    assert all("llama4" in a for a, _ in notes), notes


def test_plan_prefers_conservative_dtypes():
    cfg = get_config("gemma3-1b")
    plan, _ = choose_plan(cfg, SHAPES["train_4k"], (16, 16),
                          ("data", "model"))
    assert plan.param_dtype == "float32"
    assert plan.state_dtype == "float32"


def test_predict_plan_memory_monotonic_in_microbatches():
    cfg = get_config("gemma3-4b")
    mems = []
    for mb in (1, 4, 16):
        c = predict_plan(cfg, SHAPES["train_4k"], (16, 16),
                         ("data", "model"),
                         Plan(microbatches=mb))
        mems.append(c.mem_bytes)
    assert mems[0] > mems[1] > mems[2]


def test_model_flops_moe_uses_active_params():
    dense = get_config("minitron-4b")
    moe = get_config("llama4-maverick-400b-a17b")
    f_moe = model_flops(moe, 1e6, "train")
    # active-param flops must be ~25x below total-param flops for 400b/17b
    n_total = moe.param_count()
    f_if_total = 6.0 * n_total * 1e6
    assert f_moe < 0.15 * f_if_total
    assert model_flops(dense, 1e6, "serve") == pytest.approx(
        model_flops(dense, 1e6, "train") / 3.0)


def test_cache_bytes_families():
    g3 = cache_bytes_total(get_config("gemma3-4b"), B=1, S=32768)
    rw = cache_bytes_total(get_config("rwkv6-1.6b"), B=1, S=32768)
    assert rw < g3 / 50       # state-space cache is constant in S
    # and truly constant: quadrupling S must not change it
    assert rw == cache_bytes_total(get_config("rwkv6-1.6b"), B=1, S=131072)


def test_multipod_candidates_include_pod_fsdp():
    cfg = get_config("llama4-maverick-400b-a17b")
    plans = candidate_plans(cfg, SHAPES["train_4k"])
    assert any(p.policy == "fsdp_pod" for p in plans)
    plan, cost = choose_plan(cfg, SHAPES["train_4k"], (2, 16, 16),
                             ("pod", "data", "model"))
    assert cost.mem_bytes < 16e9 or plan.notes


# ---------------------------------------------------------------------------
# HLO analysis (loop-aware cost parsing)
# ---------------------------------------------------------------------------
def test_hlo_dot_flops_exact():
    def f(a, b):
        return a @ b
    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 64), jnp.float32),
                            jax.ShapeDtypeStruct((64, 16), jnp.float32)
                            ).compile()
    rep = analyze_hlo(comp.as_text())
    assert rep.dot_flops == 2 * 32 * 64 * 16


def test_hlo_while_multiplier():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 16), jnp.float32),
                            jax.ShapeDtypeStruct((16, 16), jnp.float32)
                            ).compile()
    rep = analyze_hlo(comp.as_text())
    assert rep.dot_flops == 7 * 2 * 8 * 16 * 16
    assert rep.n_while == 1


def test_hlo_collective_parsing_canned():
    txt = """
HloModule m

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %all-reduce = f32[128,256]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %all-gather = f32[128,256]{1,0} all-gather(%all-reduce), channel_id=2, dimensions={1}
}
"""
    rep = analyze_hlo(txt)
    assert rep.collective_bytes["all-reduce"] == 128 * 256 * 4
    assert rep.collective_bytes["all-gather"] == 128 * 256 * 4
    terms = roofline_terms(rep, n_chips=8)
    assert terms["t_collective_s"] > 0
    assert terms["bottleneck"] in ("compute", "memory", "collective")


def test_hlo_nested_loops_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(h, _):
                return jnp.tanh(h @ w), ()
            h, _ = jax.lax.scan(inner, c, None, length=3)
            return h, ()
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 8), jnp.float32),
                            jax.ShapeDtypeStruct((8, 8), jnp.float32)
                            ).compile()
    rep = analyze_hlo(comp.as_text())
    assert rep.dot_flops == 15 * 2 * 4 * 8 * 8


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_data_deterministic():
    from repro.data.pipeline import DataConfig, synthetic_batches
    cfg = DataConfig(batch=4, seq=16, vocab=128, seed=3)
    a = next(synthetic_batches(cfg))
    b = next(synthetic_batches(cfg))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert a["labels"].max() < 128
    # labels are next-token shifted
    it = synthetic_batches(cfg)
    batch = next(it)
    assert not np.array_equal(batch["tokens"], batch["labels"])


def test_prefetcher_drains():
    from repro.data.pipeline import DataConfig, Prefetcher, synthetic_batches
    it = synthetic_batches(DataConfig(batch=2, seq=8, vocab=64))
    pf = Prefetcher(it, depth=2)
    batches = [next(pf) for _ in range(4)]
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    pf.close()
