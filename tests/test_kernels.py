"""Pallas kernel validation: interpret-mode sweeps vs the pure-jnp oracles
in kernels/ref.py (shapes x dtypes x masking variants), plus causality and
numerical-stability properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.models.recurrent import wkv_chunked


def _qkv(key, B, S, Hq, Hkv, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    return (x.astype(dtype) for x in (q, k, v))


TOL = {jnp.float32: 2e-3, jnp.bfloat16: 6e-2}


@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (1, 128, 2, 2, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA 2:1
    (1, 256, 8, 1, 128),    # MQA
    (1, 512, 4, 4, 256),    # large head dim (gemma-class)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal_sweep(key, B, S, Hq, Hkv, hd, dtype):
    q, k, v = _qkv(key, B, S, Hq, Hkv, hd, dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [64, 128, 512])
def test_flash_attention_sliding_window(key, window):
    q, k, v = _qkv(key, 1, 512, 4, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=128, block_k=128)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3)


@pytest.mark.parametrize("softcap", [20.0, 50.0])
def test_flash_attention_softcap(key, softcap):
    """gemma2's logit softcapping inside the kernel."""
    q, k, v = _qkv(key, 1, 256, 4, 4, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, softcap=softcap,
                              block_q=128, block_k=128)
    want = ref.attention_ref(q, k, v, causal=True, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3)


def test_flash_attention_causality(key):
    """Perturbing future tokens must not change past outputs."""
    q, k, v = _qkv(key, 1, 256, 2, 2, 64, jnp.float32)
    out1 = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    k2 = k.at[:, 128:].add(100.0)
    v2 = v.at[:, 128:].add(-50.0)
    out2 = ops.flash_attention(q, k2, v2, causal=True,
                               block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(out1[:, :128]),
                               np.asarray(out2[:, :128]), atol=1e-5)


def test_flash_attention_extreme_logits(key):
    """Online softmax must survive large score magnitudes (no NaN/overflow)."""
    q, k, v = _qkv(key, 1, 128, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(q * 100.0, k * 100.0, v,
                              causal=True, block_q=64, block_k=64)
    assert not np.any(np.isnan(np.asarray(out)))
    want = ref.attention_ref(q * 100.0, k * 100.0, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3)


def test_flash_attention_indivisible_block_raises(key):
    from repro.kernels.flash_attention import flash_attention_bhsd
    q = jnp.zeros((2, 100, 64))
    with pytest.raises(ValueError):
        flash_attention_bhsd(q, q, q, num_kv_heads=2, block_q=64, block_k=64,
                             interpret=True)


# ---------------------------------------------------------------------------
# lru_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,W,bs,bw", [
    (1, 64, 128, 32, 128),
    (2, 256, 256, 128, 128),
    (1, 128, 100, 64, 64),     # W padded to block multiple
    (3, 96, 64, 256, 512),     # blocks clamp to dims
])
def test_lru_scan_sweep(key, B, S, W, bs, bw):
    k1, k2 = jax.random.split(key)
    a = jax.random.uniform(k1, (B, S, W), jnp.float32, 0.7, 0.999)
    b = jax.random.normal(k2, (B, S, W), jnp.float32)
    out = ops.lru_scan(a, b, block_s=bs, block_w=bw)
    want = ref.lru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(s=st.integers(2, 6).map(lambda e: 2 ** e),
       w=st.integers(4, 130),
       seed=st.integers(0, 2**31 - 1))
def test_lru_scan_property(s, w, seed):
    """Property sweep over arbitrary (S, W): kernel == sequential scan."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    a = jax.random.uniform(k1, (1, s, w), jnp.float32, 0.0, 1.0)
    b = jax.random.normal(k2, (1, s, w), jnp.float32)
    out = ops.lru_scan(a, b, block_s=32, block_w=64)
    want = ref.lru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_lru_scan_decay_bound(key):
    """|a| <= 1 and bounded b => output bounded by sum of |b| tail (stability)."""
    a = jnp.full((1, 64, 32), 0.5)
    b = jnp.ones((1, 64, 32))
    out = ops.lru_scan(a, b, block_s=32, block_w=32)
    assert float(jnp.max(jnp.abs(out))) <= 2.0 + 1e-6   # geometric sum bound


# ---------------------------------------------------------------------------
# chunked WKV (rwkv6) vs naive recurrence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (64, 64), (48, 16)])
def test_wkv_chunked_matches_ref(key, S, chunk):
    B, H, hd = 2, 2, 16
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    o1, s1 = wkv_chunked(r, k, v, lw, u, chunk=chunk)
    o2, s2 = ref.wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=2e-5, rtol=1e-4)


def test_wkv_carried_state(key):
    """Splitting a sequence in halves with carried state == one pass."""
    B, S, H, hd = 1, 32, 2, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.3 - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    o_full, s_full = wkv_chunked(r, k, v, lw, u, chunk=8)
    h = S // 2
    o1, s1 = wkv_chunked(r[:, :h], k[:, :h], v[:, :h], lw[:, :h], u, chunk=8)
    o2, s2 = wkv_chunked(r[:, h:], k[:, h:], v[:, h:], lw[:, h:], u,
                         chunk=8, state0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=2e-5, rtol=1e-4)
