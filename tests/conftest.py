"""Shared fixtures.  NOTE: no XLA_FLAGS device forcing here — tests run on
the real single CPU device; only launch/dryrun.py fabricates 512 devices."""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def testbed():
    from repro.core import build_testbed
    return build_testbed()


@pytest.fixture()
def key():
    return jax.random.key(0)
