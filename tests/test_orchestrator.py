"""Orchestrator tests (paper §3.5, Alg. 1): hierarchy construction,
local-first mapping, escalation, constraint protection, overhead ledger."""
import pytest

from repro.core import (ActiveLedger, OrcConfig, Orchestrator, Traverser,
                        build_orchestrators, build_testbed, heye_traverser)
from repro.core.topology import make_task


@pytest.fixture()
def setup():
    tb = build_testbed(edge_counts={"orin_agx": 1, "orin_nano": 1},
                       server_counts={"server1": 1, "server2": 1})
    trav = heye_traverser(tb.graph)
    root = build_orchestrators(tb.graph, trav)
    return tb, trav, root


def test_hierarchy_matches_fig4b(setup):
    tb, _, root = setup
    # root has two cluster ORCs (edge + server), each with device children
    assert len(root.children) == 2
    groups = sorted(c.group for c in root.children)
    assert groups == ["edge_cluster", "server_cluster"]
    devices = [o.group for c in root.children for o in c.children]
    assert set(devices) == set(tb.edges) | set(tb.servers)
    # device ORCs know their own PUs only (resource segregation)
    for c in root.children:
        for dev in c.children:
            assert dev.leaf_pus
            assert all(p.startswith(dev.group + ".") for p in dev.leaf_pus)
    # cluster and root ORCs hold no PUs directly
    assert not root.leaf_pus
    assert all(not c.leaf_pus for c in root.children)


def test_local_first_assignment(setup):
    tb, _, root = setup
    e = tb.edges[0]
    orc = root.find_device_orc(e)
    t = make_task("capture", origin=e, deadline=0.1)
    res = orc.map_task(t)
    assert res is not None
    assert res.pu.startswith(e + ".")       # stayed local
    assert res.hops == 0                    # no remote queries
    assert t.assigned_pu == res.pu


def test_escalation_to_server(setup):
    tb, _, root = setup
    e = tb.edges[1]                         # orin_nano: render at 90 ms
    orc = root.find_device_orc(e)
    t = make_task("render", origin=e, deadline=0.030, input_bytes=4e3)
    res = orc.map_task(t)
    assert res is not None
    dev = tb.graph.device_of(res.pu).name
    assert dev in tb.servers                # escalated off-device
    assert res.hops > 0                     # remote messages counted
    assert res.overhead > 0.0


def test_pinned_stays_local(setup):
    tb, _, root = setup
    e = tb.edges[1]
    orc = root.find_device_orc(e)
    t = make_task("capture", origin=e, deadline=0.1)
    t.attrs["pinned"] = True
    res = orc.map_task(t)
    assert tb.graph.device_of(res.pu).name == e


def test_existing_task_constraints_protected(setup):
    """Alg. 1 l.15: a new task must not break a resident task's deadline."""
    tb, trav, root = setup
    e = tb.edges[0]
    orc = root.find_device_orc(e)
    gpu = f"{e}.gpu"
    # resident: a GPU task with a deadline it barely meets
    sa = tb.graph.nodes[gpu].predict(make_task("dnn"))
    resident = make_task("dnn", origin=e, deadline=sa * 1.05)
    pred = trav.predict_task(resident, gpu, [])
    orc.ledger.add(resident, gpu, pred, now=0.0)
    # a new heavy task on the same GPU would slow the resident beyond 1.05x
    newbie = make_task("dnn", origin=e, deadline=10.0)
    ok, _ = orc._check_constraints(newbie, gpu, now=0.0)
    assert not ok
    # but a task on a PU that does not contend hard is fine
    ok2, _ = orc._check_constraints(
        make_task("capture", origin=e, deadline=10.0), f"{e}.cpu0", now=0.0)
    assert ok2


def test_best_effort_when_nothing_fits(setup):
    tb, _, root = setup
    e = tb.edges[0]
    orc = root.find_device_orc(e)
    t = make_task("render", origin=e, deadline=1e-9)   # impossible deadline
    res = orc.map_task(t)
    assert res is not None                  # degraded, not dropped
    t2 = make_task("render", origin=e, deadline=1e-9)
    cfg = OrcConfig(allow_best_effort=False)
    orc2 = build_orchestrators(tb.graph, heye_traverser(tb.graph),
                               config=cfg).find_device_orc(e)
    assert orc2.map_task(t2) is None


def test_ledger_prune_and_remove(setup):
    tb, trav, root = setup
    e = tb.edges[0]
    led = ActiveLedger()
    t = make_task("dnn", origin=e)
    led.add(t, f"{e}.gpu", trav.predict_task(t, f"{e}.gpu", []), now=0.0)
    assert led.count(f"{e}.gpu") == 1
    led.prune(now=1e9)
    assert led.count(f"{e}.gpu") == 0
    led.add(t, f"{e}.gpu", trav.predict_task(t, f"{e}.gpu", []), now=0.0)
    led.remove(t)
    assert led.count(f"{e}.gpu") == 0


def test_first_fit_cheaper_than_best_fit(setup):
    tb, trav, _ = setup
    e = tb.edges[0]
    t_bf = make_task("pose_pred", origin=e, deadline=0.5)
    t_ff = make_task("pose_pred", origin=e, deadline=0.5)
    best = build_orchestrators(tb.graph, trav, config=OrcConfig())
    first = build_orchestrators(tb.graph, trav,
                                config=OrcConfig(objective="first_fit"))
    r_bf = best.find_device_orc(e).map_task(t_bf)
    r_ff = first.find_device_orc(e).map_task(t_ff)
    assert r_ff.queries <= r_bf.queries


def test_dead_pu_not_assigned(setup):
    tb, trav, _ = setup
    e = tb.edges[0]
    tb.graph.mark_dead(f"{e}.gpu")
    root = build_orchestrators(tb.graph, heye_traverser(tb.graph))
    orc = root.find_device_orc(e)
    t = make_task("dnn", origin=e, deadline=1.0)
    res = orc.map_task(t)
    assert res is not None and res.pu != f"{e}.gpu"
    tb.graph.mark_alive(f"{e}.gpu")


def test_overhead_scales_with_remote_search(setup):
    tb, _, root = setup
    e = tb.edges[1]
    orc = root.find_device_orc(e)
    local = orc.map_task(make_task("capture", origin=e, deadline=1.0))
    remote = orc.map_task(make_task("render", origin=e, deadline=0.030,
                                    input_bytes=4e3))
    assert remote.overhead > local.overhead
